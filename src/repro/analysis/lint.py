"""AST linter for the repro tree (DESIGN.md §9).

The codebase's hot-path invariants — no host syncs inside jitted code, no
tracer-dependent Python branching, one Pallas dispatch policy — are enforced
dynamically by the test suite but are trivially easy to reintroduce in a
cold corner no test exercises. This module enforces them *statically*:

1. **Module index** (:class:`ModuleInfo`): every file under ``src/repro``
   is parsed once; function defs (including nested, by dotted qualname),
   import aliases and ``from``-imports are indexed so calls like
   ``MD.decode_step_slots`` resolve across modules.
2. **Jit reachability** (:class:`Analyzer`): roots are the functions that
   become jit/scan/cond/vmap/pallas bodies — passed by name, returned by a
   maker whose result is jitted (``jax.jit(make_slot_admit(cfg))`` marks
   every function nested in ``make_slot_admit``), decorated with ``jax.jit``
   / ``functools.partial(jax.jit, ...)`` / ``pallas_dispatch``, or called
   from a jitted lambda. The call graph is walked transitively; rules that
   only make sense inside traced code (host casts, numpy-on-traced,
   tracer branching) fire only in reachable functions.
3. **Taint** (in ``rules.py``): inside a reachable function, names assigned
   from ``jnp.``/``jax.``/``lax.`` calls (and subscripts/arithmetic over
   them) are treated as traced values. Parameters are deliberately NOT
   assumed traced — makers close over static Python config everywhere in
   this tree, and assuming params traced would drown the signal in false
   positives. The fixture tests in ``tests/test_analysis.py`` pin what each
   rule can and cannot see.

Suppressions: ``# lint: ignore[RA###] <reason>`` on the offending line
drops the finding but records it (``LintReport.suppressed``); the CLI
prints the count so blanket-suppressed trees stay visible in review.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = [
    "Finding", "LintReport", "ModuleInfo", "Analyzer", "run_lint",
    "repo_src_root",
]

# dotted call targets whose function-valued arguments become traced bodies
JIT_WRAPPERS = {
    "jax.jit", "jax.pjit", "jax.vmap", "jax.pmap", "jax.grad",
    "jax.value_and_grad", "jax.eval_shape", "jax.checkpoint", "jax.remat",
    "jax.lax.scan", "jax.lax.cond", "jax.lax.while_loop",
    "jax.lax.fori_loop", "jax.lax.switch", "jax.lax.map",
    "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}
# method names that jit their function argument regardless of receiver:
# TraceGuard.wrap_jit(name, fn, ...) is the engine's registration point for
# every hot entry, so a body handed to it is a traced body even when the
# receiver (`self._guard`) can't be resolved statically
JIT_WRAPPER_METHODS = {"wrap_jit"}
# decorators that mark a def as a traced body outright
JIT_DECORATORS = {"jax.jit", "pallas_dispatch"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    msg: str
    reason: str = ""          # suppression reason (suppressed findings only)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.msg}"


@dataclasses.dataclass
class LintReport:
    findings: List[Finding]
    suppressed: List[Finding]

    @property
    def ok(self) -> bool:
        return not self.findings


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ModuleInfo:
    """One parsed module: function index, import maps, suppressions."""

    def __init__(self, name: str, path: str, source: str):
        self.name = name
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        # qualname ('outer.inner' for nested defs) -> FunctionDef node
        self.funcs: Dict[str, ast.AST] = {}
        self.import_alias: Dict[str, str] = {}      # 'MD' -> 'repro.models.model'
        self.from_funcs: Dict[str, Tuple[str, str]] = {}  # 'init' -> (mod, name)
        self.suppressions: Dict[int, Tuple[Set[str], str]] = {}
        self._index()
        self._scan_suppressions()

    # ------------------------------------------------------------- indexing
    def _index(self) -> None:
        def visit(node: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}" if prefix else child.name
                    self.funcs[q] = child
                    visit(child, q + ".")
                elif isinstance(child, ast.ClassDef):
                    visit(child, (prefix + child.name + "."))
                else:
                    visit(child, prefix)

        visit(self.tree, "")
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.import_alias[a.asname or a.name.split(".")[0]] = (
                        a.name)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    local = a.asname or a.name
                    full = f"{node.module}.{a.name}"
                    # `from repro.models import model as MD` -> module alias;
                    # `from x import f` -> either a function or a module;
                    # record both views, resolution tries funcs first.
                    self.import_alias[local] = full
                    self.from_funcs[local] = (node.module, a.name)

    def _scan_suppressions(self) -> None:
        import re
        pat = re.compile(r"#\s*lint:\s*ignore\[([A-Z0-9,\s]+)\]\s*(.*)")
        for i, line in enumerate(self.source.splitlines(), start=1):
            m = pat.search(line)
            if m:
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                self.suppressions[i] = (codes, m.group(2).strip())

    # ----------------------------------------------------------- resolution
    def expand(self, dotted: str) -> str:
        """Map a dotted call through this module's import aliases:
        'lax.scan' -> 'jax.lax.scan', 'MD.forward' ->
        'repro.models.model.forward'."""
        root, _, rest = dotted.partition(".")
        full = self.import_alias.get(root)
        if full is None:
            return dotted
        return f"{full}.{rest}" if rest else full


def repo_src_root() -> str:
    """Directory holding the ``repro`` package (…/src). ``repro`` is a
    namespace package (no __init__.py), so resolve via ``__path__``."""
    import repro
    return os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def load_modules(root: Optional[str] = None) -> Dict[str, ModuleInfo]:
    """Parse every repro module under ``root`` (default: the installed
    src tree) into :class:`ModuleInfo` keyed by module name."""
    root = root or repo_src_root()
    mods: Dict[str, ModuleInfo] = {}
    for dirpath, dirnames, filenames in os.walk(os.path.join(root, "repro")):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root)
            name = rel[:-3].replace(os.sep, ".")
            if name.endswith(".__init__"):
                name = name[: -len(".__init__")]
            with open(path, "r", encoding="utf-8") as f:
                src = f.read()
            mods[name] = ModuleInfo(name, path, src)
    return mods


class Analyzer:
    """Cross-module jit-reachability over the parsed tree."""

    def __init__(self, modules: Dict[str, ModuleInfo]):
        self.modules = modules
        # (module_name, qualname) pairs
        self.roots: Set[Tuple[str, str]] = set()
        # static_argnames recorded for directly-jitted defs (rule RA006)
        self.jit_statics: Dict[Tuple[str, str], Set[str]] = {}
        self._edges: Dict[Tuple[str, str], Set[Tuple[str, str]]] = {}
        self._collect_roots()
        self._collect_edges()
        self.reachable = self._walk()

    # -------------------------------------------------------------- helpers
    def _resolve(self, mod: ModuleInfo, scope: str,
                 node: ast.AST) -> Optional[Tuple[str, str]]:
        """Resolve a Name/Attribute callee to (module, qualname)."""
        if isinstance(node, ast.Name):
            # innermost enclosing scope outward
            parts = scope.split(".") if scope else []
            for i in range(len(parts), -1, -1):
                q = ".".join(parts[:i] + [node.id])
                if q in mod.funcs:
                    return (mod.name, q)
            if node.id in mod.from_funcs:
                m, f = mod.from_funcs[node.id]
                target = self.modules.get(m)
                if target and f in target.funcs:
                    return (m, f)
                # `from x import y` where y is a module
                if f"{m}.{f}" in self.modules:
                    return None
            return None
        if isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if dotted is None:
                return None
            full = mod.expand(dotted)
            m, _, f = full.rpartition(".")
            target = self.modules.get(m)
            if target and f in target.funcs:
                return (m, f)
        return None

    @staticmethod
    def _unwrap_partial(node: ast.AST) -> ast.AST:
        """functools.partial(f, ...) -> f (one level)."""
        if (isinstance(node, ast.Call)
                and _dotted(node.func) in ("functools.partial", "partial")
                and node.args):
            return node.args[0]
        return node

    def _local_assigns(self, fn: ast.AST) -> Dict[str, List[ast.AST]]:
        # EVERY assignment to the name, not just the last: the engine picks
        # its jit bodies by branch (`fn = mesh_maker(...)` in one arm,
        # `fn = maker(...)` in the other) and both arms are traced bodies
        out: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                out.setdefault(node.targets[0].id, []).append(node.value)
        return out

    def _mark_body(self, mod: ModuleInfo, scope: str, arg: ast.AST,
                   assigns: Dict[str, ast.AST], depth: int = 0) -> None:
        """Mark the function(s) an argument expression denotes as roots."""
        if depth > 4:
            return
        arg = self._unwrap_partial(arg)
        if isinstance(arg, ast.IfExp):
            # `maker_a(...) if cond else maker_b(...)`: either arm may be
            # the jitted body depending on runtime config — mark both
            self._mark_body(mod, scope, arg.body, assigns, depth + 1)
            self._mark_body(mod, scope, arg.orelse, assigns, depth + 1)
            return
        if isinstance(arg, ast.Lambda):
            # a jitted lambda's callees are the traced bodies
            for sub in ast.walk(arg.body):
                if isinstance(sub, ast.Call):
                    t = self._resolve(mod, scope, sub.func)
                    if t:
                        self.roots.add(t)
            return
        if isinstance(arg, ast.Call):
            # jit(make_x(cfg)): every def nested in the maker is a body
            maker = self._resolve(mod, scope, arg.func)
            if maker:
                mmod, mq = maker
                for q in self.modules[mmod].funcs:
                    if q.startswith(mq + "."):
                        self.roots.add((mmod, q))
                # the maker itself runs on host but may return a plain
                # module function; treat it as reachable-for-rules too
                self.roots.add(maker)
            return
        target = self._resolve(mod, scope, arg)
        if target:
            self.roots.add(target)
            return
        if isinstance(arg, ast.Name) and arg.id in assigns:
            for value in assigns[arg.id]:
                self._mark_body(mod, scope, value, assigns, depth + 1)

    # ---------------------------------------------------------------- roots
    def _collect_roots(self) -> None:
        for mod in self.modules.values():
            # decorator-marked bodies
            for q, fn in mod.funcs.items():
                for dec in getattr(fn, "decorator_list", []):
                    d = self._unwrap_partial(dec)
                    dotted = _dotted(d if not isinstance(d, ast.Call)
                                     else d.func)
                    name = mod.expand(dotted) if dotted else None
                    base = dotted.rsplit(".", 1)[-1] if dotted else None
                    if name in JIT_DECORATORS or base in JIT_DECORATORS:
                        self.roots.add((mod.name, q))
                        self.jit_statics[(mod.name, q)] = (
                            self._static_names(dec))
            # call-site bodies, scoped so local assigns resolve
            for scope, fn in list(mod.funcs.items()) + [("", mod.tree)]:
                assigns = self._local_assigns(fn)
                for node in self._own_nodes(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    dotted = _dotted(node.func)
                    if dotted is None:
                        continue
                    full = mod.expand(dotted)
                    short = dotted.rsplit(".", 1)[-1]
                    if full in JIT_WRAPPERS or short in JIT_WRAPPER_METHODS \
                            or (short == "pallas_call" and "pallas" in full):
                        for arg in list(node.args) + [
                                kw.value for kw in node.keywords]:
                            self._mark_body(mod, scope, arg, assigns)
                        # record static_argnames for directly-jitted defs
                        if full in ("jax.jit", "jax.pjit") and node.args:
                            t = self._resolve(mod, scope,
                                              self._unwrap_partial(
                                                  node.args[0]))
                            if t:
                                self.jit_statics.setdefault(
                                    t, set()).update(
                                        self._static_names(node))

    @staticmethod
    def _static_names(node: ast.AST) -> Set[str]:
        """static_argnames entries of a jit call/partial-decorator node."""
        out: Set[str] = set()
        if not isinstance(node, ast.Call):
            return out
        for kw in node.keywords:
            if kw.arg == "static_argnames":
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) and isinstance(
                            sub.value, str):
                        out.add(sub.value)
        return out

    # ---------------------------------------------------------------- edges
    def _own_nodes(self, fn: ast.AST) -> Iterable[ast.AST]:
        """All nodes of ``fn`` excluding nested function bodies (those have
        their own entries)."""
        stack = (list(ast.iter_child_nodes(fn)) if isinstance(
            fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module))
            else [fn])
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child,
                              (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                stack.append(child)

    def _collect_edges(self) -> None:
        for mod in self.modules.values():
            for q, fn in mod.funcs.items():
                edges: Set[Tuple[str, str]] = set()
                for node in self._own_nodes(fn):
                    if isinstance(node, ast.Call):
                        t = self._resolve(mod, q, node.func)
                        if t:
                            edges.add(t)
                    elif isinstance(node, (ast.Name, ast.Attribute)):
                        # passing a function by reference (partial args,
                        # tree.map callables) keeps it reachable
                        t = self._resolve(mod, q, node)
                        if t:
                            edges.add(t)
                self._edges[(mod.name, q)] = edges

    def _walk(self) -> Set[Tuple[str, str]]:
        seen: Set[Tuple[str, str]] = set()
        frontier = list(self.roots)
        while frontier:
            cur = frontier.pop()
            if cur in seen or cur[1] not in self.modules.get(
                    cur[0], ModuleInfo("", "<none>", "")).funcs:
                if cur in seen:
                    continue
            seen.add(cur)
            for nxt in self._edges.get(cur, ()):
                if nxt not in seen:
                    frontier.append(nxt)
        return seen


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_lint(root: Optional[str] = None,
             rules: Optional[Iterable[str]] = None) -> LintReport:
    """Lint the repro tree. ``root``: directory containing the ``repro``
    package (defaults to the installed one). ``rules``: optional rule-id
    allowlist."""
    from repro.analysis import rules as R
    modules = load_modules(root)
    analyzer = Analyzer(modules)
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    active = list(R.RULES)
    if rules is not None:
        wanted = set(rules)
        active = [r for r in active if r.rule_id in wanted]
    for mod in modules.values():
        for rule in active:
            for f in rule.check(mod, analyzer):
                sup = mod.suppressions.get(f.line)
                if sup and f.rule in sup[0]:
                    suppressed.append(dataclasses.replace(
                        f, reason=sup[1] or "(no reason given)"))
                else:
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    suppressed.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintReport(findings, suppressed)
