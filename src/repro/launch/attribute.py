"""Collective / traffic attribution for a dry-run cell — the §Perf profiling
tool (we have no wall-clock TPU profile; the lowered IR is the profile).

    PYTHONPATH=src python -m repro.launch.attribute --arch kimi-k2-1t-a32b \
        --shape train_4k --top 15
"""
import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")
os.environ.setdefault("REPRO_TPU_SEMANTICS", "1")

import argparse
import collections
import re
import shutil
import tempfile
from pathlib import Path

from repro import configs
from repro.launch import dryrun as DR
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models.numerics import set_activation_mesh


def _mults(hlo, comps):
    entry = next((n for n in comps
                  if re.search(r"ENTRY\s+%?" + re.escape(n), hlo)), None)
    mult = collections.defaultdict(float)
    mult[entry] = 1.0
    for _ in range(12):
        ch = False
        for name, comp in comps.items():
            if mult[name] <= 0:
                continue
            for callee, kind in H._call_edges(comp):
                if callee not in comps:
                    continue
                if kind in ("while_body", "while_cond"):
                    conds = [c for c, k in H._call_edges(comp)
                             if k == "while_cond"]
                    t = max([H._trip_count(comps[c]) for c in conds
                             if c in comps] or [1])
                    new = mult[name] * t
                else:
                    new = mult[name]
                if new > mult[callee]:
                    mult[callee] = new
                    ch = True
        if not ch:
            break
    return mult


def attribute(arch, shape, multi_pod=False, top=15, cfg_override=None,
              opt_override=None, kind_filter="coll"):
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    sh = configs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)
    from repro.optim import default_optimizer_for
    opt = opt_override or default_optimizer_for(cfg.param_count())
    dump = Path(tempfile.mkdtemp(prefix="attr_"))
    try:
        _, compiled, _, _ = DR._lower_compile(
            cfg, sh["kind"], sh["global_batch"], sh["seq_len"], mesh, opt,
            dump_dir=dump)
        hlo = DR._read_spmd_dump(dump)
    finally:
        shutil.rmtree(dump, ignore_errors=True)
        set_activation_mesh(None)
    comps = H.split_computations(hlo)
    mult = _mults(hlo, comps)
    rows = []
    for name, comp in comps.items():
        m = mult[name] or 0
        if m <= 0:
            continue
        sym = {i.name: H._shape_bytes(i.type_str) for i in comp.instrs}
        for ins in comp.instrs:
            ckind = next((k for k in H._COLL_KINDS
                          if ins.opcode in (k, k + "-start")), None)
            if kind_filter == "coll" and not ckind:
                continue
            if kind_filter == "traffic" and (
                    ckind or ins.opcode not in H._TRAFFIC_OPS):
                continue
            if ckind:
                b = H._shape_bytes(ins.type_str) * H._COLL_FACTOR[ckind] * m
                label = ckind
            else:
                b = (H._shape_bytes(ins.type_str)
                     + H._operand_bytes(ins, sym)) * m
                label = ins.opcode
            meta = re.search(r'op_name="([^"]+)"', ins.line)
            rows.append((b, label, int(m), ins.type_str[:44],
                         (meta.group(1) if meta else "")[-95:]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total {kind_filter} bytes/dev: {total/2**30:.1f} GiB")
    for b, label, m, t, meta in rows[:top]:
        print(f" {b/2**30:9.2f}GiB x{m:3d} {label:18s} {t:44s} {meta}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--kind", default="coll", choices=["coll", "traffic"])
    args = ap.parse_args()
    attribute(args.arch, args.shape, args.multi_pod, args.top,
              kind_filter=args.kind)
