"""Calibration capture (JAX replacement for the paper's Torch hooks, App. B).

Two surfaces over the same capture forward:

* :class:`CalibrationStream` — a STREAMING accumulator. Feed it batches one
  at a time (``update``); it keeps, per MoE layer, a bounded token reservoir
  of expert-input activations X̂ and the running usage counts f. Host memory
  is ``O(L * max_tokens * d)`` no matter how many batches are streamed
  (Algorithm-R reservoir sampling once the cap is hit, with ONE shared
  replacement schedule across layers so every layer keeps the same token
  positions — deterministic under ``seed``). The plan executor consumes it
  layer by layer.
* :func:`collect` — the legacy one-shot API, now a thin wrapper that streams
  every batch through a ``CalibrationStream`` and returns the familiar
  ``{layer: LayerCalibration}`` dict.

Because JAX forwards are pure, a single-shot capture is exactly equivalent to
the paper's back-to-front layer traversal (merging layer ℓ never perturbs
activations at layers ≤ ℓ) — see DESIGN.md §3.

**Mesh-parallel capture (DESIGN.md §6).** Pass ``mesh=`` and the capture
forward runs data-parallel: the batch is sharded over the mesh's batch axes
(``repro.launch.sharding.calib_pspecs``), weights are replicated, and each
device computes the captured activations for its batch slice. The reservoir
replacement schedule is a PURE FUNCTION of a token's global stream index
(:func:`reservoir_slots` — a counter-based splitmix64 draw, not a stateful
RNG), so every shard folds its own token range independently and the
cross-shard merge (:func:`merge_reservoirs` — per-slot max-g) is provably
identical to one sequential fold over the whole stream. That determinism is
what makes mesh-sharded compression bit-for-bit equal to single-device
(`tests/test_dist_compress.py`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models import model as MD


@dataclass
class LayerCalibration:
    x: np.ndarray        # [T, d] expert-layer inputs (tokens pooled)
    counts: np.ndarray   # [N] usage frequencies


# ---------------------------------------------------------------------------
# deterministic reservoir schedule (shared across layers AND shards)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def _u01(seed: int, g: np.ndarray) -> np.ndarray:
    """Counter-based uniform draws in [0, 1): a pure function of (seed,
    global token index). splitmix64 finalizer over the index — no RNG state,
    so the draw for token g is the same no matter which shard computes it or
    in what order tokens are folded."""
    z = g.astype(np.uint64)
    z = z ^ np.uint64((seed * 0x9E3779B97F4A7C15 + 0x632BE59BD9B4E019)
                      & _MASK64)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return (z >> np.uint64(11)).astype(np.float64) * 2.0 ** -53


def reservoir_slots(g: np.ndarray, cap: int, seed: int,
                    policy: str = "reservoir") -> np.ndarray:
    """Reservoir slot claimed by each global token index (-1 = dropped).

    Token g claims slot g while the reservoir fills; beyond that, Algorithm
    R — slot ``floor(u(g)·(g+1))`` iff it lands below ``cap`` (replacement
    probability cap/(g+1), uniform over slots). ``policy="head"`` claims
    only the fill phase (legacy first-``cap`` truncation).

    The final reservoir is defined as: slot j holds the token with the
    LARGEST global index among all tokens claiming j. Because the claim is a
    pure function of (seed, g), that definition is independent of how the
    stream is partitioned — any sharding folds to the same reservoir.
    """
    if policy == "head":
        return np.where(g < cap, g, -1)
    js = np.floor(_u01(seed, g) * (g + 1).astype(np.float64)).astype(np.int64)
    return np.where(g < cap, g, np.where(js < cap, js, -1))


def fold_tokens(x: np.ndarray, slot_g: np.ndarray, xi: np.ndarray,
                g: np.ndarray, *, cap: int, seed: int,
                policy: str = "reservoir") -> None:
    """Fold tokens ``xi [L, n, d]`` with global indices ``g [n]`` into the
    reservoir state (``x [L, cap, d]``, ``slot_g [cap]``) in place.

    Last-write-wins BY GLOBAL INDEX, not by call order: a slot is overwritten
    only when the incoming token's g exceeds the g already stored there, so
    folding any partition of a stream in any order yields the same state as
    one sequential pass."""
    slots = reservoir_slots(g, cap, seed, policy)
    keep = slots >= 0
    if not keep.any():
        return
    tok = np.flatnonzero(keep)
    slots, gk = slots[keep], g[keep]
    order = np.argsort(gk, kind="stable")
    slots, gk, tok = slots[order], gk[order], tok[order]
    # per-slot winner within this chunk: the last (max-g) occurrence
    uniq, first_rev = np.unique(slots[::-1], return_index=True)
    sel = len(slots) - 1 - first_rev
    win = gk[sel] > slot_g[uniq]
    tgt = uniq[win]
    x[:, tgt] = xi[:, tok[sel[win]]]
    slot_g[tgt] = gk[sel[win]]


def merge_reservoirs(parts: Iterable[Tuple[np.ndarray, np.ndarray]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic cross-shard reservoir merge: per slot, keep the row
    holding the largest global token index. Given per-shard states folded
    over disjoint token ranges, the merge equals the sequential fold of the
    whole stream (claims are pure functions of g — DESIGN.md §6)."""
    parts = list(parts)
    if not parts:
        raise ValueError("merge_reservoirs needs at least one shard state")
    x, g = parts[0][0].copy(), parts[0][1].copy()
    for xi, gi in parts[1:]:
        win = gi > g
        x[:, win] = xi[:, win]
        g[win] = gi[win]
    return x, g


class CalibrationStream:
    """Streaming per-layer activation reservoir + running expert counts.

    ``max_tokens_per_layer=None`` keeps every streamed token (the legacy
    ``collect`` behavior — unbounded); an integer cap bounds host memory.
    Beyond the cap, ``policy`` picks what survives:

    * ``"reservoir"`` (default) — Algorithm-R uniform sample over every
      streamed token (seeded, deterministic, shard-count invariant);
    * ``"head"`` — keep the FIRST cap tokens and drop the rest, exactly the
      legacy concatenate-then-truncate capture (counts keep accumulating
      over the whole stream either way).

    Tokens below the cap are kept in stream order under both policies, so
    with a cap ≥ the total token count the stream is bit-identical to the
    legacy capture.

    ``mesh`` (optional): run the capture forward data-parallel over the
    mesh's batch axes. Weights are REPLICATED for capture (the expert axis is
    reserved for the solve stage), each device computes its batch slice, and
    per-shard reservoirs merge through the fixed global-index schedule —
    bit-for-bit equal to the single-device capture (DESIGN.md §6).
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 max_tokens_per_layer: Optional[int] = None, seed: int = 0,
                 policy: str = "reservoir", mesh=None):
        if cfg.moe is None:
            raise ValueError("calibration capture requires an MoE model")
        if policy not in ("reservoir", "head"):
            raise ValueError(f"unknown calibration policy {policy!r}")
        self.cfg = cfg
        self.cap = max_tokens_per_layer
        self.policy = policy
        self.seed = seed
        self.mesh = mesh
        fn = lambda p, b: MD.forward(cfg, p, b, capture=True)[2]  # noqa: E731
        if mesh is None:
            self._fwd = jax.jit(fn)
            self._params = params
        else:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch import sharding as SH
            # weights replicated; captured buffers keep the batch axis
            # sharded so each host shard folds only its own token range. A
            # batch dim that does not divide the data axes cannot use the
            # explicit out_shardings (pjit rejects uneven dims) — that case
            # drops to inferred sharding, and the fold handles whatever
            # shard layout comes back (it is partition-agnostic).
            out_sh = tuple(NamedSharding(mesh, s)
                           for s in SH.capture_pspecs(mesh))
            self._fwd_sharded = jax.jit(fn, out_shardings=out_sh)
            self._fwd_inferred = jax.jit(fn)
            self._dp_size = int(np.prod(
                [mesh.shape[a] for a in SH.data_axes(mesh)] or [1]))
            self._params = jax.device_put(params, NamedSharding(mesh, P()))
        self._x: Optional[np.ndarray] = None      # [L, cap, d] reservoir rows
        self._slot_g: Optional[np.ndarray] = None  # [cap] global idx per slot
        # uncapped mode defers concatenation: chunks pile up here and are
        # joined once on first read (streaming B batches stays O(B), not
        # O(B^2) in host copies)
        self._chunks: List[np.ndarray] = []
        self._counts: Optional[np.ndarray] = None  # [L, N]
        self.tokens_seen = 0
        self.batches_seen = 0

    # ---- feeding ----------------------------------------------------------
    def update(self, batch: dict) -> None:
        """Run one capture forward and fold the batch into the reservoir."""
        if self.mesh is not None:
            from repro.launch import sharding as SH
            batch = jax.device_put(
                batch, SH.named(SH.calib_pspecs(batch, self.mesh), self.mesh))
            B0 = jax.tree.leaves(batch)[0].shape[0]
            fwd = (self._fwd_sharded if B0 % self._dp_size == 0
                   else self._fwd_inferred)
        else:
            fwd = self._fwd
        expert_inputs, cnts = fwd(self._params, batch)
        c = np.asarray(cnts, np.float32)                 # [L, N]
        self._counts = c if self._counts is None else self._counts + c
        L, B, S, d = expert_inputs.shape
        if self.cap is None:
            xi = np.asarray(expert_inputs, np.float32).reshape(L, B * S, d)
            self._chunks.append(xi)
        else:
            if self._x is None:
                self._x = np.zeros((L, self.cap, d), np.float32)
                self._slot_g = np.full(self.cap, -1, np.int64)
            if self.mesh is None:
                xi = np.asarray(expert_inputs, np.float32).reshape(L, B * S, d)
                g = self.tokens_seen + np.arange(B * S, dtype=np.int64)
                fold_tokens(self._x, self._slot_g, xi, g, cap=self.cap,
                            seed=self.seed, policy=self.policy)
            else:
                # fold each device shard's batch slice under its own global
                # token range — order across shards is irrelevant
                for b0, _, data in _batch_shards(expert_inputs):
                    xs = np.asarray(data, np.float32)
                    nb = xs.shape[1]
                    xs = xs.reshape(L, nb * S, d)
                    g = (self.tokens_seen + b0 * S
                         + np.arange(nb * S, dtype=np.int64))
                    fold_tokens(self._x, self._slot_g, xs, g, cap=self.cap,
                                seed=self.seed, policy=self.policy)
        self.tokens_seen += B * S
        self.batches_seen += 1

    def consume(self, batches: Iterable[dict]) -> "CalibrationStream":
        for b in batches:
            self.update(b)
        return self

    def reservoir_state(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows [L, cap, d], slot_g [cap]) — the mergeable shard state for
        cross-host reduction via :func:`merge_reservoirs`."""
        if self.cap is None or self._x is None:
            raise ValueError("reservoir_state requires a capped, fed stream")
        return self._x, self._slot_g

    def _materialize(self) -> np.ndarray:
        if self._chunks:
            parts = self._chunks
            self._chunks = [parts[0] if len(parts) == 1
                            else np.concatenate(parts, axis=1)]
            return self._chunks[0]
        if self._x is None:
            raise ValueError("CalibrationStream has seen no batches")
        held = int((self._slot_g >= 0).sum())
        # fill-phase claims are slot g == token g, so filled slots form a
        # contiguous prefix; a full reservoir returns the whole buffer
        return self._x if held == self.cap else self._x[:, :held]

    # ---- consuming --------------------------------------------------------
    @property
    def n_tokens(self) -> int:
        """Tokens currently held per layer (≤ cap)."""
        if self._chunks:
            return sum(c.shape[1] for c in self._chunks)
        if self._x is None:
            return 0
        return int((self._slot_g >= 0).sum())

    def layer(self, l: int) -> LayerCalibration:
        """Calibration view for ONE layer (the plan executor's access path)."""
        x = self._materialize()
        return LayerCalibration(x=x[l], counts=self._counts[l])

    def counts(self, l: int) -> np.ndarray:
        if self._counts is None:
            raise ValueError("CalibrationStream has seen no batches")
        return self._counts[l]

    def stats(self) -> Dict[int, np.ndarray]:
        """{layer: usage counts} — the budget planner's input."""
        if self._counts is None:
            return {}
        return {l: self._counts[l] for l in range(self._counts.shape[0])}

    def as_dict(self) -> Dict[int, LayerCalibration]:
        """Legacy ``collect``-shaped view (per-layer materialization)."""
        x = self._materialize()
        return {l: self.layer(l) for l in range(x.shape[0])}


def _batch_shards(arr) -> List[Tuple[int, int, object]]:
    """Deduplicated addressable shards of a captured ``[L, B, S, d]`` buffer,
    keyed and sorted by their batch-axis range. Replicated buffers (e.g. a
    batch dim that did not divide the mesh) collapse to one full-range entry,
    so no token is ever folded twice."""
    B = arr.shape[1]
    out = {}
    for sh in arr.addressable_shards:
        sl = sh.index[1]
        b0 = 0 if sl.start is None else int(sl.start)
        b1 = B if sl.stop is None else int(sl.stop)
        out.setdefault((b0, b1), sh.data)
    return [(b0, b1, out[(b0, b1)]) for (b0, b1) in sorted(out)]


def collect(cfg: ModelConfig, params: dict, batches: Iterable[dict],
            max_tokens_per_layer: int | None = None, seed: int = 0
            ) -> Dict[int, LayerCalibration]:
    """Returns {layer_index: LayerCalibration} for every MoE layer
    (compatibility wrapper over :class:`CalibrationStream`; ``policy='head'``
    reproduces the historical concatenate-then-truncate capture exactly)."""
    assert cfg.moe is not None, "calibration capture requires an MoE model"
    stream = CalibrationStream(cfg, params,
                               max_tokens_per_layer=max_tokens_per_layer,
                               seed=seed, policy="head")
    stream.consume(batches)
    return stream.as_dict()
