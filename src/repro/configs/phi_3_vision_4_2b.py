"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend STUB
[hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (kv=32, MHA) d_ff=8192 vocab=32064, head_dim=96.
The vision tower is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings [B, 64, d_model] prepended to the text tokens.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    vlm_num_patches=64,
    remat="full",
)
