"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936, head_dim=128.
This matches the paper's own Qwen3-30B-A3B evaluation target (Table 1):
128 -> 64 merged experts reproduces the paper's 30B -> 25B compression.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=8,
        d_ff_expert=768,
        n_shared_experts=0,
        capacity_factor=1.25,
        group_size=512,
    ),
    remat="full",
)
