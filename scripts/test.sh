#!/usr/bin/env bash
# Tier-1 verification entry point.
#
#   scripts/test.sh              # fast suite (slow-marked cases deselected)
#   scripts/test.sh -m slow      # only the slow smoke cases
#   scripts/test.sh --dist       # distributed-marked tests on a forced
#                                # 4-device CPU host platform
#   scripts/test.sh tests/test_kernels.py -k grouped
#
# Extra arguments are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--dist" ]]; then
  shift
  # REPRO_DIST=1 tells conftest the forced device count is intentional
  export REPRO_DIST=1
  export JAX_PLATFORMS=cpu
  export XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}"
  exec python -m pytest -x -q -m distributed "$@"
fi
exec python -m pytest -x -q "$@"
