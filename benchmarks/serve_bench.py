"""Continuous-batching throughput: uncompressed vs MergeMoE (M = N/2).

Serves an identical Poisson-ish request trace through the continuous-batching
engine twice — once with the original checkpoint, once with the same weights
MergeMoE-compressed to half the experts (router + remap unchanged math,
merged expert tables) — and reports tokens/sec plus per-request latency.
Both runs decode through the ragged dispatch path, so on TPU the comparison
is grouped-kernel vs grouped-kernel with fewer, fuller expert groups; on CPU
(this container) the jnp oracle stands in at identical shapes.

    PYTHONPATH=src python benchmarks/serve_bench.py --requests 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.core import compress as CMP
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace


def run_trace(cfg, params, *, label, requests, prompt_lens, arrivals,
              max_new_tokens, n_slots, s_max, buckets, repeats=3):
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets), cfg=cfg, params=params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(l), dtype=np.int32)
               for l in prompt_lens]

    # warmup: compile the decode step and every prefill bucket specialization
    # on throwaway requests before the timed trace
    eng.submit(prompts[0], max_new_tokens=2)
    for l in sorted(set(eng.bucket_for(len(p)) for p in prompts)):
        eng.submit(np.zeros(min(l, s_max - 4), np.int32), max_new_tokens=1)
    eng.run()

    # trace tok/s is host-loop noisy at smoke scale -> best of ``repeats``
    best_dt, done = None, None
    for _ in range(repeats):
        # shift arrivals past the current step clock so the trace stays
        # staggered and latency = finish - arrival holds without an offset
        base = float(eng.steps)
        for i in range(requests):
            eng.submit(prompts[i], max_new_tokens=max_new_tokens,
                       arrival_time=base + float(arrivals[i]))
        t0 = time.perf_counter()
        d = eng.run()
        dt = time.perf_counter() - t0
        if best_dt is None or dt < best_dt:
            best_dt, done = dt, d

    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_finished - r.arrival_time for r in done]
    steady = eng.bench_decode(iters=50)
    rec = {
        "label": label,
        "experts": (cfg.moe_merged or cfg.moe.n_experts
                    ) if cfg.moe else 0,
        "dispatch": cfg.moe.dispatch if cfg.moe else "dense-mlp",
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(best_dt, 3),
        "tok_per_s": round(toks / best_dt, 1),
        "steady_decode_tok_per_s": round(steady, 1),
        "mean_latency_steps": round(float(np.mean(lat)), 2),
        "p95_latency_steps": round(float(np.percentile(lat, 95)), 2),
    }
    print(f"[{label:>12}] {rec['tok_per_s']:8.1f} tok/s trace  "
          f"{rec['steady_decode_tok_per_s']:8.1f} tok/s steady-decode  "
          f"({rec['tokens']} tokens, {rec['experts']} experts, "
          f"mean latency {rec['mean_latency_steps']} steps)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    M = cfg.moe.n_experts // 2
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=M, split=0,
        batches=calib)

    rng = np.random.default_rng(args.seed + 1)
    lens = rng.choice([8, 16, 24, 32], size=args.requests)
    lens = np.minimum(lens, args.s_max - args.max_new_tokens - 1)
    arrivals = poisson_trace(args.requests, rate=args.rate,
                             seed=args.seed + 2)
    buckets = (8, 16, 24, 32)
    common = dict(requests=args.requests, prompt_lens=lens, arrivals=arrivals,
                  max_new_tokens=args.max_new_tokens, n_slots=args.n_slots,
                  s_max=args.s_max, buckets=buckets)

    print(f"== serve_bench: {args.requests} requests, Poisson rate "
          f"{args.rate}/step, {args.n_slots} slots ==")
    full = run_trace(cfg, params, label="uncompressed", **common)
    comp = run_trace(ncfg, nparams, label=f"mergemoe-M{M}", **common)
    summary = {
        "full": full, "compressed": comp,
        "compression_ratio": round(info["compression_ratio"], 3),
        "speedup_trace": round(comp["tok_per_s"] / full["tok_per_s"], 3),
        "speedup_steady": round(comp["steady_decode_tok_per_s"]
                                / full["steady_decode_tok_per_s"], 3),
    }
    print(f"== trace speedup {summary['speedup_trace']}x, steady-decode "
          f"speedup {summary['speedup_steady']}x at "
          f"{summary['compression_ratio']}x fewer expert bytes ==\n"
          f"   (CPU runs the jnp oracle at identical shapes — the "
          f"fewer-fuller-blocks win is a TPU grouped-kernel effect)")
    if args.json:
        print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
