"""Flash attention Pallas kernel (TPU target, interpret-validated on CPU).

Online-softmax blocked attention: grid (B*H, Sq/bq, Skv/bk); running max /
normalizer / fp32 output accumulator live in VMEM scratch, so the [S, S]
logits matrix never touches HBM — this is the kernel that collapses the
"sdpa" HBM-traffic term in the roofline (see hlo_analysis.sdpa_flash_bytes).

Causal masking is block-aware: fully-masked kv blocks are skipped.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0]                                      # [bq, hd]
        k = k_ref[0]                                      # [bk, hd]
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=F32) * scale
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=F32))
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above the diagonal
        pl.when(jk * bk <= iq * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(jk == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q/k/v: [B, H, S, hd] (GQA pre-expanded by the caller) -> [B, H, S, hd]."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    bq = _block(Sq, block_q)
    bk = _block(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk

    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Sk, hd)
    vf = v.reshape(B * H, Sk, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),      # running max
            pltpu.VMEM((bq,), F32),      # normalizer
            pltpu.VMEM((bq, hd), F32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
