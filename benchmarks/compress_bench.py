"""Uniform vs budget-planned per-layer compression at MATCHED ratios, plus
single- vs multi-device compression wall-time.

For each uniform budget M in the sweep, the budget planner is asked to hit
the same live-byte compression ratio but may spread the expert budget
unevenly across the suffix layers (squeezing low-routing-entropy layers
harder, per the calibration stats). Both plans execute against the SAME
calibration stream and the same held-out eval batches; the report seeds the
perf trajectory for per-layer allocation:

    PYTHONPATH=src python benchmarks/compress_bench.py --layers 4

Writes ``BENCH_compress.json``: per matched ratio, the loss delta, live /
padded bytes, and merge wall-time of each strategy. (At smoke scale a
random-init model routes near-uniformly, so the planner may legitimately
reproduce the uniform allocation; on trained checkpoints with skewed routing
the per-layer budgets diverge — ``test_planner_respects_importance_stats``
pins that behavior.)

The wall-time section re-runs one uniform compression in two fresh worker
subprocesses — default single device, and a forced 4-device host platform
with ``mesh data=2,model=2`` (DP capture + 2 solve shards, DESIGN.md §6) —
and records both timings plus whether the outputs matched bit for bit.
Workers are subprocesses because the forced device count must be set before
JAX initializes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.core import calibration as CAL
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.launch.compress import eval_loss, make_batches
from repro.models import model as MD


def _record(cfg, params, plan, stream, evalb, base_loss, label):
    ncfg, nparams, info = CMP.compress_with_plan(cfg, params, plan,
                                                 stream=stream)
    loss = eval_loss(ncfg, nparams, evalb)
    rec = {
        "label": label,
        "merged_per_layer": list(plan.merged_per_layer),
        "compression_ratio": round(info["compression_ratio"], 4),
        "bytes_compressed": info["bytes_compressed"],
        "bytes_padded": info["bytes_padded"],
        "t_merge_s": round(info["t_merge_s"], 3),
        "loss": round(loss, 4),
        "loss_delta": round(loss - base_loss, 4),
    }
    print(f"  [{label:>8}] M={rec['merged_per_layer']} "
          f"ratio={rec['compression_ratio']:.3f} "
          f"Δloss={rec['loss_delta']:+.4f} merge={rec['t_merge_s']}s")
    return rec


# ---------------------------------------------------------------------------
# single- vs multi-device wall time (worker subprocess per device count)
# ---------------------------------------------------------------------------

_WALLTIME_MESH = "data=2,model=2"


def _worker(args) -> None:
    """One timed uniform compression; JSON record on stdout. The parent
    controls the device count via XLA_FLAGS in this process's environment."""
    mesh = None
    if args.worker_mesh != "none":
        from repro.launch import mesh as MESH
        mesh = MESH.make_compression_mesh(args.worker_mesh)
    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers)
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))
    calib = make_batches(cfg, args.calib_batches, batch=8,
                         seed=args.seed + 100)
    plan = PLAN.uniform(cfg, merged_experts=min(args.uniform_m),
                        split=args.split)
    t0 = time.perf_counter()
    _, nparams, info = CMP.compress_with_plan(
        cfg, params, plan, batches=calib, max_tokens=256, mesh=mesh)
    t_total = time.perf_counter() - t0
    from repro.ckpt.checkpoint import tree_digest
    print(json.dumps({
        "devices": jax.device_count(),
        "mesh": info["mesh"] and info["mesh"]["axes"],
        "solve_shards": (info["mesh"] or {}).get("solve_shards", 1),
        "t_calibrate_s": round(info["t_calibrate_s"], 3),
        "t_merge_s": round(info["t_merge_s"], 3),
        "t_total_s": round(t_total, 3),
        "digest": tree_digest(nparams["stack_c"]["moe"]),
    }))


def measure_wall_time(args) -> dict:
    """Spawn one worker on the default single device and one on a forced
    4-device host platform; return both records + the bitwise verdict."""
    recs = {}
    for label, devices, mesh in (("single_device", 1, "none"),
                                 ("mesh_4dev", 4, _WALLTIME_MESH)):
        env = {**os.environ, "JAX_PLATFORMS": "cpu"}
        env.pop("XLA_FLAGS", None)
        if devices > 1:
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={devices}"
        cmd = [sys.executable, str(Path(__file__).resolve()),
               "--worker-mesh", mesh, "--arch", args.arch,
               "--layers", str(args.layers), "--split", str(args.split),
               "--calib-batches", str(args.calib_batches),
               "--seed", str(args.seed),
               "--uniform-m"] + [str(m) for m in args.uniform_m]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"wall-time worker failed:\n{r.stderr}")
        recs[label] = json.loads(r.stdout)
        print(f"  [{label:>13}] calib={recs[label]['t_calibrate_s']}s "
              f"merge={recs[label]['t_merge_s']}s "
              f"total={recs[label]['t_total_s']}s")
    recs["mesh_spec"] = _WALLTIME_MESH
    recs["bitwise_match"] = (recs["single_device"]["digest"]
                             == recs["mesh_4dev"]["digest"])
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--layers", type=int, default=4,
                    help="stack depth (reduced config is rebuilt at this "
                         "depth so per-layer allocation has room to differ)")
    ap.add_argument("--split", type=int, default=1)
    ap.add_argument("--uniform-m", type=int, nargs="+", default=[6, 4, 2])
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-wall-time", action="store_true",
                    help="skip the single- vs multi-device timing section")
    ap.add_argument("--worker-mesh", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--out", default=str(Path(__file__).with_name(
        "BENCH_compress.json")))
    args = ap.parse_args()

    if args.worker_mesh is not None:
        _worker(args)
        return

    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers)
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))
    calib = make_batches(cfg, args.calib_batches, seed=args.seed + 100)
    evalb = make_batches(cfg, args.eval_batches, seed=args.seed + 200)

    stream = CAL.CalibrationStream(cfg, params, seed=args.seed).consume(calib)
    base_loss = eval_loss(cfg, params, evalb)
    print(f"== compress_bench: {cfg.name} L={args.layers} "
          f"split={args.split} base loss {base_loss:.4f} ==")

    rows = []
    for m in args.uniform_m:
        uni = PLAN.uniform(cfg, merged_experts=m, split=args.split)
        # matched live-byte target under the planner's own byte model
        target = PLAN.plan_live_ratio(cfg, uni)
        print(f"-- matched ratio {target:.3f} (uniform M={m}) --")
        u = _record(cfg, params, uni, stream, evalb, base_loss, "uniform")
        planned = PLAN.for_target_ratio(cfg, target_ratio=target,
                                        stats=stream.stats(),
                                        split=args.split)
        p = _record(cfg, params, planned, stream, evalb, base_loss, "planned")
        rows.append({"uniform_m": m, "target_ratio": round(target, 4),
                     "uniform": u, "planned": p})

    out = {
        "arch": args.arch, "n_layers": args.layers, "split": args.split,
        "n_experts": cfg.moe.n_experts,
        "calib_tokens": stream.n_tokens,
        "loss_full": round(base_loss, 4),
        "sweep": rows,
    }
    if not args.skip_wall_time:
        print("-- wall time: single device vs 4-device mesh "
              f"({_WALLTIME_MESH}) --")
        out["wall_time"] = measure_wall_time(args)
    Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
