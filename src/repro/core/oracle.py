"""The "w/o merging errors" oracle (paper Table 5).

Keeps ALL original experts and merges their OUTPUTS exactly: per token the
routing weight of original expert j becomes
    u_j = B_{j, c(j)} * sum of top-k weights landing in cluster c(j),
so the layer output equals  Y · B · A · mask_top_K(softmax(W_r X))ᵀ  with zero
T1/T2/T3 approximation error. Memory is NOT reduced — this is the upper bound
that isolates clustering error from merging error.

Implemented with dense all-expert evaluation; use on reduced/eval models only.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as MoE
from repro.models import model as MD
from repro.models.numerics import ein

F32 = jnp.float32


def oracle_moe_apply(cfg: ModelConfig, p: dict, x, assign, bweights):
    """assign: [N] int32 cluster ids; bweights: [N] fp32 B entries."""
    m = cfg.moe
    B_, S, d = x.shape
    w, idx, probs = MoE.route(cfg, p, x)                 # [.., k]
    # cluster weight sums s_c per token
    cl = jnp.take(jnp.asarray(assign), idx)              # [.., k] cluster of picks
    M = int(np.max(np.asarray(assign))) + 1
    onehot = jax.nn.one_hot(cl, M, dtype=F32)            # [.., k, M]
    s_c = jnp.einsum("...km,...k->...m", onehot, w)      # [.., M]
    # expand to per-original-expert weight u_j = B_j * s_{c(j)}
    u = jnp.take(s_c, jnp.asarray(assign), axis=-1) * jnp.asarray(bweights)
    # dense all-expert evaluation
    g = ein("bsd,edf->bsef", x, p["wg"])
    uu = ein("bsd,edf->bsef", x, p["wu"])
    h = (jax.nn.silu(g) * uu).astype(x.dtype)
    ye = ein("bsef,efd->bsed", h, p["wd"])
    y = jnp.einsum("bsed,bse->bsd", ye.astype(F32), u.astype(F32)).astype(x.dtype)
    if m.n_shared_experts:
        y = y + L.mlp_apply(p["shared"], x)
    return y


def oracle_forward(cfg: ModelConfig, params: dict, batch: dict,
                   assigns: Dict[int, np.ndarray],
                   bweights: Dict[int, np.ndarray]):
    """Full-model forward where layers in ``assigns`` use exact output
    merging. Runs the stack unscanned (eval-scale models only)."""
    inv_freq = L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], batch["tokens"])
    stack = params["stack"]
    n_layers = jax.tree.leaves(stack)[0].shape[0]
    for i in range(n_layers):
        lp = jax.tree.map(lambda a: a[i], stack)
        h = x + L.attn_apply(cfg, lp["attn"],
                             L.rmsnorm(lp["ln1"], x, cfg.norm_eps),
                             inv_freq=inv_freq)
        hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if i in assigns:
            y = oracle_moe_apply(cfg, lp["moe"], hn, assigns[i], bweights[i])
        else:
            y = MoE.moe_apply(cfg, lp["moe"], hn).y
        x = h + y
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    return L.lm_head(cfg, params["embed"], x)
