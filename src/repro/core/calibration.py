"""Calibration-activation capture (JAX replacement for the paper's Torch
hooks, App. B).

``collect(cfg, params, batches)`` runs the ORIGINAL model with
``capture=True`` and returns, per MoE layer, the expert-input activations X̂
and the expert usage counts f. Because JAX forwards are pure, a single-shot
capture is exactly equivalent to the paper's back-to-front layer traversal
(merging layer ℓ never perturbs activations at layers ≤ ℓ) — see DESIGN.md §3.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.models import model as MD


@dataclass
class LayerCalibration:
    x: np.ndarray        # [T, d] expert-layer inputs (tokens pooled)
    counts: np.ndarray   # [N] usage frequencies


def collect(cfg: ModelConfig, params: dict, batches: Iterable[dict],
            max_tokens_per_layer: int | None = None
            ) -> Dict[int, LayerCalibration]:
    """Returns {layer_index: LayerCalibration} for every MoE layer."""
    assert cfg.moe is not None, "calibration capture requires an MoE model"
    fwd = jax.jit(lambda p, b: MD.forward(cfg, p, b, capture=True)[2])

    xs: List[np.ndarray] = []
    counts: np.ndarray | None = None
    for batch in batches:
        cap = fwd(params, batch)
        expert_inputs, cnts = cap                     # [L,B,S,d], [L,N]
        xi = np.asarray(expert_inputs, np.float32)
        L = xi.shape[0]
        xs.append(xi.reshape(L, -1, xi.shape[-1]))    # [L, B*S, d]
        c = np.asarray(cnts, np.float32)
        counts = c if counts is None else counts + c

    x_all = np.concatenate(xs, axis=1)                # [L, T, d]
    if max_tokens_per_layer is not None:
        x_all = x_all[:, :max_tokens_per_layer]
    return {
        l: LayerCalibration(x=x_all[l], counts=counts[l])
        for l in range(x_all.shape[0])
    }
