"""Step functions: train_step / serve_prefill / serve_step builders.

These close over the ModelConfig and Optimizer, take pure pytrees, and are
what ``launch.train`` / ``launch.serve`` / ``launch.dryrun`` jit with explicit
in/out shardings.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as MD
from repro.optim import Optimizer, apply_updates

F32 = jnp.float32


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    microbatches: int = 1,
                    grad_transform: Optional[Callable] = None,
                    grad_dtype: str = "bfloat16") -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, loss, metrics).

    microbatches > 1 = gradient accumulation via lax.scan (collectives fire
    once per step instead of once per microbatch).
    grad_transform: optional hook applied to the averaged grads (e.g. the
    int8 error-feedback compressor from repro.distributed.compression).
    grad_dtype: dtype of the gradients as they cross the data-parallel
    all-reduce. bf16 halves the dW collective volume (§Perf iteration A6);
    the optimizer still accumulates fp32 states. Set "float32" to disable.
    """
    gdt = jnp.dtype(grad_dtype)

    def _cast_grads(grads, params):
        if gdt == jnp.float32:
            return grads
        casted = jax.tree.map(
            lambda g, p: g if (g.dtype == jax.dtypes.float0
                               or not jnp.issubdtype(p.dtype, jnp.floating))
            else g.astype(gdt), grads, params)
        # the barrier stops XLA's excess-precision pass from cancelling the
        # bf16 downcast against the optimizer's fp32 upcast (which would
        # silently put the DP grad all-reduce back at fp32 width)
        leaves, tdef = jax.tree_util.tree_flatten(casted)
        leaves = list(jax.lax.optimization_barrier(tuple(leaves)))
        return jax.tree_util.tree_unflatten(tdef, leaves)

    def loss_fn(params, batch):
        return MD.loss(cfg, params, batch)

    def train_step(params, opt_state, batch, step):
        if microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]), batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True, allow_int=True)(params, mbatch)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (grads, l_sum), ms = jax.lax.scan(acc, (g0, jnp.zeros((), F32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            l = l_sum / microbatches
            metrics = jax.tree.map(lambda a: jnp.mean(a), ms)
        grads = _cast_grads(grads, params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, l, metrics

    return train_step


def make_serve_prefill(cfg: ModelConfig, s_max: Optional[int] = None) -> Callable:
    def serve_prefill(params, batch):
        return MD.prefill(cfg, params, batch, s_max=s_max)
    return serve_prefill


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, token):
        return MD.decode_step(cfg, params, cache, token)
    return serve_step


# ---------------------------------------------------------------------------
# continuous-batching (slotted) serving
# ---------------------------------------------------------------------------

def admit_pad_shapes(buckets, s_max: int) -> Tuple[int, ...]:
    """The ONLY prompt pad lengths admission may compile, ascending.

    Single source of truth for the padding policy: the declared buckets
    clamped to ``s_max`` plus the big-bucket multiples used for overflow
    prompts (also clamped). ``Engine.bucket_for`` maps a length to the
    smallest member covering it and FAILS CLOSED on non-membership, and
    :func:`admit_trace_budget` counts this same set — so the shape table the
    engine pads to and the trace budget the guard enforces can never drift
    apart. The largest member is always ``s_max``, so every admissible
    prompt (``len <= s_max``) has a pad shape."""
    declared = sorted({min(int(b), int(s_max)) for b in buckets}) or [1]
    big = declared[-1]
    shapes = set(declared)
    m = 1
    while m * big < s_max:
        m += 1
        shapes.add(min(m * big, s_max))
    return tuple(sorted(shapes))


def admit_trace_budget(buckets, s_max: int, n_slots: int) -> int:
    """Upper bound on legitimate jit specializations of ``slot_admit``.

    The engine pads every admission group to (pad shape, pow2 group size);
    pad shapes come from :func:`admit_pad_shapes` (the same table
    ``Engine.bucket_for`` draws from), and group sizes are the powers of two
    up to the next pow2 >= ``n_slots``. Anything beyond this product is a
    RETRACE — some shape leaked past the padding policy (the trace guard
    counts it)."""
    shapes = admit_pad_shapes(buckets, s_max)
    sizes, p = 1, 1
    while p < n_slots:
        p *= 2
        sizes += 1
    return len(shapes) * sizes


def make_slot_decode(cfg: ModelConfig) -> Callable:
    """slot_decode(params, cache, token [B], active [B], poison [B] bool) ->
    (logits [B, V], aux [B, 2] int32, cache) with ``aux[b] = (greedy,
    finite)``. The greedy argmax AND the numeric-health flag (all-logits-
    finite per slot, DESIGN.md §12) are computed on-device and packed into
    one array, so a temperature-0 engine still does exactly one readback
    per step. ``poison`` is the fault-injection mask (serving.faults):
    True rows get their logits NaN-poisoned AFTER the forward — an
    all-False mask is a bitwise no-op (``where`` selects the untouched
    logits), so fault-free traces are unchanged."""
    def slot_decode(params, cache, token, active, poison):
        logits, cache = MD.decode_step_slots(cfg, params, cache, token, active)
        logits = jnp.where(poison[:, None], jnp.nan, logits)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        finite = jnp.all(jnp.isfinite(logits), axis=-1).astype(jnp.int32)
        return logits, jnp.stack([greedy, finite], axis=-1), cache
    return slot_decode


def make_slot_admit(cfg: ModelConfig) -> Callable:
    """Fused admission: prefill + slot insert + first-token argmax in ONE
    jitted call (one dispatch per admission group instead of three).

    slot_admit(params, cache, tokens [B, S_bucket], lengths [B], slots [B])
    -> (logits [B, V], greedy [B] int32, cache). Rows may be padding (the
    engine pads groups to a power of two to bound jit specializations):
    their ``slots`` entry is set OUT OF BOUNDS (>= n_slots), and JAX's
    default scatter semantics DROP out-of-bounds updates, so pad rows'
    garbage KV and lengths never land in the cache — the engine just ignores
    their logits rows."""
    def slot_admit(params, cache, tokens, lengths, slots):
        logits, k_new, v_new = MD.prefill_slots(cfg, params, tokens, lengths)
        cache = MD.insert_slots(cache, slots, k_new, v_new, lengths)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, greedy, cache
    return slot_admit


def make_slot_admit_paged(cfg: ModelConfig) -> Callable:
    """Fused admission into the PAGED KV pool (DESIGN.md §11).

    slot_admit_paged(params, cache, tokens [B, S_bucket], lengths [B],
    slots [B], pos0 [B]) -> (logits [B, V], greedy [B] int32, cache).

    ``tokens`` holds each request's SUFFIX (prompt minus any shared-prefix
    rows) padded to a bucket length; ``pos0`` is the per-row shared prefix
    length in rows (all zero without sharing). Pad rows carry
    ``slots >= n_slots``, which indexes the sentinel block-table row — their
    KV scatters and ``pos`` writes all drop, the ``make_slot_admit``
    contract carried over to the paged layout. With ``pos0 = 0`` the logits
    and pool rows written are bitwise the dense prefill+insert admission's
    (bf16 pools)."""
    def slot_admit_paged(params, cache, tokens, lengths, slots, pos0):
        logits, cache = MD.admit_slots_paged(cfg, params, cache, tokens,
                                             lengths, slots, pos0)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, greedy, cache
    return slot_admit_paged


def sample_tokens(logits: jax.Array, temperature: float, keys: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Sample one token per row of ``logits`` [B, V] -> [B] int32.

    ``temperature <= 0``: greedy argmax (``keys``/``positions`` unused).
    ``temperature > 0``: Gumbel-max with a POSITION-INDEXED key schedule —
    the noise added to row ``b``'s logits is
    ``gumbel(fold_in(keys[b], positions[b]))`` where ``positions[b]`` is
    the sequence position the sampled token will OCCUPY in slot ``b``'s
    cache. The noise therefore depends only on (sampling key, token
    position), never on which program computes it or how the engine
    scheduled the request. Both sampling contracts hang off that one
    property (DESIGN.md §10):

    * device == host: the fused decode loops and the engine's host-side
      fallback (``Engine._sample``) run this same function on the same
      (key, position) pairs, so they agree bitwise;
    * draft == verify (speculative decoding): the draft model proposing
      the token at position ``q`` and the full model verifying position
      ``q`` add IDENTICAL noise to their own logits, so a draft proposal
      is accepted exactly when the full model would have sampled the same
      token — accepted tokens are bitwise the full model's samples.

    keys: [B, 2] uint32 per-slot PRNG keys (the engine derives them from
    the request uid, so they travel with the request across slots and
    engine modes); positions: [B] int32.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    vocab = logits.shape[-1]

    def noise(key, q):
        return jax.random.gumbel(jax.random.fold_in(key, q), (vocab,), F32)

    g = jax.vmap(noise)(keys, positions)
    return jnp.argmax(logits.astype(F32) / temperature + g,
                      axis=-1).astype(jnp.int32)


def make_slot_decode_multi(cfg: ModelConfig, k_steps: int,
                           temperature: float = 0.0) -> Callable:
    """Fused K-step decode: the device, not Python, drives steady-state
    decode (DESIGN.md §7).

    slot_decode_multi(params, cache, token [B], active [B], remaining [B],
    eos [B], keys [B, 2], poison [B] bool) -> (block [K, B, 3] int32,
    active [B] bool, cache), where ``block[s, b] = (token, emitted,
    finite)`` — tokens, their emitted flags, and the numeric-health
    sentinel lane (all-logits-finite per step and slot, DESIGN.md §12) are
    PACKED into one array so the engine's per-block device->host readback
    is a single transfer; the sentinel costs ZERO additional host syncs.
    ``poison`` is the fault-injection mask (``serving.faults``): True rows
    get their logits NaN-poisoned after each scanned forward. An all-False
    mask is a bitwise no-op, so fault-free decode is unchanged.

    ``lax.scan`` runs ``k_steps`` decode steps inside ONE jitted call:
    sampling (:func:`sample_tokens` — greedy argmax, or Gumbel-max at
    ``temperature`` > 0 under the position-indexed key schedule) happens
    on device, and per-slot stop flags freeze finished slots in place — a
    slot whose sampled token hits its ``eos`` entry (-1 = none) or
    exhausts ``remaining`` stops advancing ``pos`` and stops emitting, but
    rides along in the batch (static shapes). ``emitted[s, b]`` marks
    which of the K tokens are real; the host replays only those. When
    every slot is frozen the remaining scan tail skips the forward
    entirely (``lax.cond``), so an early-finishing block costs control
    flow, not FLOPs. Host syncs drop from one per token to one per K
    tokens."""
    def slot_decode_multi(params, cache, token, active, remaining, eos, keys,
                          poison):
        def step(carry):
            cache, tok, act, rem = carry
            logits, cache = MD.decode_step_slots(cfg, params, cache, tok, act)
            logits = jnp.where(poison[:, None], jnp.nan, logits)
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            # cache["pos"] already advanced for active slots = the position
            # the sampled token will occupy (frozen rows sample garbage
            # that is never emitted)
            nxt = sample_tokens(logits, temperature, keys, cache["pos"])
            emitted = act
            rem = rem - act.astype(jnp.int32)
            done = (nxt == eos) | (rem <= 0)
            act = act & ~done
            tok = jnp.where(emitted, nxt, tok)
            return (cache, tok, act, rem), (nxt, emitted, finite)

        def body(carry, _):
            cache, tok, act, rem = carry
            return jax.lax.cond(
                jnp.any(act),
                lambda c: step(c),
                # skipped tail steps emit nothing; their sentinel lane
                # reports healthy (no forward ran, nothing to flag)
                lambda c: (c, (c[1], jnp.zeros_like(c[2]),
                               jnp.ones_like(c[2]))),
                (cache, tok, act, rem))

        (cache, tok, act, rem), (toks, emits, fins) = jax.lax.scan(
            body, (cache, token, active, remaining), None, length=k_steps)
        block = jnp.stack([toks, emits.astype(jnp.int32),
                           fins.astype(jnp.int32)], axis=-1)
        return block, act, cache
    return slot_decode_multi


# ---------------------------------------------------------------------------
# self-speculative decoding (draft = MergeMoE-compressed, verify = full)
# ---------------------------------------------------------------------------

def make_slot_decode_spec(cfg: ModelConfig, draft_cfg: ModelConfig,
                          k_draft: int, temperature: float = 0.0) -> Callable:
    """One fused draft/verify round (DESIGN.md §10): the compressed model
    proposes ``k_draft`` tokens per slot, the full model scores every
    proposal in ONE multi-position forward, and accept/rollback happens on
    device. Built in ``repro.serving.spec`` (the import is lazy so the
    serving package can keep importing ``launch.steps``)."""
    from repro.serving.spec import build_slot_decode_spec
    return build_slot_decode_spec(cfg, draft_cfg, k_draft, temperature)


def make_slot_admit_spec(cfg: ModelConfig, draft_cfg: ModelConfig,
                         temperature: float = 0.0) -> Callable:
    """Fused dual-model admission for speculative serving: both prefills +
    both slot inserts + the full model's first token in one jitted call."""
    from repro.serving.spec import build_slot_admit_spec
    return build_slot_admit_spec(cfg, draft_cfg, temperature)


def make_slot_admit_spec_paged(cfg: ModelConfig, draft_cfg: ModelConfig,
                               temperature: float = 0.0) -> Callable:
    """Paged-pool sibling of :func:`make_slot_admit_spec`: both models admit
    the same suffix group into their own block pools (one shared table)."""
    from repro.serving.spec import build_slot_admit_spec_paged
    return build_slot_admit_spec_paged(cfg, draft_cfg, temperature)


# ---------------------------------------------------------------------------
# expert-parallel mesh serving (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The ``*_mesh`` builders wrap the single-device slot programs above in a
# ``shard_map`` over the engine mesh: expert tables partitioned on "model"
# (the MoE layers switch to the all-to-all pair-exchange dispatch of
# ``models/moe_ep.py``), slots + KV partitioned on "data" so attention never
# crosses the wire. Per-slot vectors arrive sharded; admission groups arrive
# replicated and localize their slot ids in-program; the paged block table
# is host-written in GLOBAL block ids and localized in-program on the way in
# (never written by the device, so the wrappers hand the original back out).


def ep_serve_cfg(cfg: ModelConfig, mesh,
                 combine_wire_dtype: str = "fp32") -> ModelConfig:
    """Config view for traces INSIDE the decode shard_map: bakes the EP
    degree/axis (and combine wire dtype) into ``cfg.moe`` so the lazily
    traced model functions pick the EP dispatch without any global state.
    Identity for dense models and 1-wide "model" axes."""
    if cfg.moe is None:
        return cfg
    ep = int(mesh.shape.get("model", 1))
    if ep <= 1:
        return cfg
    import dataclasses
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, ep_axis="model", ep_degree=ep,
        combine_wire_dtype=combine_wire_dtype))


def _localize_slots(slots: jax.Array, n_local: int):
    """Global slot ids -> this data shard's local ids. Foreign (and pad)
    rows map to ``n_local`` — out of bounds for the local cache, so their
    scatters drop; as a paged-table row index it is the local sentinel row.
    Returns (local_slots, mine_mask)."""
    d0 = jax.lax.axis_index("data") * n_local
    mine = (slots >= d0) & (slots < d0 + n_local)
    return jnp.where(mine, slots - d0, n_local).astype(slots.dtype), mine


def _localize_paged_tab(cache: dict, dp: int):
    """Global block table -> this data shard's local view.

    The allocator partitions blocks so a shard's slots reference ONLY its
    own block range ``[di*nb_l, (di+1)*nb_l)`` (serving.paging, n_shards);
    entries rebase to local ids, the global sentinel (>= nb_global) maps to
    the local one (nb_l), and a local sentinel row is appended for foreign/
    pad slot ids. Dense caches pass through. Returns (cache, original_tab —
    None when nothing was localized)."""
    if "kp" not in cache:
        return cache, None
    tab = cache["tab"]                              # [n_slots + 1, mb]
    nb_l = cache["kp"].shape[1]
    n_local = cache["pos"].shape[0]
    di = jax.lax.axis_index("data")
    rows = jax.lax.dynamic_slice_in_dim(tab, di * n_local, n_local, axis=0)
    loc = jnp.where(rows >= nb_l * dp, nb_l, rows - di * nb_l)
    loc = jnp.concatenate(
        [loc, jnp.full((1, tab.shape[1]), nb_l, tab.dtype)], axis=0)
    return dict(cache, tab=loc.astype(tab.dtype)), tab


def _restore_tab(cache: dict, tab0):
    return cache if tab0 is None else dict(cache, tab=tab0)


def _mesh_specs(mesh, params, cache):
    from jax.sharding import PartitionSpec as P
    from repro.launch import sharding as SH
    return (SH.serve_param_pspecs(params, mesh),
            SH.slot_cache_pspecs(cache, mesh),
            P("data"), P())


def make_slot_decode_mesh(cfg: ModelConfig, mesh, params, cache,
                          combine_wire_dtype: str = "fp32") -> Callable:
    """Mesh form of :func:`make_slot_decode` — same signature and contract,
    args per-slot-sharded over "data" (``params``/``cache`` are template
    trees used only for spec derivation)."""
    from jax.experimental.shard_map import shard_map
    cfg_l = ep_serve_cfg(cfg, mesh, combine_wire_dtype)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    dp = int(mesh.shape.get("data", 1))

    def fn(params, cache, token, active, poison):
        cache, tab0 = _localize_paged_tab(cache, dp)
        logits, cache = MD.decode_step_slots(cfg_l, params, cache, token,
                                             active)
        logits = jnp.where(poison[:, None], jnp.nan, logits)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        finite = jnp.all(jnp.isfinite(logits), axis=-1).astype(jnp.int32)
        return logits, jnp.stack([greedy, finite], axis=-1), \
            _restore_tab(cache, tab0)

    return shard_map(fn, mesh=mesh, in_specs=(pspec, cspec, v, v, v),
                     out_specs=(v, v, cspec), check_rep=False)


def make_slot_decode_multi_mesh(cfg: ModelConfig, k_steps: int,
                                temperature: float, mesh, params, cache,
                                combine_wire_dtype: str = "fp32") -> Callable:
    """Mesh form of :func:`make_slot_decode_multi`. The scan's early-exit
    ``lax.cond`` predicate is data-row-consistent (``active`` is sharded on
    "data", replicated across "model"), and every EP collective runs on the
    "model" axis only — so all members of a collective group always take
    the same branch."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    cfg_l = ep_serve_cfg(cfg, mesh, combine_wire_dtype)
    inner = make_slot_decode_multi(cfg_l, k_steps, temperature)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    dp = int(mesh.shape.get("data", 1))

    def fn(params, cache, token, active, remaining, eos, keys, poison):
        cache, tab0 = _localize_paged_tab(cache, dp)
        block, act, cache = inner(params, cache, token, active, remaining,
                                  eos, keys, poison)
        return block, act, _restore_tab(cache, tab0)

    return shard_map(fn, mesh=mesh,
                     in_specs=(pspec, cspec, v, v, v, v, v, v),
                     out_specs=(P(None, "data"), v, cspec), check_rep=False)


def make_slot_admit_mesh(cfg: ModelConfig, mesh, params, cache) -> Callable:
    """Mesh form of :func:`make_slot_admit`: the group's tokens arrive
    REPLICATED (every shard runs the same prefill — the forward reads
    nothing from the cache, so its logits are exact everywhere, EP splitting
    the MoE work across "model"); only the KV/pos scatters are localized so
    each data shard keeps its own slots."""
    from jax.experimental.shard_map import shard_map
    cfg_l = ep_serve_cfg(cfg, mesh)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)

    def fn(params, cache, tokens, lengths, slots):
        logits, k_new, v_new = MD.prefill_slots(cfg_l, params, tokens,
                                                lengths)
        slots_l, _ = _localize_slots(slots, cache["pos"].shape[0])
        cache = MD.insert_slots(cache, slots_l, k_new, v_new, lengths)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, greedy, cache

    return shard_map(fn, mesh=mesh,
                     in_specs=(pspec, cspec, rep, rep, rep),
                     out_specs=(rep, rep, cspec), check_rep=False)


def make_slot_admit_paged_mesh(cfg: ModelConfig, mesh, params,
                               cache) -> Callable:
    """Mesh form of :func:`make_slot_admit_paged`. Unlike dense admission,
    the paged forward READS the pool (shared-prefix rows at ``pos0 > 0``),
    which only the slot-owning data shard holds — foreign shards compute
    finite garbage for those rows. Owner rows are masked in, summed over
    "data" (adding exact fp zeros), and the greedy lane recomputed from the
    reconciled logits."""
    from jax.experimental.shard_map import shard_map
    cfg_l = ep_serve_cfg(cfg, mesh)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    dp = int(mesh.shape.get("data", 1))

    def fn(params, cache, tokens, lengths, slots, pos0):
        cache, tab0 = _localize_paged_tab(cache, dp)
        slots_l, mine = _localize_slots(slots, cache["pos"].shape[0])
        logits, cache = MD.admit_slots_paged(cfg_l, params, cache, tokens,
                                             lengths, slots_l, pos0)
        if dp > 1:
            logits = jax.lax.psum(
                jnp.where(mine[:, None], logits, 0.0), "data")
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, greedy, _restore_tab(cache, tab0)

    return shard_map(fn, mesh=mesh,
                     in_specs=(pspec, cspec, rep, rep, rep, rep),
                     out_specs=(rep, rep, cspec), check_rep=False)


def make_slot_decode_spec_mesh(cfg: ModelConfig, draft_cfg: ModelConfig,
                               k_draft: int, temperature: float, mesh,
                               params, draft_params, cache, draft_cache,
                               combine_wire_dtype: str = "fp32") -> Callable:
    """Mesh form of :func:`make_slot_decode_spec`: one fused draft/verify
    round with BOTH models' expert tables EP-sharded (draft included — the
    compressed tables divide the same way) and both caches sharded with the
    slots. The shared paged block table is localized once per cache."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.serving.spec import build_slot_decode_spec
    cfg_l = ep_serve_cfg(cfg, mesh, combine_wire_dtype)
    dcfg_l = ep_serve_cfg(draft_cfg, mesh, combine_wire_dtype)
    inner = build_slot_decode_spec(cfg_l, dcfg_l, k_draft, temperature)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    from repro.launch import sharding as SH
    dpspec = SH.serve_param_pspecs(draft_params, mesh)
    dcspec = SH.slot_cache_pspecs(draft_cache, mesh)
    dp = int(mesh.shape.get("data", 1))

    def fn(params, draft_params, cache, draft_cache, token, active,
           remaining, eos, keys, poison):
        cache, tab0 = _localize_paged_tab(cache, dp)
        draft_cache, dtab0 = _localize_paged_tab(draft_cache, dp)
        block, still, cache, draft_cache = inner(
            params, draft_params, cache, draft_cache, token, active,
            remaining, eos, keys, poison)
        return (block, still, _restore_tab(cache, tab0),
                _restore_tab(draft_cache, dtab0))

    return shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, dpspec, cspec, dcspec, v, v, v, v, v, v),
        out_specs=(P(None, "data"), v, cspec, dcspec), check_rep=False)


def make_slot_admit_spec_mesh(cfg: ModelConfig, draft_cfg: ModelConfig,
                              temperature: float, mesh, params, draft_params,
                              cache, draft_cache) -> Callable:
    """Mesh form of :func:`make_slot_admit_spec` (dense caches): replicated
    dual prefill, localized scatters — the :func:`make_slot_admit_mesh`
    story applied to both models."""
    from jax.experimental.shard_map import shard_map
    from repro.serving.spec import build_slot_admit_spec
    cfg_l = ep_serve_cfg(cfg, mesh)
    dcfg_l = ep_serve_cfg(draft_cfg, mesh)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    from repro.launch import sharding as SH
    dpspec = SH.serve_param_pspecs(draft_params, mesh)
    dcspec = SH.slot_cache_pspecs(draft_cache, mesh)

    def fn(params, draft_params, cache, draft_cache, tokens, lengths, slots,
           keys):
        n_local = cache["pos"].shape[0]
        slots_l, _ = _localize_slots(slots, n_local)
        logits, k_new, v_new = MD.prefill_slots(cfg_l, params, tokens,
                                                lengths)
        cache = MD.insert_slots(cache, slots_l, k_new, v_new, lengths)
        dlogits, dk, dv = MD.prefill_slots(dcfg_l, draft_params, tokens,
                                           lengths)
        del dlogits
        draft_cache = MD.insert_slots(draft_cache, slots_l, dk, dv, lengths)
        first = sample_tokens(logits, temperature, keys, lengths)
        return logits, first, cache, draft_cache

    return shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, dpspec, cspec, dcspec, rep, rep, rep, rep),
        out_specs=(rep, rep, cspec, dcspec), check_rep=False)


def make_slot_admit_spec_paged_mesh(cfg: ModelConfig, draft_cfg: ModelConfig,
                                    temperature: float, mesh, params,
                                    draft_params, cache,
                                    draft_cache) -> Callable:
    """Mesh form of :func:`make_slot_admit_spec_paged`: both pools admit the
    localized suffix group; logits reconcile over "data" (the
    :func:`make_slot_admit_paged_mesh` masking) and the first token is
    re-sampled from the reconciled logits so it is exact on every shard."""
    from jax.experimental.shard_map import shard_map
    from repro.serving.spec import build_slot_admit_spec_paged
    cfg_l = ep_serve_cfg(cfg, mesh)
    dcfg_l = ep_serve_cfg(draft_cfg, mesh)
    pspec, cspec, v, rep = _mesh_specs(mesh, params, cache)
    from repro.launch import sharding as SH
    dpspec = SH.serve_param_pspecs(draft_params, mesh)
    dcspec = SH.slot_cache_pspecs(draft_cache, mesh)
    dp = int(mesh.shape.get("data", 1))

    def fn(params, draft_params, cache, draft_cache, tokens, lengths, slots,
           pos0, keys):
        cache, tab0 = _localize_paged_tab(cache, dp)
        draft_cache, dtab0 = _localize_paged_tab(draft_cache, dp)
        slots_l, mine = _localize_slots(slots, cache["pos"].shape[0])
        logits, cache = MD.admit_slots_paged(cfg_l, params, cache, tokens,
                                             lengths, slots_l, pos0)
        _dl, draft_cache = MD.admit_slots_paged(
            dcfg_l, draft_params, draft_cache, tokens, lengths, slots_l,
            pos0)
        del _dl
        if dp > 1:
            logits = jax.lax.psum(
                jnp.where(mine[:, None], logits, 0.0), "data")
        first = sample_tokens(logits, temperature, keys, pos0 + lengths)
        return (logits, first, _restore_tab(cache, tab0),
                _restore_tab(draft_cache, dtab0))

    return shard_map(
        fn, mesh=mesh,
        in_specs=(pspec, dpspec, cspec, dcspec, rep, rep, rep, rep, rep),
        out_specs=(rep, rep, cspec, dcspec), check_rep=False)
