"""Prefill + single-token decode must reproduce the full forward pass —
for every architecture family (KV cache, SSM state, hybrid, enc-dec)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as MD

B, S = 2, 16


def _cfg(arch):
    cfg = configs.get(arch).reduced()
    if cfg.moe is not None:
        # headroom so capacity dropping can't cause (expected) mismatches
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=4.0))
    return cfg


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = MD.init(cfg, jax.random.PRNGKey(1))
    rng = jax.random.PRNGKey(2)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.n_audio_ctx, cfg.d_model)).astype(cfg.param_dtype)

    full, _, _ = MD.forward(cfg, params, batch)

    pre = dict(batch)
    pre["tokens"] = tokens[:, :S - 2]
    plogits, cache = MD.prefill(cfg, params, pre, s_max=S + 2)
    np.testing.assert_allclose(
        np.asarray(plogits, np.float32),
        np.asarray(full[:, S - 3], np.float32), atol=0.08, rtol=0.05)

    # two decode steps
    for t in (S - 2, S - 1):
        dlogits, cache = MD.decode_step(cfg, params, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(dlogits, np.float32),
            np.asarray(full[:, t], np.float32), atol=0.08, rtol=0.05)


def test_cache_pos_advances():
    cfg = _cfg("granite-8b")
    params = MD.init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, 4), 0,
                                cfg.vocab_size)
    _, cache = MD.prefill(cfg, params, {"tokens": tokens}, s_max=8)
    assert int(cache["pos"]) == 4
    _, cache = MD.decode_step(cfg, params, cache, tokens[:, 0])
    assert int(cache["pos"]) == 5


def test_init_cache_shapes():
    cfg = _cfg("zamba2-2.7b")
    cache = MD.init_cache(cfg, batch_size=3, s_max=64)
    nseg = cfg.n_layers // cfg.hybrid_attn_every
    assert cache["k"].shape == (nseg, 3, 64, cfg.n_kv_heads, cfg.hd)
    assert cache["ssm"].ssm.shape[0] == cfg.n_layers
