"""hlo_analysis: computation splitting, while-trip multiplication, dot FLOPs,
collective accounting — on a synthetic HLO fixture plus a real lowered jit."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H

FIXTURE = """\
HloModule jit_step

%add.clone (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add = f32[] add(%x, %y)
}

%body (arg: (s32[], bf16[8,16])) -> (s32[], bf16[8,16]) {
  %arg = (s32[], bf16[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = bf16[8,16] get-tuple-element(%arg), index=1
  %w = bf16[16,16] constant({...})
  %dot.1 = bf16[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = bf16[8,16] all-reduce(%dot.1), replica_groups={}, to_apply=%add.clone
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %tup = (s32[], bf16[8,16]) tuple(%ip, %ar)
}

%cond (arg: (s32[], bf16[8,16])) -> pred[] {
  %arg = (s32[], bf16[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p0: bf16[8,16]) -> bf16[8,16] {
  %p0 = bf16[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], bf16[8,16]) tuple(%zero, %p0)
  %while.1 = (s32[], bf16[8,16]) while(%init), condition=%cond, body=%body
  %ag = bf16[16,16] all-gather(%p0), replica_groups={}, dimensions={0}
  ROOT %out = bf16[8,16] get-tuple-element(%while.1), index=1
}
"""


def test_fixture_trip_multiplication():
    an = H.analyze_module(FIXTURE)
    # dot: 2 * (8*16) * 16 = 4096 flops, x12 trips
    assert an.dot_flops == 4096 * 12
    # all-reduce in body: 8*16*2 bytes * factor 2 * 12 trips
    ar = an.coll_by_kind["all-reduce"]
    assert ar == 8 * 16 * 2 * 2.0 * 12
    # all-gather in entry: once
    assert an.coll_by_kind["all-gather"] == 16 * 16 * 2


def test_real_lowered_module_flops():
    """Dot FLOPs parsed from a real compiled module match the analytic
    count for a plain matmul chain."""
    M, K, N = 64, 128, 32

    def f(a, b):
        return jnp.dot(a, b)

    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    an = H.analyze_module(compiled.as_text())
    assert an.dot_flops == 2 * M * K * N


def test_scan_counts_layers():
    L, B, D = 7, 4, 16

    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    an = H.analyze_module(compiled.as_text())
    assert an.dot_flops == 2 * B * D * D * L


def test_roofline_terms():
    r = H.roofline_terms(197e12, 819e9, 0.0)      # 1s compute, 1s memory
    assert abs(r["t_compute_s"] - 1.0) < 1e-9
    assert abs(r["t_memory_s"] - 1.0) < 1e-9
    r2 = H.roofline_terms(197e12, 0.0, 500e9)
    assert r2["dominant"] == "collective"
    assert r2["t_collective_s"] == 10.0
