"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared expert, per the K2 design), head_dim=128.
This is the PRIMARY MergeMoE target at scale: 384 -> 192 merged experts
halves expert memory (see core.merge / launch.compress).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=163840,
    rope_theta=50_000.0,
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        capacity_factor=1.25,
        group_size=2048,
    ),
    remat="full",
)
