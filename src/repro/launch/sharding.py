"""Path-rule based sharding: params / optimizer state / batches / caches.

Strategy:
  * batch DP over ("pod","data"); FSDP weight sharding over "data";
    Megatron-style TP over "model" (fused head dim / FFN width);
    expert parallelism = expert dim over "model".
  * decode KV caches are SEQUENCE-sharded over "model" (flash-decoding
    style) because several archs have n_kv_heads < 16.
  * every rule is divisibility-checked against the actual leaf shape; a
    non-divisible axis entry is dropped (replicated) rather than failing —
    e.g. vocab=50280 can't split 16 ways, so the embed's vocab dim stays
    local while d_model still shards.

Rules are ordered; first regex match on the "/"-joined tree path wins.
Hillclimbing performance = editing RULES (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.launch.mesh import data_axes

# spec templates: "D" -> "data" (FSDP), "M" -> "model" (TP/EP), "DP" -> batch
# axes (pod+data), None -> replicated. Templates are right-aligned against the
# leaf's dims (leading stack dims are unsharded).
RULES: List[Tuple[str, Tuple]] = [
    # experts: EP over "model", FSDP over d_model. (Sharding f over "data"
    # instead was tried and REFUTED — it swapped already-CSE'd weight
    # gathers for larger activation reduce-scatters; §Perf iteration A5.)
    (r"moe/(wg|wu)$",        ("M", "D", None)),     # [.., E, d@D, f]
    (r"moe/wd$",             ("M", None, "D")),     # [.., E, f, d@D]
    # int8 expert tables (DESIGN.md §8): same EP/FSDP layout as the bf16
    # leaves; the per-output-channel scales shard the expert dim only (the
    # keepdim axis is 1 and the channel dim must stay whole next to its
    # table's unsharded channel dim). Must precede the catch-all
    # "ln|scale" rule, which would otherwise replicate *_scale.
    (r"moe/qexp/(wg|wu)$",   ("M", "D", None)),     # int8 [.., E, d@D, f]
    (r"moe/qexp/wd$",        ("M", None, "D")),     # int8 [.., E, f, d@D]
    (r"moe/qexp/\w+_scale$", ("M", None, None)),    # f32 [.., E, 1, ch]
    (r"moe/router$",         (None, None)),         # tiny, replicated
    (r"moe/remap$",          (None,)),
    (r"moe/live$",           ()),                    # per-layer scalar
    (r"shared/(wg|wu)$",     ("D", "M")),
    (r"shared/wd$",          ("M", "D")),
    # Q/O tensor-parallel over heads; K/V REPLICATED across "model" (GQA has
    # n_kv_heads < 16 on most archs — replicating the small KV projections
    # avoids partial-sum all-reduces in attention; Megatron-GQA style).
    (r"attn/wq$",            ("D", "M")),           # [.., d, H]
    (r"attn/w[kv]$",         ("D", None)),
    (r"attn/wo$",            ("M", "D")),           # [.., H, d]
    (r"attn/bq$",            ("M",)),
    (r"attn/b[kv]$",         ()),
    (r"mlp/(wg|wu)$",        ("D", "M")),
    (r"mlp/wd$",             ("M", "D")),
    (r"embed/tok$",          ("M", "D")),           # [V, d]
    (r"embed/head$",         ("D", "M")),           # [d, V]
    (r"in_proj$",            ("D", None)),          # mamba [.., d, k]
    (r"out_proj$",           ("M", "D")),           # mamba [.., di, d]
    (r"conv_w$|conv_b$|A_log$|dt_bias$|norm_scale$|/D$",  ()),
    (r"ln|scale",            ()),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# parallelism profile: "2d" (FSDP x TP, default) or "dp_only" (small models:
# replicate params, batch over EVERY mesh axis — no weight gathers at all;
# §Perf iteration C2). Selected per model size by profile_for().
_PROFILE = {"mode": "2d"}


def set_profile(mode: str) -> None:
    _PROFILE["mode"] = mode


def profile_for(cfg, mesh=None, global_batch=None) -> str:
    """Pure DP for sub-1B models when the batch covers every rank; 2-D
    (FSDP x TP) otherwise."""
    if cfg.param_count() >= 1e9:
        return "2d"
    if mesh is not None and global_batch is not None:
        total = int(np.prod(list(mesh.shape.values())))
        if global_batch % total != 0:
            return "2d"
    return "dp_only"


def _resolve_axis(tok, mesh) -> Optional[Any]:
    if tok is None:
        return None
    names = mesh.axis_names
    dp_only = _PROFILE["mode"] == "dp_only"
    if tok == "D":
        if dp_only:
            return None
        return "data" if "data" in names else None
    if tok == "M":
        if dp_only:
            return None
        return "model" if "model" in names else None
    if tok == "DP":
        ax = tuple(names) if dp_only else data_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    return tok


def _axis_size(entry, mesh) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


def _fit_spec(template: Sequence, shape: Tuple[int, ...], mesh) -> P:
    """Right-align the template with the shape; drop non-divisible axes."""
    ndim = len(shape)
    tpl = list(template)
    if len(tpl) > ndim:
        tpl = tpl[len(tpl) - ndim:]
    tpl = [None] * (ndim - len(tpl)) + tpl
    entries = []
    for dim, tok in zip(shape, tpl):
        ax = _resolve_axis(tok, mesh)
        if ax is not None and dim % _axis_size(ax, mesh) != 0:
            ax = None                       # replicate instead of failing
        entries.append(ax)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def params_pspecs(shapes_tree, mesh, rules: Optional[List] = None):
    """shapes_tree: pytree of ShapeDtypeStruct (or arrays). Returns pspecs."""
    rules = rules if rules is not None else RULES

    def one(path, leaf):
        ps = _path_str(path)
        for rx, tpl in rules:
            if re.search(rx, ps):
                return _fit_spec(tpl, leaf.shape, mesh)
        return P()                          # default: replicate

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


# optimizer state paths end with /m /v /vr /vc /_ — the same RULES regexes
# still match (they anchor on the param name earlier in the path, except the
# `$`-anchored ones). Strip the trailing state key before matching.
_STATE_KEYS = ("m", "v", "vr", "vc", "_")


def opt_pspecs(opt_shapes_tree, mesh, rules: Optional[List] = None):
    rules = rules if rules is not None else RULES

    def one(path, leaf):
        ps = _path_str(path)
        parts = ps.split("/")
        if parts and parts[-1] in _STATE_KEYS:
            ps = "/".join(parts[:-1])
        for rx, tpl in rules:
            if re.search(rx, ps):
                return _fit_spec(tpl, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(one, opt_shapes_tree)


# ---------------------------------------------------------------------------
# data batches and decode caches
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shapes, mesh):
    dp = _resolve_axis("DP", mesh)

    def one(path, leaf):
        return _fit_spec((dp,) + (None,) * (len(leaf.shape) - 1),
                         leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def cache_pspecs(cache_shapes, mesh):
    """Decode caches: batch over DP; KV sequence axis over "model"
    (flash-decoding); SSM heads over "model"; conv channels over "model".
    When the batch dim can't shard (e.g. long_500k B=1) the sequence axis
    additionally takes the "data" axis."""
    dp = _resolve_axis("DP", mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps.endswith("pos"):
            return P()
        if ps.endswith("k") or ps.endswith("v"):        # [L, B, S, kv, hd]
            b_ok = shape[1] % _axis_size(dp, mesh) == 0
            if b_ok:
                return _fit_spec((None, dp, "M", None, None), shape, mesh)
            seq = ("D", "M") if shape[2] % (
                _axis_size("data", mesh) * _axis_size("model", mesh)) == 0 else "M"
            tpl = (None, None, seq if isinstance(seq, str) else ("data", "model"),
                   None, None)
            return _fit_spec(tpl, shape, mesh)
        if "ssm" in ps and len(shape) == 5:             # [L, B, nh, hd, state]
            return _fit_spec((None, dp, "M", None, None), shape, mesh)
        if "conv" in ps:                                # [L, B, w-1, C]
            return _fit_spec((None, dp, None, "M"), shape, mesh)
        if ps.endswith("enc"):                          # [B, na, d]
            return _fit_spec((dp, None, "M"), shape, mesh)
        return _fit_spec((dp,) + (None,) * (len(shape) - 1), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# expert-parallel serving (DESIGN.md §13)
# ---------------------------------------------------------------------------

# Sharded-decode param layout: ONLY the expert tables (and their int8 qexp
# leaves) are partitioned — expert dim over "model" — and everything else is
# replicated. Attention/embeddings/router run replicated inside the decode
# shard_map (their per-token math is what the data axis parallelizes), so
# FSDP-style weight sharding would force gathers inside the block. Literal
# axis names (not "M"/"D" tokens) keep the layout independent of the
# train-time parallelism profile.
SERVE_RULES: List[Tuple[str, Tuple]] = [
    (r"moe/(wg|wu|wd)$",     ("model", None, None)),   # [.., E, ., .]
    (r"moe/qexp/(wg|wu|wd)$", ("model", None, None)),
    (r"moe/qexp/\w+_scale$", ("model", None, None)),
]


def serve_param_pspecs(shapes_tree, mesh):
    """Param pspecs for the EP decode shard_map: expert tables over
    "model", the rest replicated. Same tree feeds device_put placement and
    the shard_map in_specs, so layout and program always agree."""
    return params_pspecs(shapes_tree, mesh, rules=SERVE_RULES)


def validate_ep_params(shapes_tree, mesh) -> None:
    """Fail fast if any expert-table leaf can't split over "model": a
    silently replicated table would make every shard treat its full copy
    as the LOCAL slice (owner = id // E_local collapses to shard 0)."""
    ep = int(mesh.shape.get("model", 1))
    if ep <= 1:
        return
    problems = []

    def one(path, leaf):
        ps = _path_str(path)
        for rx, _ in SERVE_RULES:
            if re.search(rx, ps):
                # expert dim is the leaf's first non-stack axis: templates
                # are right-aligned 3-dim, so it's shape[-3]
                if leaf.shape[-3] % ep != 0:
                    problems.append(f"{ps}: {leaf.shape[-3]} experts % "
                                    f"model={ep} != 0")
                return
    jax.tree_util.tree_map_with_path(one, shapes_tree)
    if problems:
        raise ValueError(
            "expert tables not divisible by the EP degree: "
            + "; ".join(problems))


def slot_cache_pspecs(cache_shapes, mesh):
    """Serve-cache pspecs (dense slot cache OR paged pool, DESIGN.md §13):
    slots ride the "data" axis — dense k/v [L, B, S, nkv, hd] and the block
    pools [L, nb, bs, nkv, hd] shard axis 1, per-slot ``pos`` shards with
    them — while the block table stays REPLICATED (host-written global ids;
    the mesh step wrappers localize it in-program). KV is replicated over
    "model", so attention never crosses the wire."""
    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        if name == "tab":
            return P()
        if name == "pos":
            return P("data")
        return P(None, "data")

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def slot_vector_pspec() -> P:
    """Per-slot engine vectors (token/active/remaining/eos/keys/poison):
    sharded over "data" with the slots."""
    return P("data")


# ---------------------------------------------------------------------------
# calibration capture buffers (mesh-parallel compression, DESIGN.md §6)
# ---------------------------------------------------------------------------

def calib_batch_axes(mesh):
    """Mesh axes carrying the calibration batch. Capture is data-parallel
    ONLY: weights stay replicated (the "model"/expert axis is reserved for
    the solve stage), so the batch rides every data axis, pod included."""
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def calib_pspecs(batch_shapes, mesh):
    """Specs for the calibration batch fed to the capture forward: leading
    (batch) dim over the data axes, everything else replicated. Independent
    of the parallelism profile — capture sharding must not change with the
    training profile, or the captured reservoirs would depend on it."""
    dp = calib_batch_axes(mesh)

    def one(path, leaf):
        return _fit_spec((dp,) + (None,) * (len(leaf.shape) - 1),
                         leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def capture_pspecs(mesh) -> Tuple[P, P]:
    """(expert_inputs [L, B, S, d], usage_counts [L, N]) output specs for the
    capture forward: activations keep the batch dim sharded so each host
    shard folds only its own token range; counts are exact one-hot sums, so
    the all-reduce into a replicated buffer is bitwise-safe."""
    return P(None, calib_batch_axes(mesh)), P()


def logits_pspec(mesh, shape=None) -> P:
    if shape is not None:
        return _fit_spec(("DP", "M"), shape, mesh)
    dp = _resolve_axis("DP", mesh)
    return P(dp, "model")


def named(tree_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
