"""MoE dispatch invariants (property tests) + multi-device collective
compression (subprocess with 8 simulated devices)."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models import moe as MoE
from repro.models import model as MD


def _cfg(E=8, k=2, cf=2.0, G=64):
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, n_experts=E, top_k=k, capacity_factor=cf, group_size=G))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_topk_iterative_matches_lax(seed, E, k):
    probs = jax.random.uniform(jax.random.PRNGKey(seed), (6, 7, E))
    w_ref, i_ref = jax.lax.top_k(probs, k)
    w, i = MoE._topk_iterative(probs, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_dispatch_combine_weights_sum_to_topk_weights():
    """Every undropped token's combine weights equal its top-k routing
    weights; dropped tokens contribute zero (never NaN)."""
    cfg = _cfg(cf=8.0)   # big capacity: nothing dropped
    m = cfg.moe
    G, E = 32, m.n_experts
    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (G, m.top_k)), axis=-1)
    idx = jax.random.randint(key, (G, m.top_k), 0, E)
    C = MoE._capacity(m, G, E)
    combine, dispatch = MoE._dispatch_tensors(cfg, w, idx, E, C)
    per_token = np.asarray(jnp.sum(combine, axis=(1, 2)))
    np.testing.assert_allclose(per_token, np.asarray(jnp.sum(w, -1)),
                               atol=1e-5)
    assert bool(jnp.all(jnp.sum(dispatch, axis=(1, 2)) <= m.top_k))


def test_capacity_drops_are_deterministic_prefix():
    """With capacity 4, only the first 4 tokens routed to an expert keep
    their slots (GShard prefix semantics)."""
    cfg = _cfg(E=2, k=1, cf=0.25, G=32)   # tiny capacity
    m = cfg.moe
    G = 32
    w = jnp.ones((G, 1))
    idx = jnp.zeros((G, 1), jnp.int32)    # everyone wants expert 0
    C = MoE._capacity(m, G, 2)
    combine, _ = MoE._dispatch_tensors(cfg, w, idx, 2, C)
    kept = np.asarray(jnp.sum(combine, axis=(1, 2)))
    assert kept[:C].sum() == C and kept[C:].sum() == 0


def test_remap_duplicates_sum_weights():
    """After compression, two selected originals mapping to the same merged
    expert contribute additively (matrix A acting on routing weights)."""
    cfg = _cfg(E=4, k=2, cf=8.0)
    params = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    # all originals -> one real expert
    p1 = dict(params, remap=jnp.zeros(4, jnp.int32))
    y1 = MoE.moe_apply(cfg, p1, x).y
    # reference: that expert applied with weight 1 (softmax weights sum to 1)
    from repro.kernels import ref
    xe = x.reshape(-1, cfg.d_model)
    e0 = ref.swiglu_mlp(xe, p1["wg"][0], p1["wu"][0], p1["wd"][0])
    np.testing.assert_allclose(
        np.asarray(y1.reshape(-1, cfg.d_model), np.float32),
        np.asarray(e0, np.float32), atol=2.0, rtol=0.02)  # bf16 precision


def test_compressed_psum_multidevice():
    """int8-over-the-wire psum inside shard_map on 8 simulated devices."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 7.0

        def body(xs):
            return compressed_psum(xs[0], "data", jax.random.PRNGKey(0))[None]

        f = shard_map(body, mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None))
        out = f(x)
        exact = jnp.sum(x, axis=0)
        err = float(jnp.max(jnp.abs(out[0] - exact)) / jnp.max(jnp.abs(exact)))
        assert err < 0.05, err
        print("OK", err)
    """)
    # JAX_PLATFORMS=cpu: without it, a container with libtpu installed spends
    # ~8 min retrying GCP metadata probes before falling back to CPU.
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                       "HOME": "/root",
                                       "JAX_PLATFORMS": "cpu"}, cwd="/root/repo",
                       timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr
