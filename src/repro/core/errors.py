"""Typed errors for the compression pipeline."""


class TechniqueInapplicable(Exception):
    """Raised when MergeMoE is requested for an architecture without routed
    experts (dense / ssm / hybrid / vlm / audio families). See DESIGN.md
    §Arch-applicability."""


class CalibrationError(Exception):
    """Raised when calibration data is insufficient (e.g. below the paper's
    critical sample threshold, Fig. 4) and the caller asked for strictness."""
