"""Unified model configuration covering all assigned architecture families.

Families: dense | moe | vlm | hybrid | ssm | audio (enc-dec).
A single ``ModelConfig`` instance fully determines parameter shapes,
forward semantics and sharding-relevant dimensions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    """Routed-expert configuration (token-choice top-k, GShard-style capacity dispatch)."""
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # Tokens are dispatched within groups of this size; keeps the one-hot
    # dispatch einsum linear in total tokens (cost ~ k*cf*d_model*T*group).
    group_size: int = 1024
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    # 'dense'  = capacity/einsum dispatch (pjit friendly, used in dry-run)
    # 'ragged' = sort-based grouped matmul (single-device / Pallas path)
    # 'gather' = ragged that specializes decode-shaped calls (one token per
    #            sequence, S == 1, and T <= gather_max_tokens) to the
    #            per-token gather kernel; prefill buckets (S > 1) always
    #            keep the grouped kernel. Trace-time switch (DESIGN.md §7).
    dispatch: str = "dense"
    # token-count ceiling for the gather specialization under
    # dispatch='gather' (the serving engine raises it to cover n_slots)
    gather_max_tokens: int = 8
    # Expert parallelism (serving; DESIGN.md §13). ``ep_axis`` names the
    # mesh axis the expert tables are partitioned over and must only be set
    # on configs traced INSIDE a shard_map over that axis; ``ep_degree`` is
    # the static partition count (tables hold n_real/ep_degree rows per
    # shard). Defaults keep every existing config / artifact single-device.
    ep_axis: Optional[str] = None
    ep_degree: int = 1
    # Wire dtype for the EP combine step: 'fp32' returns per-pair outputs
    # via all-to-all (bitwise vs single device); 'int8' all-reduces the
    # pair table through distributed.compressed_psum (tolerance-gated).
    combine_wire_dtype: str = "fp32"
    combine_wire_seed: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) configuration."""
    d_state: int
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                        # dense-MLP width (0 for pure SSM)
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    # MergeMoE compression state: layers [moe_split, n_layers) hold
    # ``moe_merged`` REAL expert slots (plus the original router + remap
    # table). moe_merged == 0 means uncompressed. Heterogeneous per-layer
    # budgets set ``moe_merged_layers`` (one live count per suffix layer);
    # the stored tables stay padded to ``moe_merged`` = max so the suffix
    # stack scans homogeneously, and the remap/router-logit mask keeps the
    # pad rows unreachable (DESIGN.md §5).
    moe_split: int = 0
    moe_merged: int = 0
    moe_merged_layers: Optional[Tuple[int, ...]] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* attention+MLP block applied every k SSM blocks
    hybrid_attn_every: int = 0

    # enc-dec (whisper): n_layers applies to BOTH encoder and decoder stacks
    encdec: bool = False
    n_audio_ctx: int = 0             # encoder sequence length (precomputed frames)

    # vlm: number of precomputed image-patch embeddings prepended to the text
    vlm_num_patches: int = 0

    dtype: str = "bfloat16"
    remat: str = "none"              # none | full | dots
    scan_layers: bool = True
    logits_softcap: float = 0.0

    # ---- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> eligible for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def compressed(self, merged_experts: int, split: Optional[int] = None
                   ) -> "ModelConfig":
        """Config view after MergeMoE compression: layers [split, n_layers)
        carry ``merged_experts`` real experts. Default split follows the
        paper's suffix convention (last ~40% of layers) when not given."""
        if self.moe is None:
            from repro.core.errors import TechniqueInapplicable
            raise TechniqueInapplicable(
                f"{self.name} ({self.family}) has no routed experts; "
                "MergeMoE expert merging does not apply (DESIGN.md §4).")
        if split is None:
            split = int(self.n_layers * 0.6)
        return self.replace(moe_split=split, moe_merged=merged_experts,
                            moe_merged_layers=None)

    def compressed_per_layer(self, merged_per_layer: Tuple[int, ...],
                             split: int) -> "ModelConfig":
        """Config view after a heterogeneous plan: suffix layer ``split + i``
        keeps ``merged_per_layer[i]`` LIVE experts; physical tables are
        padded to the max so the stack scans homogeneously (DESIGN.md §5)."""
        if self.moe is None:
            from repro.core.errors import TechniqueInapplicable
            raise TechniqueInapplicable(
                f"{self.name} ({self.family}) has no routed experts; "
                "MergeMoE expert merging does not apply (DESIGN.md §4).")
        merged = tuple(int(m) for m in merged_per_layer)
        if len(merged) != self.n_layers - split:
            raise ValueError(
                f"need one merged-expert count per layer in "
                f"[{split}, {self.n_layers}); got {len(merged)}")
        if any(not 1 <= m <= self.moe.n_experts for m in merged):
            raise ValueError(
                f"per-layer merged counts {merged} outside "
                f"[1, {self.moe.n_experts}]")
        uniform = len(set(merged)) == 1
        return self.replace(moe_split=split, moe_merged=max(merged),
                            moe_merged_layers=None if uniform else merged)

    def live_experts_per_suffix_layer(self) -> Tuple[int, ...]:
        """Live (routable) expert count for each compressed suffix layer."""
        if not self.moe_merged:
            raise ValueError("model is not compressed")
        if self.moe_merged_layers is not None:
            return self.moe_merged_layers
        return (self.moe_merged,) * (self.n_layers - self.moe_split)

    # ---- (de)serialization for compressed artifacts ------------------------
    def to_json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.moe_merged_layers is not None:
            d["moe_merged_layers"] = list(self.moe_merged_layers)
        return d

    # ---- parameter accounting (for roofline MODEL_FLOPS) ------------------
    def attn_params_per_layer(self) -> int:
        d, hd = self.d_model, self.hd
        nq, nkv = self.n_heads, self.n_kv_heads
        qkv = d * (nq * hd + 2 * nkv * hd)
        if self.qkv_bias:
            qkv += nq * hd + 2 * nkv * hd
        out = nq * hd * d
        return qkv + out

    def dense_mlp_params_per_layer(self) -> int:
        return 3 * self.d_model * self.d_ff if self.d_ff else 0

    def ssm_params_per_layer(self) -> int:
        if self.ssm is None:
            return 0
        s, d = self.ssm, self.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
        conv = (di + 2 * s.n_groups * s.d_state) * s.conv_width
        out_proj = di * d
        extra = nh * 2 + di  # A_log, D, norm
        return in_proj + conv + out_proj + extra

    def moe_params_per_layer(self, active_only: bool = False) -> int:
        if self.moe is None:
            return 0
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert
        n = m.top_k if active_only else m.n_experts
        router = self.d_model * m.n_experts
        shared = m.n_shared_experts * per_expert
        return n * per_expert + router + shared

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active) parameter count, for 6*N*D napkin math."""
        emb = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            emb *= 2
        total = emb
        if self.family == "ssm":
            total += self.n_layers * self.ssm_params_per_layer()
        elif self.family == "hybrid":
            total += self.n_layers * self.ssm_params_per_layer()
            # one shared attention+MLP block
            total += self.attn_params_per_layer() + self.dense_mlp_params_per_layer()
        elif self.family == "audio":
            per_enc = self.attn_params_per_layer() + self.dense_mlp_params_per_layer()
            per_dec = 2 * self.attn_params_per_layer() + self.dense_mlp_params_per_layer()
            total += self.n_layers * (per_enc + per_dec)
        else:
            per = self.attn_params_per_layer()
            if self.moe is not None:
                per += self.moe_params_per_layer(active_only=active_only)
            else:
                per += self.dense_mlp_params_per_layer()
            total += self.n_layers * per
        return total

    # ---- reduced variant for CPU smoke tests ------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family config: 2 layers, narrow width, tiny vocab."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            scan_layers=self.scan_layers,
            remat="none",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=2, d_ff_expert=32, group_size=64,
                n_shared_experts=min(self.moe.n_shared_experts, 1))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32)
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
        if self.encdec:
            kw["n_audio_ctx"] = 32
        if self.vlm_num_patches:
            kw["vlm_num_patches"] = 4
        return self.replace(**kw)


def config_from_dict(d: dict) -> ModelConfig:
    """Inverse of :meth:`ModelConfig.to_json_dict` (JSON-safe types back to
    the frozen dataclasses; lists back to tuples)."""
    d = dict(d)
    if d.get("moe") is not None:
        d["moe"] = MoEConfig(**d["moe"])
    if d.get("ssm") is not None:
        d["ssm"] = SSMConfig(**d["ssm"])
    if d.get("moe_merged_layers") is not None:
        d["moe_merged_layers"] = tuple(int(m) for m in d["moe_merged_layers"])
    return ModelConfig(**d)
