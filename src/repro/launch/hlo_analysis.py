"""Post-SPMD HLO analysis: MXU FLOPs, HBM traffic, collective traffic.

Why hand-rolled: XLA's ``compiled.cost_analysis()`` on this CPU backend
(a) counts while-loop bodies ONCE, ignoring trip counts — fatal for
scan-over-layers models — and (b) reflects CPU fusion decisions. This module
parses the post-optimization, post-SPMD HLO text directly:

* computations are classified (entry / while body / fused / applied lambda)
  and given execution MULTIPLIERS from while-loop trip counts (recovered from
  the loop condition's comparison constant);
* ``dot`` instructions contribute 2 * |out| * |contraction| FLOPs wherever
  they appear (including inside fusions — they run on the MXU either way);
* HBM traffic is counted post-fusion: for every top-level-executed
  instruction, operand bytes + output bytes (fused computations' internals
  stay in registers/VMEM and are skipped);
* collectives contribute link traffic with ring-algorithm factors:
  all-gather ~ out bytes, all-reduce ~ 2x, reduce-scatter ~ in bytes,
  all-to-all / collective-permute ~ bytes. Collective buffers are excluded
  from HBM traffic (they are accounted in the collective term).

All numbers are PER DEVICE (the module is the SPMD-partitioned per-device
program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*?)\s+([\w\-]+)\(")
_HDR_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%[\w\.\-]+\s+=")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "iota", "after-all", "partition-id", "replica-id", "domain", "token",
    "opt-barrier", "copy-start", "copy-done",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims.strip() else []


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    text: str = ""


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        is_header = (stripped.endswith("{") and
                     not _HDR_ASSIGN_RE.match(line) and
                     ("(" in line))
        if is_header:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        cur.text += line + "\n"
        im = _INSTR_RE.match(line)
        if im:
            cur.instrs.append(Instr(im.group(1), im.group(2), im.group(3),
                                    line))
    return comps


def _call_edges(comp: Computation) -> List[Tuple[str, str]]:
    """(callee, kind) pairs; kind in {call, while_body, while_cond}."""
    edges = []
    for ins in comp.instrs:
        if ins.opcode == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            if mb:
                edges.append((mb.group(1), "while_body"))
            if mc:
                edges.append((mc.group(1), "while_cond"))
        else:
            for ref in re.findall(r"(?:calls=|to_apply=)%?([\w\.\-]+)",
                                  ins.line):
                edges.append((ref, "call"))
    return edges


def _trip_count(cond: Computation) -> int:
    consts = re.findall(r"s32\[\]\s+constant\((\d+)\)", cond.text)
    return max((int(c) for c in consts), default=1)


@dataclass
class ModuleAnalysis:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    dot_count: int = 0
    sdpa_traffic_bytes: float = 0.0   # attention-materialization traffic
    sdpa_flash_bytes: float = 0.0     # what a fused flash kernel would move
    notes: List[str] = field(default_factory=list)

    @property
    def traffic_bytes_flash(self) -> float:
        """HBM traffic if the Pallas flash-attention kernel replaces the
        materialized [B,H,S,S] softmax path (reads q,k,v + writes o only)."""
        return self.traffic_bytes - self.sdpa_traffic_bytes + self.sdpa_flash_bytes


# Instruction classes that materialize HBM traffic on TPU (pre-fusion HLO):
# elementwise chains fuse into their consumers, so only "anchor" ops count.
_TRAFFIC_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "sort", "concatenate", "pad",
    "custom-call", "cholesky", "triangular-solve", "fft", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",  # collective buffers also touch HBM locally
}


def _collective_dtype_reference(hlo: str) -> Dict[Tuple[str, Tuple[int, ...]], str]:
    """Map (collective kind, dims) -> dtype from a TRUE-dtype module (the
    post-SPMD dump), used to undo the CPU backend's bf16->f32 legalization
    when counting the FINAL schedule."""
    ref: Dict[Tuple[str, Tuple[int, ...]], str] = {}
    for m in re.finditer(
            r"=\s+\(?([a-z0-9]+)\[([\d,]*)\][^\s]*\)?\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", hlo):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        ref.setdefault((m.group(3), dims), m.group(1))
    return ref


def analyze_collectives(schedule_hlo: str,
                        dtype_ref: Optional[Dict] = None) -> ModuleAnalysis:
    """Collective accounting on the FINAL optimized module — the real
    schedule after XLA's all-reduce folding / reduce-scatter creation / CSE
    (the post-SPMD dump overstates collectives by ~2-5x). Byte sizes are
    dtype-corrected against ``dtype_ref`` because the CPU backend legalizes
    bf16 collectives to f32."""
    comps = split_computations(schedule_hlo)
    entry = next((n for n in comps
                  if re.search(r"ENTRY\s+%?" + re.escape(n), schedule_hlo)),
                 None)
    mult = _multipliers(comps, entry)[0]
    out = ModuleAnalysis(coll_by_kind=defaultdict(float),
                         coll_count=defaultdict(int))
    for name, comp in comps.items():
        m = mult[name] if mult[name] > 0 else 0.0
        if m <= 0:
            continue
        for ins in comp.instrs:
            kind = next((k for k in _COLL_KINDS
                         if ins.opcode in (k, k + "-start")), None)
            if not kind:
                continue
            b = _shape_bytes(ins.type_str)
            if dtype_ref is not None:
                sm = _SHAPE_RE.search(ins.type_str)
                if sm and sm.group(1) == "f32":
                    dims = tuple(int(d) for d in sm.group(2).split(",") if d)
                    if dtype_ref.get((kind, dims)) == "bf16":
                        b //= 2
            b = b * _COLL_FACTOR[kind] * m
            out.coll_bytes += b
            out.coll_by_kind[kind] += b
            out.coll_count[kind] += 1
    out.coll_by_kind = dict(out.coll_by_kind)
    out.coll_count = dict(out.coll_count)
    return out


def _multipliers(comps, entry):
    mult: Dict[str, float] = defaultdict(float)
    toplevel: Dict[str, bool] = defaultdict(bool)
    if entry:
        mult[entry] = 1.0
        toplevel[entry] = True
    else:
        for n in comps:
            mult[n] = 1.0
            toplevel[n] = True
    for _ in range(12):
        changed = False
        for name, comp in comps.items():
            if mult[name] <= 0:
                continue
            for callee, kind in _call_edges(comp):
                if callee not in comps:
                    continue
                if kind == "while_body":
                    cond_names = [c for c, k in _call_edges(comp)
                                  if k == "while_cond"]
                    trips = 1
                    for cn in cond_names:
                        if cn in comps:
                            trips = max(trips, _trip_count(comps[cn]))
                    new = mult[name] * trips
                    top = True
                elif kind == "while_cond":
                    new = mult[name] * max(_trip_count(comps[callee]), 1)
                    top = True
                else:
                    new = mult[name]
                    top = False
                if new > mult[callee]:
                    mult[callee] = new
                    changed = True
                if top and not toplevel[callee]:
                    toplevel[callee] = True
                    changed = True
        if not changed:
            break
    return mult, toplevel


def analyze_module(hlo: str) -> ModuleAnalysis:
    comps = split_computations(hlo)
    entry = next((n for n in comps
                  if re.search(r"ENTRY\s+%?" + re.escape(n), hlo)), None)
    mult, toplevel = _multipliers(comps, entry)

    # ---- per-instruction accounting
    out = ModuleAnalysis(coll_by_kind=defaultdict(float),
                         coll_count=defaultdict(int))
    for name, comp in comps.items():
        m = mult[name] if mult[name] > 0 else 0.0
        if m <= 0:
            continue
        # symbol table for operand byte lookups
        sym = {ins.name: _shape_bytes(ins.type_str) for ins in comp.instrs}
        for ins in comp.instrs:
            op = ins.opcode
            in_sdpa = "sdpa" in ins.line  # named_scope tag in metadata
            if op == "dot":
                dims_out = _shape_dims(ins.type_str)
                n_out = 1
                for d in dims_out:
                    n_out *= d
                cdim_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                   ins.line)
                # lhs dims: compiled modules print typed operands
                # (``dot(f32[64,128]{1,0} %Arg_0.1, ...)``) — read the shape
                # straight off the line; hand-written/abbreviated HLO
                # (``dot(%a, %b)``) falls back to the symbol table.
                lhs_dims = None
                typed_m = re.search(r"dot\(([a-z0-9]+)\[([\d,]*)\]", ins.line)
                if typed_m:
                    lhs_dims = [int(d) for d in typed_m.group(2).split(",")
                                if d.strip()]
                else:
                    lhs_m = re.search(r"dot\(%([\w\.\-]+)", ins.line)
                    if lhs_m and lhs_m.group(1) in sym:
                        lhs_ins = next((i for i in comp.instrs
                                        if i.name == lhs_m.group(1)), None)
                        if lhs_ins is not None:
                            lhs_dims = _shape_dims(lhs_ins.type_str)
                contract = 1
                if lhs_dims is not None and cdim_m and cdim_m.group(1).strip():
                    for ci in cdim_m.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contract *= lhs_dims[ci]
                out.dot_flops += 2.0 * n_out * contract * m
                out.dot_count += 1
            is_coll = next((k for k in _COLL_KINDS
                            if op == k or op == k + "-start"), None)
            if is_coll:
                b = _shape_bytes(ins.type_str) * _COLL_FACTOR[is_coll] * m
                out.coll_bytes += b
                out.coll_by_kind[is_coll] += b
                out.coll_count[is_coll] += 1
                continue
            if op in _SKIP_TRAFFIC or op not in _TRAFFIC_OPS:
                continue
            buf_sizes = [_shape_bytes(ins.type_str)]
            args_m = re.search(re.escape(op) + r"\((.*?)\)", ins.line)
            if args_m:
                buf_sizes += [sym.get(r, 0)
                              for r in re.findall(r"%([\w\.\-]+)",
                                                  args_m.group(1))]
            b = sum(buf_sizes) * m
            out.traffic_bytes += b
            if in_sdpa:
                # attention materialization: the [B,H,S,S] logits/probs
                # buffers dwarf q/k/v/o; a flash kernel only moves the
                # latter. Classify buffers by relative size.
                out.sdpa_traffic_bytes += b
                big = max(buf_sizes) if buf_sizes else 0
                flash = sum(s for s in buf_sizes if s < 0.25 * big)
                out.sdpa_flash_bytes += flash * m

    out.coll_by_kind = dict(out.coll_by_kind)
    out.coll_count = dict(out.coll_count)
    return out


def _operand_bytes(ins: Instr, sym: Dict[str, int]) -> int:
    args_m = re.search(re.escape(ins.opcode) + r"\((.*?)\)", ins.line)
    if not args_m:
        return 0
    total = 0
    for ref in re.findall(r"%([\w\.\-]+)", args_m.group(1)):
        total += sym.get(ref, 0)
    return total


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e-like target; assignment constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_x, 1e-30)
    return {
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom,
        "roofline_fraction": t_c / bound,
    }


# ---------------------------------------------------------------------------
# analytic decode HBM-traffic model (the "modeled bytes/token" the serving
# benchmark and Engine.bench_decode report; DESIGN.md §8)
# ---------------------------------------------------------------------------

def expected_distinct_experts(n_experts: int, draws: int) -> float:
    """Expected number of DISTINCT experts hit by ``draws`` uniform routing
    draws over ``n_experts`` — ``E·(1 − (1 − 1/E)^draws)``. This is where
    MergeMoE shows up in the traffic model: fewer live experts ⇒ more
    collisions across a decode batch ⇒ fewer distinct tables streamed per
    step, even though every token still consumes top-k experts."""
    if n_experts <= 0 or draws <= 0:
        return 0.0
    return n_experts * (1.0 - (1.0 - 1.0 / n_experts) ** draws)


_ACT_BYTES = {"bf16": 2, "f16": 2, "f32": 4}
_WIRE_BYTES = {"fp32": 4.0, "int8": 1.0}


def decode_traffic_model(cfg, *, n_slots: int, pos: int,
                         weight_dtype: str = "bf16",
                         prefix_weight_dtype: str = "bf16",
                         tokens_per_slot: int = 1,
                         kv_dtype: str = "bf16",
                         ep_degree: int = 1,
                         dp_degree: int = 1,
                         combine_wire_dtype: str = "fp32",
                         act_dtype: str = "bf16"
                         ) -> Dict[str, float]:
    """Modeled HBM bytes for ONE decode step of ``n_slots`` tokens at cache
    position ``pos`` (gather-dispatch serving path), per device.

    Per step the device streams: every non-expert weight once (attention,
    norms, router, shared experts, LM head), the KV prefix of each slot,
    and — the dominant term at scale — the expert SwiGLU tables the batch's
    ``n_slots·top_k`` routing draws actually hit
    (:func:`expected_distinct_experts` per layer, at each layer's LIVE
    expert count and storage dtype). ``weight_dtype`` is the storage dtype
    of the expert tables (of the merged suffix when ``cfg`` is compressed —
    ``prefix_weight_dtype`` then covers the untouched prefix stack).

    ``tokens_per_slot`` > 1 models a MULTI-POSITION forward (the
    speculative-decoding verify pass, ``model.verify_step_slots``): routing
    draws scale to ``n_slots·tokens_per_slot·top_k``, each slot writes and
    reads ``tokens_per_slot`` fresh KV rows, and the non-expert weights
    STILL stream once — that amortization is the entire economics of
    verify-in-one-pass (DESIGN.md §10).

    ``kv_dtype`` is the KV cache storage dtype: ``"bf16"`` streams
    ``2·hd·itemsize`` bytes per (row, kv-head); ``"int8"`` models the paged
    quantized pool (DESIGN.md §11) at ``2·(hd·1 + 4)`` — int8 payload plus
    one fp32 scale per (row, head) for each of K and V. At hd=128 that is a
    512/264 ≈ 1.94x stream reduction, which is what moves the needle at
    long contexts where the KV prefix dominates the step.

    ``ep_degree`` / ``dp_degree`` model the EXPERT-PARALLEL serving mesh of
    DESIGN.md §13 (all numbers stay per device): expert tables partition
    ``ep_degree``-ways on "model" — each device holds ``live/ep`` tables
    and streams only the distinct experts ITS shard's draws hit, which
    under uniform routing is ``expected_distinct_experts(live, draws)/ep``
    (each of the shard's ``draws`` hits a given local expert w.p.
    ``1/live``) — while slots/KV partition ``dp_degree``-ways on "data".
    Attention/norm/router/shared/head weights stay replicated and stream
    in full on every device. The cost of the split is INTERCONNECT: per
    MoE layer each device all-to-alls its ``T/ep`` local tokens' ``top_k``
    activation rows to the owner shards (``(ep−1)/ep`` of the payload
    crosses a link), receives the pair outputs back on a second all-to-all
    (fp32 wire, or ~4x cheaper opt-in ``combine_wire_dtype='int8'``), and
    all-gathers the combined token block — reported as
    ``interconnect_bytes_per_step`` for the ``t_collective_s`` roofline
    term (``ICI_BW``). Dense models (``cfg.moe is None``) have no a2a:
    their interconnect term is 0 by construction.

    Returns a component breakdown plus ``bytes_per_token`` and
    ``flops_per_token``; feed those to :func:`roofline_terms` for the
    bandwidth-bound tok/s ceiling (``1 / t_memory_s``). Numbers target the
    roofline constants above — they are a MODEL of the TPU serving path,
    not a measurement of this host.
    """
    from repro.core.plan import expert_bytes   # single byte-model source

    pb = cfg.param_dtype.itemsize
    m = cfg.moe
    L = cfg.n_layers
    ep = max(int(ep_degree), 1)
    dp = max(int(dp_degree), 1)
    if act_dtype not in _ACT_BYTES:
        raise ValueError(f"act_dtype must be one of {sorted(_ACT_BYTES)}, "
                         f"got {act_dtype!r}")
    if combine_wire_dtype not in _WIRE_BYTES:
        raise ValueError(f"combine_wire_dtype must be 'fp32' or 'int8', "
                         f"got {combine_wire_dtype!r}")
    # this device's data shard: its slots, its tokens, its routing draws
    slots_dev = n_slots / dp
    draws = slots_dev * tokens_per_slot * (m.top_k if m else 0)

    # per-layer live expert counts + storage dtype
    layers = []                                   # (live, dtype) per layer
    if m is not None:
        if cfg.moe_merged:
            live = cfg.live_experts_per_suffix_layer()
            layers += [(m.n_experts, prefix_weight_dtype)] * cfg.moe_split
            layers += [(int(v), weight_dtype) for v in live]
        else:
            layers += [(m.n_experts, weight_dtype)] * L

    moe_b = 0.0
    moe_b_1dev = 0.0          # unsharded reference (ep=1, dp=1, all slots)
    router_b = 0.0
    shared_b = 0.0
    draws_1dev = n_slots * tokens_per_slot * (m.top_k if m else 0)
    for live, wdt in layers:
        # distinct LOCAL experts this device streams: each of the shard's
        # draws hits a given local expert w.p. 1/live, and the device holds
        # live/ep of them -> expected_distinct_experts(live, draws) / ep
        moe_b += (expected_distinct_experts(live, draws)
                  * expert_bytes(cfg, wdt)) / ep
        moe_b_1dev += (expected_distinct_experts(live, draws_1dev)
                       * expert_bytes(cfg, wdt))
        router_b += cfg.d_model * m.n_experts * 4          # router is fp32
        shared_b += m.n_shared_experts * 3 * cfg.d_model * m.d_ff_expert * pb

    # interconnect (EP all-to-all dataflow, DESIGN.md §13) — per device
    act_b = _ACT_BYTES[act_dtype]
    wire_b = _WIRE_BYTES[combine_wire_dtype]
    a2a_dispatch = a2a_combine = ag_out = 0.0
    if m is not None and ep > 1:
        t_dev = slots_dev * tokens_per_slot        # tokens on this data shard
        t_loc = t_dev / ep                         # ... on this model shard
        n_moe_layers = float(len(layers))
        cross = (ep - 1) / ep                      # payload crossing a link
        a2a_dispatch = n_moe_layers * t_loc * m.top_k * cfg.d_model \
            * act_b * cross
        a2a_combine = n_moe_layers * t_loc * m.top_k * cfg.d_model \
            * wire_b * cross
        ag_out = n_moe_layers * t_dev * cfg.d_model * act_b * cross
    interconnect_b = a2a_dispatch + a2a_combine + ag_out

    attn_b = float(L * cfg.attn_params_per_layer() * pb)
    if cfg.moe is None:
        attn_b += L * cfg.dense_mlp_params_per_layer() * pb
    head_b = float(cfg.vocab_size * cfg.d_model * pb)      # lm head read
    if kv_dtype == "int8":
        # int8 K + V payload plus one fp32 scale per (row, head) each
        kv_row_b = cfg.n_kv_heads * 2 * (cfg.hd * 1 + 4)
    elif kv_dtype == "bf16":
        kv_row_b = cfg.n_kv_heads * 2 * cfg.hd * pb
    else:
        raise ValueError(f"kv_dtype must be 'bf16' or 'int8', got "
                         f"{kv_dtype!r}")
    # KV shards with the slots on "data": each device streams its own
    kv_b = float(L * slots_dev * (pos + tokens_per_slot) * kv_row_b)

    step = moe_b + router_b + shared_b + attn_b + head_b + kv_b
    # per-device step bytes over GLOBAL tokens committed per step, so
    # tok/s_system == HBM_BW / bytes_per_token holds on any mesh
    tokens = max(n_slots * tokens_per_slot, 1)
    return {
        "n_slots": float(n_slots),
        "pos": float(pos),
        "ep_degree": float(ep),
        "dp_degree": float(dp),
        "moe_expert_bytes_per_step": moe_b,
        "router_bytes_per_step": router_b,
        "shared_bytes_per_step": shared_b,
        "attn_weight_bytes_per_step": attn_b,
        "lm_head_bytes_per_step": head_b,
        "kv_bytes_per_step": kv_b,
        "kv_bytes_per_token": kv_b / tokens,
        "bytes_per_step": step,
        "bytes_per_token": step / tokens,
        "moe_expert_bytes_per_token": moe_b / tokens,
        # EP interconnect terms (0 on a single device and for dense models)
        "a2a_dispatch_bytes_per_step": a2a_dispatch,
        "a2a_combine_bytes_per_step": a2a_combine,
        "allgather_bytes_per_step": ag_out,
        "interconnect_bytes_per_step": interconnect_b,
        "interconnect_bytes_per_token": interconnect_b / tokens,
        # how much LESS expert table each device streams vs one device
        # serving the whole batch (>= ep under uniform routing: the split
        # plus fewer draws per shard); the serve-bench gate checks this
        "expert_stream_reduction": (moe_b_1dev / moe_b) if moe_b > 0
        else 1.0,
        # 2 FLOPs per active weight per token (napkin 2·N_active·D)
        "flops_per_token": 2.0 * cfg.param_count(active_only=True),
    }


def spec_decode_traffic_model(cfg, draft_cfg, *, k_draft: int, n_slots: int,
                              pos: int, mean_committed: float,
                              weight_dtype: str = "bf16",
                              prefix_weight_dtype: str = "bf16",
                              draft_weight_dtype: str = "bf16",
                              draft_prefix_weight_dtype: str = "bf16",
                              kv_dtype: str = "bf16",
                              ep_degree: int = 1,
                              dp_degree: int = 1,
                              combine_wire_dtype: str = "fp32"
                              ) -> Dict[str, float]:
    """Modeled HBM bytes per COMMITTED token for one speculative
    draft/verify round (DESIGN.md §10).

    A round is ``k_draft`` decode steps of the DRAFT config (each modeled
    by :func:`decode_traffic_model` at the draft's live-expert counts and
    storage dtypes) plus ONE full-model verify forward over
    ``k_draft + 1`` positions per slot (``tokens_per_slot`` above: the full
    model's non-expert weights stream ONCE for all K+1 positions — the
    amortization spec decode banks on — while its expert stream scales
    with the extra routing draws). Dividing round bytes by
    ``n_slots · mean_committed`` (the MEASURED tokens committed per slot
    per round) gives bytes per committed token; ``modeled_speedup`` is the
    plain full-model decode step's bytes/token over it, i.e. the
    bandwidth-roofline tok/s ratio.

    Two honest caveats the numbers surface rather than hide: acceptance is
    an input (measured, not assumed), and the verify pass's expert stream
    GROWS with ``k_draft·top_k`` extra draws per slot — on a many-expert
    MoE the speedup only materializes once the batch is near expert-stream
    saturation (``expected_distinct_experts`` ≈ all live experts), which
    is why callers model deployment ``n_slots``, not the smoke batch.
    """
    mesh_kw = dict(ep_degree=ep_degree, dp_degree=dp_degree,
                   combine_wire_dtype=combine_wire_dtype)
    draft = decode_traffic_model(
        draft_cfg, n_slots=n_slots, pos=pos,
        weight_dtype=draft_weight_dtype,
        prefix_weight_dtype=draft_prefix_weight_dtype, kv_dtype=kv_dtype,
        **mesh_kw)
    verify = decode_traffic_model(
        cfg, n_slots=n_slots, pos=pos, weight_dtype=weight_dtype,
        prefix_weight_dtype=prefix_weight_dtype,
        tokens_per_slot=k_draft + 1, kv_dtype=kv_dtype, **mesh_kw)
    baseline = decode_traffic_model(
        cfg, n_slots=n_slots, pos=pos, weight_dtype=weight_dtype,
        prefix_weight_dtype=prefix_weight_dtype, kv_dtype=kv_dtype,
        **mesh_kw)

    draft_round = k_draft * draft["bytes_per_step"]
    round_bytes = draft_round + verify["bytes_per_step"]
    committed = max(n_slots * mean_committed, 1e-9)
    bytes_per_token = round_bytes / committed
    # FLOPs per committed token: K draft + (K+1) verify forwards per slot
    flops = (k_draft * draft["flops_per_token"]
             + (k_draft + 1) * baseline["flops_per_token"]) / max(
                 mean_committed, 1e-9)
    return {
        "n_slots": float(n_slots),
        "pos": float(pos),
        "k_draft": float(k_draft),
        "mean_committed": float(mean_committed),
        "draft_bytes_per_round": draft_round,
        "verify_bytes_per_round": verify["bytes_per_step"],
        "bytes_per_round": round_bytes,
        "bytes_per_token": bytes_per_token,
        "interconnect_bytes_per_round":
            k_draft * draft["interconnect_bytes_per_step"]
            + verify["interconnect_bytes_per_step"],
        "flops_per_token": flops,
        "baseline_bytes_per_token": baseline["bytes_per_token"],
        # bandwidth-roofline tok/s ratio, spec vs plain full-model decode
        "modeled_speedup": baseline["bytes_per_token"] / bytes_per_token,
    }
