"""MergeMoE compression driver: train-or-load -> calibrate -> merge -> eval.

    PYTHONPATH=src python -m repro.launch.compress --arch qwen3-moe-30b-a3b \
        --method mergemoe --merged-experts 4 --eval-batches 4

Reports the paper's headline quantities: bytes before/after, per-method
held-out loss, merge wall-time (Fig. 3 analogue).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compress as CMP
from repro.models import model as MD


def eval_loss(cfg, params, batches) -> float:
    fn = jax.jit(lambda p, b: MD.loss(cfg, p, b)[0])
    losses = [float(fn(params, b)) for b in batches]
    return float(np.mean(losses))


def make_batches(cfg, n, batch=4, seq=64, seed=0):
    out = []
    for i in range(n):
        key = jax.random.PRNGKey(seed + i)
        out.append({"tokens": jax.random.randint(
            key, (batch, seq), 0, cfg.vocab_size)})
    return out


def run(arch: str, method: str, merged_experts: int, split=None,
        calib_batches: int = 2, eval_batches: int = 4, params=None,
        cfg=None, seed: int = 0):
    cfg = cfg if cfg is not None else configs.get(arch).reduced()
    if params is None:
        params = MD.init(cfg, jax.random.PRNGKey(seed))
    calib = make_batches(cfg, calib_batches, seed=seed + 100)
    evalb = make_batches(cfg, eval_batches, seed=seed + 200)

    base_loss = eval_loss(cfg, params, evalb)
    t0 = time.perf_counter()
    new_cfg, new_params, info = CMP.compress_model(
        cfg, params, method=method, merged_experts=merged_experts,
        split=split, batches=calib)
    t_total = time.perf_counter() - t0
    comp_loss = eval_loss(new_cfg, new_params, evalb)
    report = {
        "arch": arch, "method": method,
        "n_experts": info["n_experts"],
        "merged_experts": info["merged_experts"],
        "layers_merged": info["layers_merged"],
        "bytes_original": info["bytes_original"],
        "bytes_compressed": info["bytes_compressed"],
        "compression_ratio": round(info["compression_ratio"], 4),
        "t_merge_s": round(info["t_merge_s"], 3),
        "t_total_s": round(t_total, 3),
        "loss_full": round(base_loss, 4),
        "loss_compressed": round(comp_loss, 4),
        "loss_delta": round(comp_loss - base_loss, 4),
    }
    return new_cfg, new_params, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--method", default="mergemoe",
                    choices=["mergemoe", "msmoe", "average", "zipit"])
    ap.add_argument("--merged-experts", type=int, default=4)
    ap.add_argument("--split", type=int, default=None)
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--eval-batches", type=int, default=4)
    args = ap.parse_args()
    _, _, report = run(args.arch, args.method, args.merged_experts,
                       split=args.split, calib_batches=args.calib_batches,
                       eval_batches=args.eval_batches)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
