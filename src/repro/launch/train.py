"""End-to-end training driver.

CPU-runnable (reduced configs) and production-shaped: sharded step, data
pipeline with checkpointable cursor, atomic keep-N checkpoints with async
save, automatic resume-from-latest, straggler monitoring, optional int8
error-feedback gradient compression, gradient accumulation.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
        --reduced --steps 100 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as CKPT
from repro.data.pipeline import make_pipeline, DataState
from repro.distributed import StragglerMonitor, ef_compressed
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh
from repro.optim import make_optimizer, default_optimizer_for


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    optimizer: str = ""                # "" -> size-based default
    microbatches: int = 1
    ckpt_dir: str = ""
    ckpt_every: int = 50
    ckpt_keep: int = 3
    async_ckpt: bool = True
    grad_compression: bool = False
    seed: int = 0
    data_dir: str = ""
    log_every: int = 10
    mesh_shape: str = ""               # e.g. "2,4"; "" -> (n_devices, 1)


def build(tc: TrainConfig):
    cfg = configs.get(tc.arch)
    if tc.reduced:
        cfg = cfg.reduced()
    if tc.mesh_shape:
        shape = tuple(int(x) for x in tc.mesh_shape.split(","))
        mesh = make_host_mesh(shape)
    else:
        mesh = make_host_mesh()
    set_activation_mesh(mesh)

    opt_name = tc.optimizer or default_optimizer_for(cfg.param_count())
    opt = make_optimizer(opt_name, lr=tc.lr)
    if tc.grad_compression:
        opt = ef_compressed(opt)

    params = MD.init(cfg, jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)

    p_sh = SH.named(SH.params_pspecs(params, mesh), mesh)
    o_sh = SH.named(SH.opt_pspecs(opt_state, mesh), mesh)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    step_fn = jax.jit(
        ST.make_train_step(cfg, opt, microbatches=tc.microbatches),
        in_shardings=(p_sh, o_sh, None, None),
        out_shardings=(p_sh, o_sh, None, None),
        donate_argnums=(0, 1))
    return cfg, mesh, params, opt_state, step_fn, (p_sh, o_sh)


def train(tc: TrainConfig):
    cfg, mesh, params, opt_state, step_fn, (p_sh, o_sh) = build(tc)
    data = make_pipeline(cfg, tc.seq_len, tc.global_batch,
                         data_dir=tc.data_dir or None, seed=tc.seed)
    start_step = 0
    mgr = None
    if tc.ckpt_dir:
        mgr = CKPT.CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep,
                                     async_save=tc.async_ckpt)
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt_state), extras = mgr.restore(
                latest, shardings=(p_sh, o_sh))
            data.restore(DataState.from_json(extras["data_state"]))
            start_step = latest
            print(f"[train] resumed from step {latest}")

    mon = StragglerMonitor()
    history = []
    for step in range(start_step, tc.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        mon.start_step()
        params, opt_state, loss, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(loss)
        rep = mon.end_step()
        history.append(loss)
        if rep.should_restart:
            print(f"[train] straggler policy fired at step {step} "
                  f"(x{rep.ratio:.1f} median) — checkpoint + abort for relaunch")
            if mgr:
                mgr.save(step + 1, (params, opt_state),
                         {"data_state": data.state().to_json()})
                mgr.wait()
            return {"aborted_for_relaunch": True, "step": step,
                    "losses": history}
        if step % tc.log_every == 0 or step == tc.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"ce={float(metrics['ce']):.4f} dt={rep.duration_s*1e3:.0f}ms",
                  flush=True)
        if mgr and (step + 1) % tc.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state),
                     {"data_state": data.state().to_json()})
    if mgr:
        mgr.save(tc.steps, (params, opt_state),
                 {"data_state": data.state().to_json()})
        mgr.wait()
    return {"losses": history, "final_loss": history[-1] if history else None,
            "params": params, "cfg": cfg}


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true" if not f.default
                            else "store_false", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args()
    tc = TrainConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(TrainConfig)})
    out = train(tc)
    print(json.dumps({k: v for k, v in out.items()
                      if k in ("final_loss", "aborted_for_relaunch", "step")}))


if __name__ == "__main__":
    main()
