"""Typed errors for the compression pipeline and the serving engine.

The serving hierarchy deliberately roots at :class:`ServingError` while the
submission-time rejections ALSO subclass ``ValueError``: every pre-existing
caller (and test) that caught ``ValueError`` around ``Engine.submit`` keeps
working, but new callers can discriminate shed-vs-invalid-vs-device failures
without string matching.
"""


class TechniqueInapplicable(Exception):
    """Raised when MergeMoE is requested for an architecture without routed
    experts (dense / ssm / hybrid / vlm / audio families). See DESIGN.md
    §Arch-applicability."""


class CalibrationError(Exception):
    """Raised when calibration data is insufficient (e.g. below the paper's
    critical sample threshold, Fig. 4) and the caller asked for strictness."""


# ---------------------------------------------------------------------------
# serving (DESIGN.md §12)
# ---------------------------------------------------------------------------

class ServingError(Exception):
    """Base class for engine-raised failures."""


class RequestValidationError(ServingError, ValueError):
    """A request that can never be served: rejected at SUBMISSION time (the
    only place the caller can react). Subclasses ``ValueError`` for
    backward compatibility with callers that caught the old bare raises."""


class InvalidTokenError(RequestValidationError):
    """Prompt contains token ids outside ``[0, vocab_size)`` — these would
    silently clamp at the embedding gather and serve garbage."""


class DuplicateUidError(RequestValidationError):
    """A submitted uid collides with a pending/active request. In-flight
    uids must be unique: the sampling key is ``fold_in(base, uid)``
    (DESIGN.md §10), so duplicates alias the Gumbel noise stream and two
    supposedly independent sampled generations become bitwise identical."""


class QueueFullError(ServingError):
    """Bounded pending queue is full and the backpressure policy could not
    make room (DESIGN.md §12 shed policy)."""


class DeviceStepError(ServingError):
    """A device step kept failing past the engine's bounded retry budget."""


class NumericHealthError(ServingError):
    """Strict-mode numeric sentinel: a slot produced non-finite logits
    (DESIGN.md §12). In ``count`` mode the engine quarantines the slot
    instead of raising."""


class ArtifactCorruptError(ServingError):
    """A checkpoint's recomputed ``tree_digest`` does not match the digest
    recorded in ``meta.json`` at save time — the artifact bytes were
    corrupted between save and load. Pass ``verify=False`` to load anyway
    (forensics only; never serve an unverified artifact)."""
