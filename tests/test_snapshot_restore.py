"""Snapshot/restore with verified artifacts (DESIGN.md §12): a mid-trace
``Engine.snapshot()`` captures the COMPLETE serving state — scheduler,
slot occupancy, sampling keys, counters, PagedAllocator (free-list order,
refcounts, prefix registry LRU), and both KV pools — so a restored engine
finishes the trace token-for-token identical to the uninterrupted run, in
dense, paged, and speculative modes. Disk snapshots ride the checkpoint
layer and carry a ``tree_digest``; corrupted bytes refuse to load.
"""
import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint as CKPT
from repro.core import compress as CMP
from repro.core import errors as ERR
from repro.models import model as MD
from repro.serving import Engine, EngineConfig
from repro.serving.faults import FaultPlan, FaultSpec

ARCH = "qwen3-moe-30b-a3b"
P, NEW = 8, 10
ARRIVALS = (0.0, 0.0, 5.0)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=P, dtype=np.int32)
               for _ in range(len(ARRIVALS))]
    return cfg, params, ncfg, nparams, prompts


MODES = {
    # kv_block=4 so an 8-token prompt registers a full prefix block: the
    # snapshot then must carry a non-empty registry with its LRU order
    "dense": dict(),
    "paged": dict(kv_layout="paged", kv_block=4),
    "spec": dict(spec_k=4),
}


def _mk(setup, mode, **kw):
    cfg, params, ncfg, nparams, _ = setup
    ec = dict(arch=ARCH, n_slots=2, s_max=32, prefill_buckets=(P,))
    ec.update(MODES[mode])
    ec.update(kw)
    spec = mode == "spec"
    return Engine(EngineConfig(**ec), cfg=cfg, params=params,
                  draft_cfg=ncfg if spec else None,
                  draft_params=nparams if spec else None)


def _submit(eng, prompts):
    for i, (p, a) in enumerate(zip(prompts, ARRIVALS)):
        eng.submit(p, max_new_tokens=NEW, arrival_time=a, uid=i)


def _advance_once(eng, mode):
    return eng.step_spec() if mode == "spec" else eng.step_block()


def _tokens(done):
    return {r.uid: (list(r.out_tokens), r.status) for r in done}


@pytest.mark.parametrize("mode", list(MODES))
def test_restore_finishes_token_for_token(setup, mode):
    """The §12 acceptance bar: interrupt a trace after one fused call
    (two slots mid-stream, one request still queued), restore from the
    snapshot into a FRESH engine, and the union of pre-crash and
    post-restore outputs equals the uninterrupted run bitwise — and the
    continued original engine agrees, so snapshotting itself perturbed
    nothing."""
    cfg, params, ncfg, nparams, prompts = setup
    ref = _mk(setup, mode)
    _submit(ref, prompts)
    want = _tokens(ref.run())
    assert all(st == "ok" for _, st in want.values())

    a = _mk(setup, mode)
    _submit(a, prompts)
    pre = _advance_once(a, mode)          # mid-trace: 2 active + 1 pending
    assert not a.idle
    snap = a.snapshot()
    step_at_snap = a.steps

    b = Engine.restore(snap, cfg=cfg, params=params,
                       draft_cfg=ncfg if mode == "spec" else None,
                       draft_params=nparams if mode == "spec" else None)
    assert b.steps == step_at_snap
    got_b = _tokens(list(pre) + b.run())
    got_a = _tokens(list(pre) + a.run())  # the engine that kept running
    assert got_b == want
    assert got_a == want
    if mode == "paged":
        # restored allocator drained cleanly: nothing owned, every
        # non-free block is pinned by the prefix registry, no leaks
        b._alloc.check_invariants()
        state = b._alloc.state_dict()
        assert not state["owned"]
        pinned = {blk for _, chain in state["registry"] for blk in chain}
        assert b._alloc.free_blocks == b._alloc.nb - len(pinned)
        assert snap["host"]["alloc"]["registry"], "registry not captured"


def test_snapshot_host_part_is_json_safe(setup):
    """The host half of a snapshot must survive a JSON round-trip
    unchanged — that is what lets save_snapshot ship it through the
    checkpoint layer's meta.json extras."""
    eng = _mk(setup, "paged")
    _submit(eng, setup[-1])
    eng.step_block()
    snap = eng.snapshot()
    assert json.loads(json.dumps(snap["host"])) == snap["host"]
    assert snap["host"]["alloc"]["registry"], "prefix registry not captured"
    eng.run()                              # drain so the module moves on


def test_disk_snapshot_roundtrip_and_digest_guard(setup, tmp_path):
    """save_snapshot -> Engine.restore(directory) finishes the trace
    bitwise; a single bit flipped in one leaf file (via the fault plan's
    ckpt site, so the corruption itself is seeded and replayable) fails
    digest verification with ArtifactCorruptError, and verify=False still
    loads it for forensics."""
    cfg, params, _, _, prompts = setup
    ref = _mk(setup, "dense")
    _submit(ref, prompts)
    want = _tokens(ref.run())

    eng = _mk(setup, "dense")
    _submit(eng, prompts)
    pre = eng.step_block()
    committed = eng.save_snapshot(tmp_path / "snap")
    assert (committed / "COMMIT").exists()
    meta = json.loads((committed / "meta.json").read_text())
    assert meta["tree_digest"]

    b = Engine.restore(tmp_path / "snap", cfg=cfg, params=params)
    assert _tokens(list(pre) + b.run()) == want

    # flip the HIGH byte of the last element of the largest leaf (bf16 is
    # stored as f32; a low-bit mantissa flip could round away in the
    # bf16 cast and dodge the digest — the exponent byte cannot)
    leaf = max(committed.glob("leaf_*.npy"), key=lambda p: p.stat().st_size)
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="ckpt", kind="corrupt", steps=(0,),
                  byte_offsets=(-1,)),))
    leaf.write_bytes(plan.corrupt(leaf.read_bytes()))
    with pytest.raises(ERR.ArtifactCorruptError, match="digest"):
        Engine.restore(tmp_path / "snap", cfg=cfg, params=params)
    forensic = Engine.restore(tmp_path / "snap", cfg=cfg, params=params,
                              verify=False)
    assert isinstance(forensic, Engine)


def test_checkpoint_digest_unit(tmp_path):
    """Checkpoint-layer contract, no engine: save records tree_digest in
    meta.json, load verifies it by default, a corrupted leaf raises, and
    verify=False is the explicit forensics escape hatch."""
    tree = {"w": np.arange(32, dtype=np.float32).reshape(4, 8),
            "b": {"x": np.ones(5, np.int32)}}
    d = CKPT.save(tmp_path, 0, tree, extras={"note": "hi"})
    got, extras = CKPT.load(tmp_path)
    assert extras["note"] == "hi"
    np.testing.assert_array_equal(got["w"], tree["w"])

    leaf = sorted(d.glob("leaf_*.npy"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 1
    leaf.write_bytes(bytes(raw))
    with pytest.raises(ERR.ArtifactCorruptError, match="verify=False"):
        CKPT.load(tmp_path)
    got2, _ = CKPT.load(tmp_path, verify=False)
    assert got2["w"].shape == (4, 8)
