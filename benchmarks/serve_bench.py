"""Continuous-batching serving benchmark -> benchmarks/BENCH_serve.json.

Serves an identical Poisson request trace through the engine in two modes —

* **before**: the pre-PR hot loop (``decode_block=1`` step-at-a-time decode,
  ragged dispatch, batch-of-1 admission): one jitted call + one host sync per
  decode STEP;
* **after**: the fused loop (``decode_block=K`` device-resident scan with
  on-device sampling/stop flags, gather-dispatch decode MoE, batched
  same-bucket admission): one call + one sync per K steps —

for both the uncompressed checkpoint and the same weights MergeMoE-compressed
to half the experts, and records tokens/sec, p50/p95 request latency, and
host dispatches per generated token. Every mode pair is asserted
token-for-token identical (greedy), and the JSON carries the parity bits the
CI smoke gate checks. On TPU the compressed rows route fewer, fuller expert
groups through the grouped/gather kernels; on CPU (this container) the jnp
oracles stand in at identical shapes, so the trustworthy CPU signals are the
host-dispatch counts and the fused-loop overhead reduction.

    PYTHONPATH=src python benchmarks/serve_bench.py --requests 16
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.core import compress as CMP
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace

OUT_PATH = Path(__file__).resolve().parent / "BENCH_serve.json"


def run_trace(cfg, params, *, label, decode_block, dispatch, batch_admission,
              requests, prompt_lens, arrivals, max_new_tokens, n_slots, s_max,
              buckets, repeats=3, bench_iters=50, run_bench=True):
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets,
                              decode_block=decode_block, dispatch=dispatch,
                              batch_admission=batch_admission),
                 cfg=cfg, params=params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(l), dtype=np.int32)
               for l in prompt_lens]

    # warmup: compile the decode block and every prefill specialization —
    # each bucket at each power-of-two admission-group size the trace can
    # produce — on throwaway requests before the timed trace
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run()
    for l in sorted(set(eng.bucket_for(len(p)) for p in prompts)):
        for burst in (n_slots, 2, 1):
            for _ in range(burst):
                eng.submit(np.zeros(min(l, s_max - 4), np.int32),
                           max_new_tokens=1)
            eng.run()
    for c in eng.counters:
        eng.counters[c] = 0

    # trace tok/s is host-loop noisy at smoke scale -> best of ``repeats``
    best_dt, done = None, None
    for _ in range(repeats):
        # shift arrivals past the current step clock so the trace stays
        # staggered and latency = finish - arrival holds without an offset
        base = float(eng.steps)
        for i in range(requests):
            eng.submit(prompts[i], max_new_tokens=max_new_tokens,
                       arrival_time=base + float(arrivals[i]))
        t0 = time.perf_counter()
        d = eng.run()
        dt = time.perf_counter() - t0
        if best_dt is None or dt < best_dt:
            best_dt, done = dt, d

    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_finished - r.arrival_time for r in done]
    # parity-isolation runs only need tokens, not a steady-state timing pass
    steady = (eng.bench_decode(iters=bench_iters) if run_bench
              else {"tok_per_s": 0.0, "dispatches_per_s": 0.0,
                    "host_dispatches_per_token": 0.0})
    rec = {
        "label": label,
        "experts": (cfg.moe_merged or cfg.moe.n_experts) if cfg.moe else 0,
        "dispatch": dispatch,
        "decode_block": decode_block,
        "batch_admission": batch_admission,
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(best_dt, 3),
        "tok_per_s": round(toks / best_dt, 1),
        # trace-loop counters cover all repeats (the ratio is what matters)
        "host_dispatches_per_token": round(eng.host_dispatches_per_token, 4),
        "steady_decode_tok_per_s": round(steady["tok_per_s"], 1),
        "steady_dispatches_per_s": round(steady["dispatches_per_s"], 1),
        "steady_host_dispatches_per_token": round(
            steady["host_dispatches_per_token"], 4),
        "mean_latency_steps": round(float(np.mean(lat)), 2),
        "p50_latency_steps": round(float(np.percentile(lat, 50)), 2),
        "p95_latency_steps": round(float(np.percentile(lat, 95)), 2),
    }
    print(f"[{label:>22}] {rec['tok_per_s']:8.1f} tok/s trace  "
          f"{rec['steady_decode_tok_per_s']:8.1f} tok/s steady  "
          f"{rec['host_dispatches_per_token']:.3f} disp/tok  "
          f"(p95 latency {rec['p95_latency_steps']} steps)")
    # tokens in submission order (uids are per-engine; position is the
    # cross-engine-stable key, and repeats are deterministic replicas)
    tokens = [list(r.out_tokens) for r in sorted(done, key=lambda r: r.uid)]
    return rec, tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="fused K (the 'after' engine)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--bench-iters", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    M = cfg.moe.n_experts // 2
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=M, split=0,
        batches=calib)

    rng = np.random.default_rng(args.seed + 1)
    lens = rng.choice([8, 16, 24, 32], size=args.requests)
    lens = np.minimum(lens, args.s_max - args.max_new_tokens - 1)
    arrivals = poisson_trace(args.requests, rate=args.rate,
                             seed=args.seed + 2)
    common = dict(requests=args.requests, prompt_lens=lens, arrivals=arrivals,
                  max_new_tokens=args.max_new_tokens, n_slots=args.n_slots,
                  s_max=args.s_max, buckets=(8, 16, 24, 32),
                  repeats=args.repeats, bench_iters=args.bench_iters)
    K = args.decode_block
    before = dict(decode_block=1, dispatch="ragged", batch_admission=False)
    after = dict(decode_block=K, dispatch="gather", batch_admission=True)

    print(f"== serve_bench: {args.requests} requests, Poisson rate "
          f"{args.rate}/step, {args.n_slots} slots, K={K} ==")
    rows, toks = {}, {}
    for tag, c, p in (("full", cfg, params), ("compressed", ncfg, nparams)):
        rb, tb = run_trace(c, p, label=f"{tag}/before(K1,ragged)",
                           **before, **common)
        ra, ta = run_trace(c, p, label=f"{tag}/after(K{K},gather)",
                           **after, **common)
        # gather==ragged isolation at the same fused K, and batched==serial
        # admission isolation at the same dispatch
        rr, tr = run_trace(c, p, label=f"{tag}/after(K{K},ragged)",
                           **dict(after, dispatch="ragged"),
                           **dict(common, repeats=1, run_bench=False))
        rs, ts = run_trace(c, p, label=f"{tag}/after(serial-admit)",
                           **dict(after, batch_admission=False),
                           **dict(common, repeats=1, run_bench=False))
        rows[tag] = {"before": rb, "after": ra}
        toks[tag] = {"before": tb, "after": ta, "ragged": tr, "serial": ts}

    parity = {
        "fused_vs_step_bitwise": all(
            toks[t]["before"] == toks[t]["after"] for t in toks),
        "gather_vs_ragged_bitwise": all(
            toks[t]["after"] == toks[t]["ragged"] for t in toks),
        "batched_vs_serial_admission_bitwise": all(
            toks[t]["after"] == toks[t]["serial"] for t in toks),
    }
    fb, fa = rows["full"]["before"], rows["full"]["after"]
    cb, ca = rows["compressed"]["before"], rows["compressed"]["after"]
    summary = {
        "arch": args.arch,
        "n_slots": args.n_slots,
        "decode_block": K,
        "requests": args.requests,
        "max_new_tokens": args.max_new_tokens,
        "full": rows["full"],
        "compressed": rows["compressed"],
        "parity": parity,
        "compression_ratio": round(info["compression_ratio"], 3),
        "speedup": {
            "host_dispatch_reduction_fused": round(
                fb["host_dispatches_per_token"]
                / fa["host_dispatches_per_token"], 2),
            "steady_dispatch_reduction_fused": round(
                fb["steady_host_dispatches_per_token"]
                / fa["steady_host_dispatches_per_token"], 2),
            "steady_tok_per_s_fused": round(
                fa["steady_decode_tok_per_s"]
                / fb["steady_decode_tok_per_s"], 3),
            "trace_tok_per_s_fused": round(
                fa["tok_per_s"] / fb["tok_per_s"], 3),
            "steady_tok_per_s_compressed_after": round(
                ca["steady_decode_tok_per_s"]
                / fa["steady_decode_tok_per_s"], 3),
            "trace_tok_per_s_compressed_after": round(
                ca["tok_per_s"] / fa["tok_per_s"], 3),
        },
    }
    print(f"== fused K={K}: {summary['speedup']['host_dispatch_reduction_fused']}x "
          f"fewer host dispatches/token on the trace "
          f"({summary['speedup']['steady_dispatch_reduction_fused']}x steady), "
          f"{summary['speedup']['trace_tok_per_s_fused']}x trace tok/s, "
          f"{summary['speedup']['steady_tok_per_s_fused']}x steady tok/s ==")
    print(f"== parity {parity} ==")
    OUT_PATH.write_text(json.dumps(summary, indent=1))
    print(f"wrote {OUT_PATH}")
    if args.json:
        print(json.dumps(summary, indent=1))
    if not all(parity.values()):
        raise SystemExit("serve_bench parity check FAILED: " + repr(parity))


if __name__ == "__main__":
    main()
