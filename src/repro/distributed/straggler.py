"""Straggler detection + restart policy.

Under SPMD every collective is a barrier, so a slow chip stalls the fleet;
the mitigation at scale is LAUNCHER-level: detect persistent step-time
regression, drain the job, and relaunch on a spare slice (the elastic
checkpoint restore in repro.ckpt makes the relaunch cheap). This module is
the detector + policy half; ``launch.train`` consumes it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Optional


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration_s: float
    median_s: float
    ratio: float
    is_straggler: bool
    consecutive: int
    should_restart: bool


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x rolling median; recommends a
    drain/relaunch after ``patience`` consecutive flagged steps."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 patience: int = 5, warmup: int = 3):
        self.window: Deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.patience = patience
        self.warmup = warmup
        self.consecutive = 0
        self._step = 0
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self) -> StragglerReport:
        assert self._t0 is not None, "start_step() not called"
        dur = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(dur)

    def observe(self, duration_s: float) -> StragglerReport:
        self._step += 1
        if len(self.window) >= self.warmup:
            med = sorted(self.window)[len(self.window) // 2]
            ratio = duration_s / max(med, 1e-9)
            is_straggler = ratio > self.threshold
        else:
            med, ratio, is_straggler = duration_s, 1.0, False
        self.consecutive = self.consecutive + 1 if is_straggler else 0
        # slow steps are NOT added to the window (they'd poison the median)
        if not is_straggler:
            self.window.append(duration_s)
        return StragglerReport(
            step=self._step, duration_s=duration_s, median_s=med,
            ratio=ratio, is_straggler=is_straggler,
            consecutive=self.consecutive,
            should_restart=self.consecutive >= self.patience)
