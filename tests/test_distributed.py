"""Fault-tolerance substrate: gradient compression, straggler policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import (ef_compressed, quantize, dequantize,
                               StragglerMonitor)
from repro.optim import sgd, adamw, apply_updates


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)) * 3.0, jnp.float32)
    q, scale = quantize(g, jax.random.PRNGKey(0))
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) * 1.01   # within one quantization step


def test_ef_compression_converges_like_uncompressed():
    """Error feedback: the quantization bias cancels over steps."""
    target = jnp.asarray([1.0, -2.0, 0.5, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    def run(opt, steps=300):
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        for i in range(steps):
            g = jax.grad(loss)(params)
            u, state = opt.update(g, state, params, jnp.asarray(i))
            params = apply_updates(params, u)
        return float(loss(params))

    base = run(sgd(lr=0.1))
    comp = run(ef_compressed(sgd(lr=0.1)))
    assert comp < 1e-3 and base < 1e-6
    # and with adamw
    comp2 = run(ef_compressed(adamw(lr=3e-2)), steps=400)
    assert comp2 < 1e-2


def test_ef_residual_state_present():
    opt = ef_compressed(sgd(lr=0.1))
    params = {"w": jnp.zeros((3, 3))}
    st = opt.init(params)
    assert "ef" in st and st["ef"]["w"].shape == (3, 3)


def test_straggler_monitor_flags_and_restart():
    mon = StragglerMonitor(window=20, threshold=2.0, patience=3, warmup=3)
    for _ in range(10):
        rep = mon.observe(0.1)
        assert not rep.is_straggler
    r = mon.observe(0.5)
    assert r.is_straggler and not r.should_restart
    mon.observe(0.5)
    r = mon.observe(0.5)
    assert r.should_restart
    # recovery resets the counter
    r = mon.observe(0.1)
    assert r.consecutive == 0 and not r.should_restart


def test_straggler_median_not_poisoned():
    mon = StragglerMonitor(window=10, threshold=2.0, patience=100, warmup=3)
    for _ in range(5):
        mon.observe(0.1)
    for _ in range(5):
        mon.observe(10.0)   # all flagged; median must stay ~0.1
    r = mon.observe(0.1)
    assert not r.is_straggler
