"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
while smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(axis_sizes, axis_names):
    """Version-compat AbstractMesh constructor.

    JAX <= 0.4.x takes ``AbstractMesh(shape_tuple=(("data", 16), ...))``;
    newer releases take ``AbstractMesh(axis_sizes, axis_names)``. Spec
    derivation (sharding rules, dry-run lowering) only needs shape + names,
    so either form is equivalent.
    """
    import inspect
    from jax.sharding import AbstractMesh

    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    if len(axis_sizes) != len(axis_names):
        raise ValueError(f"{len(axis_sizes)} sizes vs {len(axis_names)} names")
    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(axis_sizes, axis_names)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes carrying batch data-parallelism (pod included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
