"""Continuous-batching serving engine.

Replaces the fixed-batch loop (``launch.serve.FixedBatchServer``) with
request-level scheduling, the deployment path the paper's serving claim is
about: merged checkpoints route fewer, fuller expert groups through the
grouped kernel at identical arithmetic.

Design:

* **Slots.** The engine owns a persistent slotted KV cache
  (``[L, n_slots, s_max, nkv, hd]`` + per-slot ``pos``). A request occupies
  one slot from admission to completion; eviction just marks the slot free —
  stale rows are masked by the per-slot causal mask and overwritten in place
  by the next occupant (no copying, no reallocation).
* **Admission.** Pending requests are FIFO by arrival time. At the top of
  every engine step, each free slot admits the next due request: the prompt
  is right-padded to a small set of bucket lengths (bounding jit
  specializations), prefilled as a batch of one, and its KV inserted into the
  slot. The prefill logits yield the request's first generated token.
* **Decode.** One jitted step advances ALL occupied slots together at their
  own positions. Idle slots ride along (static shapes) without advancing
  ``pos``. With ``dispatch='ragged'`` the MoE layers sort the slot tokens by
  expert and run the grouped SwiGLU kernel — the path where MergeMoE's
  smaller expert count means fewer, fuller groups.
* **Stop conditions.** Per-request ``max_new_tokens`` and optional
  ``eos_token``; finished requests free their slot for the next admission at
  the following step.

The clock is pluggable: ``clock='steps'`` interprets ``arrival_time`` in
decode-step units (deterministic — used by tests and the CPU benchmark),
``clock='wall'`` in seconds.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-filled result/telemetry."""
    uid: int
    prompt: np.ndarray                  # [prompt_len] int32
    max_new_tokens: int
    eos_token: Optional[int] = None
    arrival_time: float = 0.0           # steps or seconds, per engine clock
    # engine-filled
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    finish_reason: Optional[str] = None  # "length" | "eos"

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class EngineConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    n_slots: int = 4
    s_max: int = 128                    # per-slot KV capacity
    prefill_buckets: Sequence[int] = (16, 32, 64)
    temperature: float = 0.0
    seed: int = 0
    # MoE dispatch for the serving path; "ragged" routes decode through the
    # grouped kernel. None keeps whatever the ModelConfig says.
    dispatch: Optional[str] = "ragged"
    clock: str = "steps"                # "steps" | "wall"


class Engine:
    """Continuous-batching engine over a slotted KV cache."""

    def __init__(self, ec: EngineConfig, cfg=None, params=None):
        self.ec = ec
        cfg = cfg if cfg is not None else (
            configs.get(ec.arch).reduced() if ec.reduced
            else configs.get(ec.arch))
        if cfg.moe is not None and ec.dispatch is not None:
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      dispatch=ec.dispatch))
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"continuous batching serves token-only families "
                f"(dense/moe), not {cfg.family}")
        self.cfg = cfg
        mesh = make_host_mesh()
        set_activation_mesh(mesh)
        self.params = params if params is not None else MD.init(
            cfg, jax.random.PRNGKey(ec.seed))

        self._prefill = jax.jit(ST.make_slot_prefill(cfg))
        self._insert = jax.jit(ST.make_slot_insert(cfg))
        self._decode = jax.jit(ST.make_slot_decode(cfg))
        self.cache = MD.init_slot_cache(cfg, ec.n_slots, ec.s_max)

        self._buckets = tuple(sorted(set(int(b) for b in ec.prefill_buckets)))
        self._slot_req: List[Optional[Request]] = [None] * ec.n_slots
        self._last_tok = np.zeros((ec.n_slots,), np.int32)
        self._active = np.zeros((ec.n_slots,), bool)
        # kept sorted by (arrival_time, uid) so admission is FIFO by arrival
        # regardless of submission order
        self._pending: List[Request] = []
        self._next_uid = 0
        self._step_count = 0
        self._t0: Optional[float] = None
        self._rng = np.random.default_rng(ec.seed)
        # plan/report extras when booted via from_checkpoint
        self.artifact: Optional[dict] = None

    # ------------------------------------------------------------------ API

    @classmethod
    def from_checkpoint(cls, directory, ec: Optional[EngineConfig] = None,
                        step: int | None = None) -> "Engine":
        """Boot an engine directly from a ``save_compressed`` artifact.

        The artifact's own ModelConfig (including per-layer merged-expert
        counts) and parameters are used verbatim; ``ec`` only controls
        serving knobs (slots, buckets, dispatch — ragged by default). The
        executed plan and compression report are exposed as
        ``engine.artifact``."""
        from repro.ckpt import checkpoint as CKPT
        cfg, params, artifact = CKPT.load_compressed(directory, step=step)
        if ec is None:
            ec = EngineConfig(arch=cfg.name, reduced=False)
        eng = cls(ec, cfg=cfg, params=params)
        eng.artifact = artifact
        return eng

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def idle(self) -> bool:
        return not self._pending and not self._active.any()

    @property
    def steps(self) -> int:
        """Decode steps taken so far (the 'steps' clock's current time)."""
        return self._step_count

    def submit(self, prompt, max_new_tokens: int, eos_token: int | None = None,
               arrival_time: float = 0.0, uid: int | None = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_new_tokens > self.ec.s_max:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds slot capacity s_max={self.ec.s_max}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if uid is None:
            uid = self._next_uid
        self._next_uid = max(self._next_uid, uid) + 1
        req = Request(uid=uid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_token=eos_token, arrival_time=arrival_time)
        bisect.insort(self._pending, req,
                      key=lambda r: (r.arrival_time, r.uid))
        return req

    def step(self, now: float | None = None) -> List[Request]:
        """Admit due requests, run one decode step, evict finished.
        Returns the requests that finished during this step."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        if self._active.any():
            toks = jnp.asarray(self._last_tok)
            act = jnp.asarray(self._active)
            logits, greedy, self.cache = self._decode(
                self.params, self.cache, toks, act)
            next_toks = self._sample(logits, greedy)
            for slot in np.flatnonzero(self._active):
                req = self._slot_req[slot]
                tok = int(next_toks[slot])
                req.out_tokens.append(tok)
                self._last_tok[slot] = tok
                if self._is_done(req, tok):
                    self._evict(slot, now)
                    finished.append(req)
        self._step_count += 1
        return finished

    def run(self, requests: Sequence[Request] | None = None) -> List[Request]:
        """Drive until every pending/submitted request completes."""
        if requests:
            for r in requests:
                bisect.insort(self._pending, r,
                              key=lambda q: (q.arrival_time, q.uid))
        done: List[Request] = []
        while not self.idle:
            done.extend(self.step())
        return sorted(done, key=lambda r: r.uid)

    def bench_decode(self, iters: int = 50) -> float:
        """Steady-state decode throughput (tokens/sec) with every slot
        active, bypassing admission — isolates the jitted model step (the
        grouped-kernel path) from scheduler overhead. Does not disturb
        engine bookkeeping: runs on a scratch copy of the cache."""
        n = self.ec.n_slots
        cache = jax.tree.map(jnp.copy, self.cache)
        cache["pos"] = jnp.full((n,), self.ec.s_max // 2, jnp.int32)
        toks = jnp.zeros((n,), jnp.int32)
        act = jnp.ones((n,), bool)
        _, greedy, cache = self._decode(self.params, cache, toks, act)  # warm
        greedy.block_until_ready()
        cache["pos"] = jnp.full((n,), self.ec.s_max // 2, jnp.int32)
        t0 = time.perf_counter()
        for _ in range(iters):
            cache["pos"] = jnp.minimum(cache["pos"], self.ec.s_max - 1)
            _, greedy, cache = self._decode(self.params, cache, toks, act)
        greedy.block_until_ready()
        dt = time.perf_counter() - t0
        return n * iters / dt

    # ------------------------------------------------------------ internals

    def _now(self) -> float:
        if self.ec.clock == "steps":
            return float(self._step_count)
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def bucket_for(self, n: int) -> int:
        """Prefill pad length for an ``n``-token prompt (the jit
        specialization it will compile into). Clamped to ``s_max`` so a
        bucket never outgrows the slot it is inserted into (``submit``
        guarantees the prompt itself fits)."""
        for b in self._buckets:
            if n <= b:
                return min(b, self.ec.s_max)
        big = self._buckets[-1] if self._buckets else 1
        return min(-(-n // big) * big, self.ec.s_max)

    def _sample(self, logits, greedy) -> np.ndarray:
        if self.ec.temperature <= 0.0:
            return np.asarray(greedy)
        lg = np.asarray(logits, np.float64) / self.ec.temperature
        g = self._rng.gumbel(size=lg.shape)
        return np.argmax(lg + g, axis=-1).astype(np.int32)

    def _is_done(self, req: Request, tok: int) -> bool:
        if req.eos_token is not None and tok == req.eos_token:
            req.finish_reason = "eos"
            return True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _admit(self, now: float) -> List[Request]:
        """Fill free slots with due pending requests (prefill + insert +
        first token). Returns requests that finish AT admission (e.g.
        max_new_tokens == 1)."""
        finished: List[Request] = []
        free = [s for s in range(self.ec.n_slots) if not self._active[s]]
        while free and self._pending \
                and self._pending[0].arrival_time <= now:
            req = self._pending.pop(0)
            slot = free.pop(0)
            bucket = self.bucket_for(req.n_prompt)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :req.n_prompt] = req.prompt
            logits, k_new, v_new = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray([req.n_prompt], jnp.int32))
            self.cache = self._insert(
                self.cache, jnp.asarray(slot, jnp.int32), k_new, v_new,
                jnp.asarray(req.n_prompt, jnp.int32))
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = int(self._sample(logits, greedy)[0])
            req.out_tokens.append(tok)
            req.t_admitted = now
            req.t_first_token = now
            self._slot_req[slot] = req
            self._last_tok[slot] = tok
            self._active[slot] = True
            if self._is_done(req, tok):
                self._evict(slot, now)
                finished.append(req)
        return finished

    def _evict(self, slot: int, now: float) -> None:
        req = self._slot_req[slot]
        if req is not None:
            req.t_finished = now
        self._slot_req[slot] = None
        self._active[slot] = False


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def poisson_trace(n_requests: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests per clock
    unit: decode steps or seconds, matching the engine clock)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n_requests)
    return np.cumsum(gaps)
