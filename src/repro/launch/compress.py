"""MergeMoE compression driver: train-or-load -> calibrate -> plan -> merge
-> eval -> (optionally) persist a loadable artifact.

    # legacy uniform surface
    PYTHONPATH=src python -m repro.launch.compress --arch qwen3-moe-30b-a3b \
        --method mergemoe --merged-experts 4 --eval-batches 4

    # declarative plan from disk
    PYTHONPATH=src python -m repro.launch.compress --plan plan.json

    # budget-driven: allocate per-layer M from calibration stats
    PYTHONPATH=src python -m repro.launch.compress --target-ratio 1.4 \
        --save-dir /tmp/qwen3_c      # artifact for Engine.from_checkpoint

Reports the paper's headline quantities: bytes before/after, per-method
held-out loss, merge wall-time (Fig. 3 analogue), plus the executed per-layer
plan and eval wall-time.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import configs
from repro.core import calibration as CAL
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.models import model as MD

# ONE jitted loss fn for every evaluation in this process: the config rides
# as a static argument, so calling it with the base and the compressed model
# reuses the same callable (each distinct cfg traces once, instead of the old
# eval_loss re-jitting from scratch on every call).
_EVAL_LOSS = jax.jit(lambda cfg, p, b: MD.loss(cfg, p, b)[0],
                     static_argnums=0)


def eval_loss(cfg, params, batches) -> float:
    losses = [float(_EVAL_LOSS(cfg, params, b)) for b in batches]
    return float(np.mean(losses))


def make_batches(cfg, n, batch=4, seq=64, seed=0):
    out = []
    for i in range(n):
        key = jax.random.PRNGKey(seed + i)
        out.append({"tokens": jax.random.randint(
            key, (batch, seq), 0, cfg.vocab_size)})
    return out


def build_plan(cfg, *, plan_path=None, target_ratio=None, method="mergemoe",
               merged_experts=4, split=None, stream=None,
               weight_dtype="bf16"):
    """Resolve the CLI's three plan sources, most declarative first.
    ``weight_dtype`` applies to the built plan (a plan file keeps its own)."""
    if plan_path:
        return PLAN.CompressionPlan.load(plan_path).validate(cfg)
    if target_ratio:
        stats = stream.stats() if stream is not None else None
        return PLAN.for_target_ratio(cfg, target_ratio=target_ratio,
                                     stats=stats, method=method, split=split,
                                     weight_dtype=weight_dtype)
    return PLAN.uniform(cfg, method=method, merged_experts=merged_experts,
                        split=split, weight_dtype=weight_dtype)


def run(arch: str, method: str = "mergemoe", merged_experts: int = 4,
        split=None, calib_batches: int = 2, eval_batches: int = 4,
        params=None, cfg=None, seed: int = 0, plan=None, plan_path=None,
        target_ratio=None, max_calib_tokens=None, save_dir=None,
        mesh_spec=None, weight_dtype: str = "bf16"):
    cfg = cfg if cfg is not None else configs.get(arch).reduced()
    if params is None:
        params = MD.init(cfg, jax.random.PRNGKey(seed))
    calib = make_batches(cfg, calib_batches, seed=seed + 100)
    evalb = make_batches(cfg, eval_batches, seed=seed + 200)

    # mesh-parallel compression: DP capture over "data", solve shards over
    # "model" — bit-for-bit equal to the single-device run (DESIGN.md §6)
    mesh = None
    if mesh_spec is not None:
        from repro.launch import mesh as MESH
        mesh = MESH.make_compression_mesh(mesh_spec)

    t0 = time.perf_counter()
    base_loss = eval_loss(cfg, params, evalb)
    t_eval_base = time.perf_counter() - t0

    # calibrate ONCE: the same stream feeds the budget planner's stats and
    # the per-layer merges
    stream = CAL.CalibrationStream(cfg, params,
                                   max_tokens_per_layer=max_calib_tokens,
                                   seed=seed, mesh=mesh).consume(calib)
    if plan is None:
        plan = build_plan(cfg, plan_path=plan_path, target_ratio=target_ratio,
                          method=method, merged_experts=merged_experts,
                          split=split, stream=stream,
                          weight_dtype=weight_dtype)

    t0 = time.perf_counter()
    new_cfg, new_params, info = CMP.compress_with_plan(
        cfg, params, plan, stream=stream, mesh=mesh)
    t_total = time.perf_counter() - t0

    t0 = time.perf_counter()
    comp_loss = eval_loss(new_cfg, new_params, evalb)
    t_eval_comp = time.perf_counter() - t0

    if save_dir:
        from repro.ckpt import checkpoint as CKPT
        CKPT.save_compressed(save_dir, new_cfg, new_params,
                             plan=plan.with_mesh(mesh), report=info)

    report = {
        "arch": arch, "method": info["method"],
        "plan": info["plan"],
        "mesh": info["mesh"],
        "weight_dtype": info["weight_dtype"],
        "n_experts": info["n_experts"],
        "merged_experts": info["merged_experts"],
        "merged_per_layer": info["merged_per_layer"],
        "layers_merged": info["layers_merged"],
        "calib_tokens": info["calib_tokens"],
        "bytes_original": info["bytes_original"],
        "bytes_compressed": info["bytes_compressed"],
        "compression_ratio": round(info["compression_ratio"], 4),
        "t_merge_s": round(info["t_merge_s"], 3),
        "t_total_s": round(t_total, 3),
        "t_eval_base_s": round(t_eval_base, 3),
        "t_eval_compressed_s": round(t_eval_comp, 3),
        "t_eval_s": round(t_eval_base + t_eval_comp, 3),
        "loss_full": round(base_loss, 4),
        "loss_compressed": round(comp_loss, 4),
        "loss_delta": round(comp_loss - base_loss, 4),
    }
    if save_dir:
        report["artifact"] = str(save_dir)
    return new_cfg, new_params, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--plan", default=None, metavar="PLAN_JSON",
                    help="execute a CompressionPlan from disk "
                         "(overrides --method/--merged-experts/--split)")
    ap.add_argument("--target-ratio", type=float, default=None,
                    help="budget-driven planning: allocate per-layer M from "
                         "calibration stats to hit this compression ratio")
    ap.add_argument("--method", default="mergemoe",
                    choices=PLAN.available_methods())
    ap.add_argument("--weight-dtype", default="bf16",
                    choices=PLAN.WEIGHT_DTYPES,
                    help="storage dtype for the merged expert tables: int8 "
                         "halves decode HBM traffic on top of merging "
                         "(DESIGN.md §8); ignored when --plan is given "
                         "(the plan file carries its own)")
    ap.add_argument("--merged-experts", type=int, default=4)
    ap.add_argument("--split", type=int, default=None)
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--max-calib-tokens", type=int, default=None,
                    help="calibration reservoir cap per layer (bounds host "
                         "memory; default keeps every token)")
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--save-dir", default=None,
                    help="persist the compressed artifact "
                         "(Engine.from_checkpoint loads it)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="device mesh for the compression pipeline, e.g. "
                         "'data=4' (DP capture) or 'data=2,model=2' (DP "
                         "capture + sharded solves); bit-for-bit equal to "
                         "the single-device run (DESIGN.md §6)")
    args = ap.parse_args()
    _, _, report = run(args.arch, args.method, args.merged_experts,
                       split=args.split, calib_batches=args.calib_batches,
                       eval_batches=args.eval_batches, plan_path=args.plan,
                       target_ratio=args.target_ratio,
                       max_calib_tokens=args.max_calib_tokens,
                       save_dir=args.save_dir, mesh_spec=args.mesh,
                       weight_dtype=args.weight_dtype)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
