"""Numerical verification of Theorem 1 (Appendix A) with hypothesis."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import clustering as CL
from repro.core import theory as TH


def _random_instance(rng, N, M, K):
    assign = rng.integers(0, M, size=N)
    # every cluster non-empty
    assign[:M] = np.arange(M)
    f = rng.random(N) * 10 + 0.1
    Y0 = rng.standard_normal((K, N))
    W = Y0.T @ Y0
    A = CL.summation_matrix(assign.astype(np.int32), M)
    return assign, f, Y0, W, A


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), N=st.integers(4, 12),
       M=st.integers(2, 4), K=st.integers(2, 8))
def test_theorem1_frequency_weights_minimize(seed, N, M, K):
    """The frequency-weighted B is a minimum: any perturbation of the
    within-cluster weights does not decrease the objective."""
    rng = np.random.default_rng(seed)
    assign, f, Y0, W, A = _random_instance(rng, N, M, K)
    B_opt = TH.optimal_B(assign.astype(np.int32), f, M)
    j_opt = TH.objective(B_opt, A, W, f)
    for _ in range(8):
        delta = rng.standard_normal(B_opt.shape) * 0.1
        delta[B_opt == 0] = 0.0                       # keep support pattern
        B_pert = B_opt + delta
        j_pert = TH.objective(B_pert, A, W, f)
        assert j_pert >= j_opt - 1e-9 * max(1.0, abs(j_opt))


def test_objective_zero_when_identity():
    """M == N with identity clustering -> B A = I -> zero error."""
    N = 6
    assign = np.arange(N, dtype=np.int32)
    f = np.ones(N)
    Y0 = np.random.default_rng(0).standard_normal((4, N))
    W = Y0.T @ Y0
    A = CL.summation_matrix(assign, N)
    B = TH.optimal_B(assign, f, N)
    assert abs(TH.objective(B, A, W, f)) < 1e-9


def test_quasi_frobenius():
    Y = np.asarray([[3.0, 0.0], [4.0, 2.0]])
    np.testing.assert_allclose(TH.quasi_frobenius(Y), [25.0, 4.0])


def test_output_error_decreases_with_more_clusters():
    rng = np.random.default_rng(3)
    N, K = 8, 5
    Y = rng.standard_normal((K, N))
    r = np.abs(rng.standard_normal(N))
    f = np.abs(rng.standard_normal(N)) + 0.1
    errs = []
    for M in (2, 4, 8):
        feats_g = rng.standard_normal((N, 16))
        assign = CL.cluster_experts(
            feats_g.reshape(N, 4, 4), feats_g.reshape(N, 4, 4), f, M)
        A = CL.summation_matrix(assign, M)
        B = CL.mixing_matrix(assign, f, M)
        errs.append(TH.output_error(Y, B, A, r))
    assert errs[-1] < 1e-9                  # M == N exact
    assert errs[0] >= errs[1] - 1e-9        # coarser is worse (or equal)
