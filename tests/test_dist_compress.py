"""Mesh-parallel compression: the bit-for-bit differential harness.

Three layers of evidence that sharded ``compress_with_plan`` equals the
single-device run (DESIGN.md §6):

1. DIFFERENTIAL (subprocess): the same compression job runs on 1 device and
   on a forced 4-device host platform (pure-DP and DP x expert-shard
   meshes); tables, remaps, live counts, and the canonical report must be
   IDENTICAL — digests compared across process boundaries.
2. ALGEBRAIC (host-only): the reservoir replacement schedule is a pure
   function of the global token index, so folding ANY partition of a token
   stream in ANY order and merging per-slot must equal one sequential fold —
   property-tested over random partitions.
3. EXECUTOR (host-only): ``shard_layer_solves`` gathers results by index,
   so any shard count returns the sequential list.

In-process multi-device cases run only under ``scripts/test.sh --dist``
(forced 4-device parent, REPRO_DIST=1); everything else runs in the default
tier-1 lane.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import calibration as CAL
from repro.distributed import shard_layer_solves

REPO = Path(__file__).resolve().parents[1]


def _run_child(mesh=None, devices=None):
    # inherit the real environment (CI runners need their PATH/HOME/python
    # setup intact) and override only the knobs under test. JAX_PLATFORMS=cpu:
    # without it, a container with libtpu installed spends minutes retrying
    # GCP metadata probes before falling back to CPU.
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    cmd = [sys.executable, "tests/_dist_compress_child.py"]
    if mesh:
        cmd += ["--mesh", mesh]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=str(REPO), timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    return json.loads(r.stdout)


# ---------------------------------------------------------------------------
# 1. differential: sharded == single-device, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_mesh_compression_bit_identical_to_single_device():
    """Uniform AND heterogeneous plans compress to bit-identical tables,
    remaps, live counts, and reports on a 4-device mesh vs one device."""
    single = _run_child()
    assert single["devices"] == 1
    data4 = _run_child(mesh="data=4", devices=4)
    assert data4["devices"] == 4
    mixed = _run_child(mesh="data=2,model=2", devices=4)
    for name in ("uniform", "hetero"):
        assert data4[name] == single[name], \
            f"{name}: pure-DP mesh diverged from single device"
        assert mixed[name] == single[name], \
            f"{name}: DP x expert-shard mesh diverged from single device"
    # the reports really carry content (not vacuously-equal empties)
    assert single["hetero"]["report"]["merged_per_layer"] == [4, 2]
    assert any(e["resid"] for e in single["hetero"]["report"]["per_layer"])


# ---------------------------------------------------------------------------
# 2. reservoir shard-merge determinism (host-only, property-based)
# ---------------------------------------------------------------------------

def _sequential_fold(xi, cap, seed, policy="reservoir"):
    L, T, d = xi.shape
    x = np.zeros((L, cap, d), np.float32)
    slot_g = np.full(cap, -1, np.int64)
    CAL.fold_tokens(x, slot_g, xi, np.arange(T, dtype=np.int64),
                    cap=cap, seed=seed, policy=policy)
    return x, slot_g


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=5, max_value=40))
def test_reservoir_partition_invariance(seed, n_shards, cap):
    """Folding any contiguous partition of the stream, in any shard order,
    then merging, equals the sequential fold — the determinism argument the
    mesh-parallel calibration capture rests on."""
    rng = np.random.default_rng(seed)
    T = int(rng.integers(cap, 4 * cap + 8))
    xi = rng.standard_normal((2, T, 3)).astype(np.float32)
    ref_x, ref_g = _sequential_fold(xi, cap, seed)

    cuts = np.sort(rng.integers(0, T + 1, size=n_shards - 1))
    bounds = [0, *cuts.tolist(), T]
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        x = np.zeros((2, cap, 3), np.float32)
        slot_g = np.full(cap, -1, np.int64)
        if hi > lo:
            CAL.fold_tokens(x, slot_g, xi[:, lo:hi],
                            np.arange(lo, hi, dtype=np.int64),
                            cap=cap, seed=seed)
        parts.append((x, slot_g))
    rng.shuffle(parts)                      # merge order must not matter
    got_x, got_g = CAL.merge_reservoirs(parts)
    np.testing.assert_array_equal(got_g, ref_g)
    np.testing.assert_array_equal(got_x, ref_x)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_reservoir_fold_is_order_independent(seed):
    """Folding shard chunks into ONE state in reversed order still matches
    the sequential fold (last-write-wins is by global index, not call order)."""
    rng = np.random.default_rng(seed)
    cap, T = 16, 50
    xi = rng.standard_normal((1, T, 2)).astype(np.float32)
    ref_x, ref_g = _sequential_fold(xi, cap, seed)
    x = np.zeros((1, cap, 2), np.float32)
    slot_g = np.full(cap, -1, np.int64)
    for lo, hi in [(30, 50), (0, 15), (15, 30)]:
        CAL.fold_tokens(x, slot_g, xi[:, lo:hi],
                        np.arange(lo, hi, dtype=np.int64), cap=cap, seed=seed)
    np.testing.assert_array_equal(slot_g, ref_g)
    np.testing.assert_array_equal(x, ref_x)


def test_reservoir_is_uniform_enough():
    """Sanity on the counter-based Algorithm R: every slot is claimed, and
    late-stream tokens survive at roughly cap/T rate (not systematically
    dropped — the property that makes the sample uniform over the stream)."""
    cap, T = 64, 4096
    slots = CAL.reservoir_slots(np.arange(T, dtype=np.int64), cap, seed=7)
    kept = slots >= 0
    assert kept[:cap].all()                       # fill phase keeps everything
    tail = kept[T // 2:]
    expect = cap * np.log(2)                      # sum_{g>T/2} cap/g ≈ cap ln 2
    assert 0.4 * expect < tail.sum() < 2.5 * expect
    assert set(slots[kept][-200:]) <= set(range(cap))


# ---------------------------------------------------------------------------
# 3. sharded solve executor (host-only)
# ---------------------------------------------------------------------------

def test_shard_layer_solves_matches_sequential_any_shard_count():
    thunks = [lambda i=i: np.arange(i, i + 4) * (i + 1) for i in range(7)]
    ref, _ = shard_layer_solves(thunks, 1)
    for n in (2, 3, 7, 16):
        got, stats = shard_layer_solves(thunks, n)
        assert stats["n_shards"] == n
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(a, b)


def test_shard_layer_solves_propagates_errors():
    def boom():
        raise RuntimeError("solve failed")
    with pytest.raises(RuntimeError, match="solve failed"):
        shard_layer_solves([lambda: 1, boom, lambda: 3], 2)
    with pytest.raises(ValueError):
        shard_layer_solves([lambda: 1], 0)


# ---------------------------------------------------------------------------
# in-process multi-device cases (scripts/test.sh --dist lane)
# ---------------------------------------------------------------------------

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs a forced 4-device host platform (scripts/test.sh --dist)")


@pytest.mark.distributed
@needs_devices
def test_mesh_capture_matches_single_stream_in_process():
    """CalibrationStream(mesh=...) reproduces the unsharded stream bitwise:
    same reservoir rows, same slot schedule, same counts."""
    from repro import configs
    from repro.launch import mesh as MESH
    from repro.models import model as MD

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (8, 16),
                                             0, cfg.vocab_size)}
               for i in range(2)]
    ref = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=48,
                                seed=11).consume(batches)
    mesh = MESH.make_compression_mesh("data=4")
    got = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=48,
                                seed=11, mesh=mesh).consume(batches)
    rx, rg = ref.reservoir_state()
    gx, gg = got.reservoir_state()
    np.testing.assert_array_equal(gg, rg)
    np.testing.assert_array_equal(gx, rx)
    for l in range(cfg.n_layers):
        np.testing.assert_array_equal(got.counts(l), ref.counts(l))


@pytest.mark.distributed
@needs_devices
def test_mesh_capture_uncapped_and_nondivisible_batch():
    """Uncapped streams gather every token in order; a batch dim that does
    not divide the data axis falls back to replicated capture (divisibility
    drop) without changing the captured values."""
    from repro import configs
    from repro.launch import mesh as MESH
    from repro.models import model as MD

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    mesh = MESH.make_compression_mesh("data=4")
    for B in (8, 6):                          # 6 does not divide data=4
        batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(9),
                                                 (B, 16), 0, cfg.vocab_size)}]
        ref = CAL.CalibrationStream(cfg, params).consume(batches)
        got = CAL.CalibrationStream(cfg, params, mesh=mesh).consume(batches)
        assert got.n_tokens == ref.n_tokens == B * 16
        for l in range(cfg.n_layers):
            np.testing.assert_array_equal(got.layer(l).x, ref.layer(l).x)
            np.testing.assert_array_equal(got.layer(l).counts,
                                          ref.layer(l).counts)
