"""Tests run with the DEFAULT single CPU device (the dry-run's 512-device
XLA flag must never leak here). The ONE sanctioned exception is the
distributed lane: ``scripts/test.sh --dist`` forces a 4-device host platform
for the distributed-marked cases and marks the intent with REPRO_DIST=1."""
import os

assert ("xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")
        or os.environ.get("REPRO_DIST") == "1"), \
    "tests must not inherit a forced device-count flag (scripts/test.sh " \
    "--dist sets REPRO_DIST=1 for the distributed lane)"

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
