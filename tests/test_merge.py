"""MergeMoE core: merge math, baselines, end-to-end compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import clustering as CL
from repro.core import compress as CMP
from repro.core import merge as MG
from repro.core.errors import TechniqueInapplicable
from repro.models import model as MD


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    moe = params["stack"]["moe"]
    wg = np.asarray(moe["wg"][0], np.float32)
    wu = np.asarray(moe["wu"][0], np.float32)
    wd = np.asarray(moe["wd"][0], np.float32)
    X = np.random.default_rng(0).standard_normal(
        (512, cfg.d_model)).astype(np.float32)
    counts = np.random.default_rng(1).random(cfg.moe.n_experts) * 100
    return cfg, params, wg, wu, wd, X, counts


def _cluster_err(res, wg, wu, wd, X):
    errs = []
    for c in range(res.wg.shape[0]):
        members = np.where(res.assign == c)[0]
        Z = sum(res.weights[j] * MG.expert_forward(
            X.astype(np.float64), wg[j].astype(np.float64),
            wu[j].astype(np.float64), wd[j].astype(np.float64))
            for j in members)
        Y = MG.expert_forward(X.astype(np.float64), res.wg[c], res.wu[c],
                              res.wd[c])
        errs.append(np.linalg.norm(Y - Z) / (np.linalg.norm(Z) + 1e-12))
    return float(np.mean(errs))


def test_identity_merge_is_exact(setup):
    cfg, _, wg, wu, wd, X, counts = setup
    N = cfg.moe.n_experts
    res = MG.merge_mergemoe(wg, wu, wd, counts, X, N)
    np.testing.assert_allclose(res.wg, wg, atol=1e-4)
    np.testing.assert_allclose(res.wd, wd, atol=1e-4)
    assert (res.remap == np.arange(N)).all()


def test_literal_t1_equals_simplified(setup):
    """Paper's T1 = Q P† construction == direct lstsq(P, Z) (DESIGN.md §1)."""
    _, _, wg, wu, wd, X, counts = setup
    r1 = MG.merge_mergemoe(wg, wu, wd, counts, X, 4, literal_t1=False)
    r2 = MG.merge_mergemoe(wg, wu, wd, counts, X, 4, literal_t1=True)
    np.testing.assert_allclose(r1.wd, r2.wd, atol=1e-6, rtol=1e-6)


def test_mergemoe_beats_msmoe_in_sample(setup):
    """Least-squares optimality: on the calibration inputs, MergeMoE's
    output error is <= M-SMoE's (same clustering, same targets)."""
    _, _, wg, wu, wd, X, counts = setup
    e_ours = _cluster_err(MG.merge_layer("mergemoe", wg, wu, wd, counts, X, 4),
                          wg, wu, wd, X)
    e_msmoe = _cluster_err(MG.merge_layer("msmoe", wg, wu, wd, counts, X, 4),
                           wg, wu, wd, X)
    assert e_ours < e_msmoe


@pytest.mark.parametrize("method", list(MG.METHODS))
def test_all_methods_produce_valid_tables(setup, method):
    cfg, _, wg, wu, wd, X, counts = setup
    M = 4
    res = MG.merge_layer(method, wg, wu, wd, counts, X, M)
    N = cfg.moe.n_experts
    assert res.wg.shape == (M,) + wg.shape[1:]
    assert res.remap.shape == (N,) and res.remap.max() < M
    assert np.isfinite(res.wd).all()
    # weights sum to 1 within each cluster
    for c in range(M):
        s = res.weights[res.assign == c].sum()
        np.testing.assert_allclose(s, 1.0, atol=1e-5)


def test_clustering_centers_are_top_usage(setup):
    _, _, wg, wu, wd, X, counts = setup
    M = 4
    assign = CL.cluster_experts(wg, wu, counts, M)
    centers = np.argsort(-counts)[:M]
    for rank, c in enumerate(centers):
        assert assign[c] == rank


def test_summation_and_mixing_matrices(setup):
    _, _, wg, wu, _, _, counts = setup
    N = wg.shape[0]
    M = 4
    assign = CL.cluster_experts(wg, wu, counts, M)
    A = CL.summation_matrix(assign, M)
    B = CL.mixing_matrix(assign, counts, M)
    assert A.shape == (M, N) and (A.sum(axis=0) == 1).all()
    np.testing.assert_allclose((A @ B).diagonal(), np.ones(M), atol=1e-6)


def test_compress_model_end_to_end(setup):
    cfg, params, *_ = setup
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 64),
                                             0, cfg.vocab_size)}
               for i in range(2)]
    new_cfg, new_params, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=1,
        batches=batches)
    assert info["compression_ratio"] > 1.05
    assert new_cfg.moe_merged == 4 and new_cfg.moe_split == 1
    l0, _ = MD.loss(cfg, params, batches[0])
    l1, _ = MD.loss(new_cfg, new_params, batches[0])
    assert np.isfinite(float(l1))
    assert abs(float(l1) - float(l0)) < 1.5
    # compressed suffix holds M real experts; prefix untouched
    assert new_params["stack_c"]["moe"]["wg"].shape[1] == 4
    np.testing.assert_array_equal(
        np.asarray(new_params["stack"]["moe"]["wg"]),
        np.asarray(params["stack"]["moe"]["wg"][:1]))


def test_compressed_model_serves(setup):
    cfg, params, *_ = setup
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(9), (2, 32),
                                             0, cfg.vocab_size)}]
    new_cfg, new_params, _ = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=1,
        batches=batches)
    tokens = batches[0]["tokens"]
    _, cache = MD.prefill(new_cfg, new_params, {"tokens": tokens}, s_max=40)
    logits, cache = MD.decode_step(new_cfg, new_params, cache, tokens[:, 0])
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_inapplicable_raises():
    cfg = configs.get("granite-8b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(TechniqueInapplicable):
        CMP.compress_model(cfg, params, method="mergemoe", merged_experts=4,
                           batches=[])


def test_sample_threshold_strictness(setup):
    """Paper Fig. 4: below the critical sample count the solve is
    under-determined — strict mode refuses."""
    cfg, params, *_ = setup
    from repro.core.errors import CalibrationError
    tiny = [{"tokens": jax.random.randint(jax.random.PRNGKey(0), (1, 8),
                                          0, cfg.vocab_size)}]
    with pytest.raises(CalibrationError):
        CMP.compress_model(cfg, params, method="mergemoe", merged_experts=4,
                           split=1, batches=tiny, strict_samples=True)
