"""CompressionPlan API: builders, strategy registry, streaming calibration,
uniform-plan == legacy-shim bit-for-bit regression, heterogeneous execution.
"""
import json

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import calibration as CAL
from repro.core import compress as CMP
from repro.core import merge as MG
from repro.core import plan as PLAN
from repro.core.errors import TechniqueInapplicable
from repro.models import model as MD


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (2, 64),
                                             0, cfg.vocab_size)}
               for i in range(2)]
    return cfg, params, batches


# ---------------------------------------------------------------------------
# builders + (de)serialization
# ---------------------------------------------------------------------------

def test_uniform_builder_matches_legacy_surface(setup):
    cfg, _, _ = setup
    plan = PLAN.uniform(cfg, method="mergemoe", merged_experts=4, split=1)
    assert plan.split == 1
    assert plan.layers == tuple(range(1, cfg.n_layers))
    assert plan.merged_per_layer == (4,) * (cfg.n_layers - 1)
    assert plan.is_uniform


def test_default_split_is_paper_suffix(setup):
    cfg, _, _ = setup
    plan = PLAN.uniform(cfg, merged_experts=4)
    assert plan.split == int(cfg.n_layers * 0.6)


def test_suffix_builder():
    cfg = configs.get("qwen3-moe-30b-a3b")          # 48 layers, full scale
    plan = PLAN.suffix(cfg, merged_experts=64, frac=0.4)
    assert plan.split == 48 - 19                    # round(48*0.4) == 19
    assert len(plan.specs) == 19


def test_plan_json_roundtrip(setup):
    cfg, _, _ = setup
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 4),
        PLAN.LayerSpec(1, "msmoe", 2),
    ))
    again = PLAN.CompressionPlan.from_json(plan.to_json())
    assert again == plan
    assert json.loads(plan.to_json())["version"] == PLAN.PLAN_FORMAT_VERSION


def test_plan_validation_rejects_bad_shapes(setup):
    cfg, _, _ = setup
    with pytest.raises(ValueError):                 # hole in the suffix
        PLAN.CompressionPlan(
            (PLAN.LayerSpec(0, "mergemoe", 4),)).validate(cfg)
    with pytest.raises(ValueError):                 # M out of range
        PLAN.CompressionPlan(
            (PLAN.LayerSpec(1, "mergemoe", 99),)).validate(cfg)
    with pytest.raises(KeyError):                   # unknown method
        PLAN.CompressionPlan(
            (PLAN.LayerSpec(1, "nope", 4),)).validate(cfg)
    with pytest.raises(TechniqueInapplicable):      # expert-free arch
        PLAN.uniform(configs.get("yi-34b"), merged_experts=4)


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

def test_registry_covers_legacy_methods():
    assert set(MG.METHODS) <= set(PLAN.available_methods())
    assert PLAN.get_strategy("mergemoe").requires == ("x", "counts")
    assert PLAN.get_strategy("msmoe").requires == ("counts", "router")


def test_custom_strategy_registers_and_merges(setup):
    cfg, params, batches = setup

    @PLAN.register_method("keep-top")
    class KeepTop(PLAN.MergeStrategy):
        """Toy strategy: keep the M most-used experts, remap the rest."""
        requires = ("counts",)

        def merge(self, wg, wu, wd, counts, X, M, *, router=None, **kw):
            N = wg.shape[0]
            keep = np.sort(np.argsort(-np.asarray(counts))[:M])
            remap = np.array([int(np.argmin(np.abs(keep - e)))
                              for e in range(N)], np.int32)
            w = np.ones(N, np.float32)
            return MG.MergeResult(wg[keep], wu[keep], wd[keep], remap,
                                  remap.copy(), w, info={"method": "keep-top"})

    try:
        assert "keep-top" in PLAN.available_methods()
        plan = PLAN.uniform(cfg, method="keep-top", merged_experts=4, split=1)
        ncfg, nparams, info = CMP.compress_with_plan(
            cfg, params, plan, batches=batches)
        assert nparams["stack_c"]["moe"]["wg"].shape[1] == 4
        l, _ = MD.loss(ncfg, nparams, batches[0])
        assert np.isfinite(float(l))
    finally:
        PLAN._REGISTRY.pop("keep-top", None)


# ---------------------------------------------------------------------------
# streaming calibration
# ---------------------------------------------------------------------------

def test_stream_matches_legacy_collect(setup):
    cfg, params, batches = setup
    legacy = CAL.collect(cfg, params, batches)
    stream = CAL.CalibrationStream(cfg, params).consume(batches)
    for l in range(cfg.n_layers):
        np.testing.assert_array_equal(stream.layer(l).x, legacy[l].x)
        np.testing.assert_array_equal(stream.layer(l).counts,
                                      legacy[l].counts)


def test_stream_bounds_host_memory(setup):
    cfg, params, batches = setup
    cap = 100
    stream = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=cap,
                                   seed=3).consume(batches)
    assert stream.n_tokens == cap
    assert stream._x.shape == (cfg.n_layers, cap, cfg.d_model)
    assert stream.tokens_seen == 2 * 2 * 64          # counts keep streaming
    assert stream.counts(0).sum() > 0


def test_head_policy_is_legacy_truncation(setup):
    """policy='head' + cap == the historical concatenate-then-truncate
    capture (the semantics compress_model(max_tokens=...) shims to)."""
    cfg, params, batches = setup
    full = CAL.collect(cfg, params, batches)
    head = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=50,
                                 policy="head").consume(batches)
    assert head.n_tokens == 50
    for l in range(cfg.n_layers):
        np.testing.assert_array_equal(head.layer(l).x, full[l].x[:50])
        np.testing.assert_array_equal(head.layer(l).counts, full[l].counts)


def test_stream_reservoir_deterministic_and_layer_aligned(setup):
    cfg, params, batches = setup
    a = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=64,
                              seed=5).consume(batches)
    b = CAL.CalibrationStream(cfg, params, max_tokens_per_layer=64,
                              seed=5).consume(batches)
    np.testing.assert_array_equal(a._x, b._x)
    # shared replacement schedule: every layer holds the SAME token slots,
    # so a token kept at layer 0 is kept at layer 1 too
    legacy = CAL.collect(cfg, params, batches)
    full = np.stack([legacy[l].x for l in range(cfg.n_layers)])  # [L, T, d]
    # find each reservoir row of layer 0 in the full stream ...
    for j in [0, 17, 63]:
        t = np.flatnonzero((full[0] == a._x[0, j]).all(axis=1))[0]
        # ... the same position must be stored for the other layer
        np.testing.assert_array_equal(a._x[1, j], full[1, t])


# ---------------------------------------------------------------------------
# acceptance: uniform plan == legacy shim, bit for bit
# ---------------------------------------------------------------------------

def test_uniform_plan_reproduces_legacy_compress_model(setup):
    cfg, params, batches = setup
    ncfg, nparams, ninfo = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=1,
        batches=batches)
    plan = PLAN.uniform(cfg, method="mergemoe", merged_experts=4, split=1)
    pcfg, pparams, pinfo = CMP.compress_with_plan(
        cfg, params, plan, batches=batches)
    assert pcfg == ncfg
    na, pa = jax.tree.leaves(nparams), jax.tree.leaves(pparams)
    assert len(na) == len(pa)
    for a, b in zip(na, pa):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ninfo["merged_per_layer"] == pinfo["merged_per_layer"]
    assert ninfo["bytes_compressed"] == pinfo["bytes_compressed"]


def test_small_sample_warns_and_reports(setup):
    cfg, params, _ = setup
    tiny = [{"tokens": jax.random.randint(jax.random.PRNGKey(0), (1, 8),
                                          0, cfg.vocab_size)}]
    with pytest.warns(UserWarning, match="calibration tokens"):
        _, _, info = CMP.compress_model(
            cfg, params, method="average", merged_experts=4, split=1,
            batches=tiny)
    assert info["calib_tokens"] == 8
    assert info["calib_warning"] is True


# ---------------------------------------------------------------------------
# heterogeneous execution
# ---------------------------------------------------------------------------

def test_heterogeneous_plan_mixed_methods(setup):
    cfg, params, batches = setup
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 4),
        PLAN.LayerSpec(1, "msmoe", 2),
    ))
    ncfg, nparams, info = CMP.compress_with_plan(
        cfg, params, plan, batches=batches)
    assert ncfg.moe_merged == 4
    assert ncfg.moe_merged_layers == (4, 2)
    assert info["method"] == "mixed"
    moe = nparams["stack_c"]["moe"]
    assert moe["wg"].shape[1] == 4                  # padded to max M
    np.testing.assert_array_equal(np.asarray(moe["live"]), [4, 2])
    # remap only ever addresses live rows; layer-1 pad rows are all zero
    remap = np.asarray(moe["remap"])
    assert (remap[0] < 4).all() and (remap[1] < 2).all()
    assert not np.asarray(moe["wg"][1, 2:], np.float32).any()
    l, _ = MD.loss(ncfg, nparams, batches[0])
    assert np.isfinite(float(l))


def test_router_logit_mask_is_noop_for_valid_remap(setup):
    """Masked routing == unmasked routing whenever remap is valid (the mask
    only guards pad rows, DESIGN.md §5)."""
    cfg, params, batches = setup
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 4),
        PLAN.LayerSpec(1, "average", 2),
    ))
    ncfg, nparams, _ = CMP.compress_with_plan(cfg, params, plan,
                                              batches=batches)
    logits_masked, _, _ = MD.forward(ncfg, nparams, batches[0])
    stripped = jax.tree.map(lambda x: x, nparams)
    stripped["stack_c"] = dict(stripped["stack_c"])
    stripped["stack_c"]["moe"] = {
        k: v for k, v in stripped["stack_c"]["moe"].items() if k != "live"}
    logits_plain, _, _ = MD.forward(ncfg, stripped, batches[0])
    np.testing.assert_array_equal(np.asarray(logits_masked),
                                  np.asarray(logits_plain))


def test_dense_capacity_sized_by_smallest_live_count(setup):
    """Dense dispatch must not under-provision a hetero layer whose traffic
    concentrates on few live rows: capacity is sized by min(live), not by
    the padded table width (DESIGN.md §5)."""
    from repro.models import moe as MOE
    cfg, params, batches = setup
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 6),
        PLAN.LayerSpec(1, "average", 2),
    ))
    ncfg, nparams, _ = CMP.compress_with_plan(cfg, params, plan,
                                              batches=batches)
    layer0 = jax.tree.map(lambda a: a[0], nparams["stack_c"]["moe"])
    assert MOE.capacity_experts(ncfg, layer0) == 2
    # prefix/uncompressed layers keep physical-count sizing
    uncomp = jax.tree.map(lambda a: a[0], params["stack"]["moe"])
    assert MOE.capacity_experts(cfg, uncomp) == cfg.moe.n_experts
    # uniform compression: live == physical, unchanged sizing
    ucfg, uparams, _ = CMP.compress_model(
        cfg, params, method="average", merged_experts=4, split=1,
        batches=batches)
    ulayer = jax.tree.map(lambda a: a[0], uparams["stack_c"]["moe"])
    assert MOE.capacity_experts(ucfg, ulayer) == 4
    # degenerate hetero plan with max M == N: suffix tables are N wide, so
    # the prefix matches too — BOTH stacks size by min(live) (conservative:
    # extra slots, never extra drops)
    N = cfg.moe.n_experts
    dplan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "average", N),
        PLAN.LayerSpec(1, "average", 2),
    ))
    dcfg, dparams, _ = CMP.compress_with_plan(cfg, params, dplan,
                                              batches=batches)
    dlayer = jax.tree.map(lambda a: a[0], dparams["stack_c"]["moe"])
    assert MOE.capacity_experts(dcfg, dlayer) == 2


# ---------------------------------------------------------------------------
# budget planner
# ---------------------------------------------------------------------------

def test_planner_hits_target_ratio():
    cfg = configs.get("qwen3-moe-30b-a3b")          # 48 layers, 128 experts
    plan = PLAN.for_target_ratio(cfg, target_ratio=1.5, split=28)
    got = PLAN.plan_live_ratio(cfg, plan)
    assert got >= 1.5                                # met ...
    # ... and not overshot by more than one expert's worth of bytes
    per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert \
        * cfg.param_dtype.itemsize
    total = cfg.param_count() * cfg.param_dtype.itemsize
    assert (total / 1.5) - (total / got) <= per_expert + 1


def test_planner_respects_importance_stats():
    """A layer whose routing concentrates on few experts is squeezed harder
    than one spreading traffic across all of them."""
    cfg = configs.get("qwen3-moe-30b-a3b").reduced().replace(n_layers=4)
    N = cfg.moe.n_experts
    stats = {l: np.ones(N) for l in range(4)}
    stats[1] = np.zeros(N)
    stats[1][0] = 100.0                              # layer 1: one hot expert
    plan = PLAN.for_target_ratio(cfg, target_ratio=1.12, stats=stats, split=1)
    by_layer = dict(zip(plan.layers, plan.merged_per_layer))
    assert by_layer[1] < by_layer[2] and by_layer[1] < by_layer[3]


def test_planner_deterministic_and_unreachable_raises():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced().replace(n_layers=4)
    a = PLAN.for_target_ratio(cfg, target_ratio=1.1, split=2)
    b = PLAN.for_target_ratio(cfg, target_ratio=1.1, split=2)
    assert a == b
    with pytest.raises(ValueError, match="unreachable"):
        PLAN.for_target_ratio(cfg, target_ratio=50.0, split=3)
