"""Compressed-variant configs at FULL scale (abstract shapes only — this is
what the --compressed dry-run lowers)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.errors import TechniqueInapplicable
from repro.models import model as MD


def test_kimi_compressed_param_shapes():
    cfg = configs.get("kimi-k2-1t-a32b").compressed(192, 0)
    specs = jax.eval_shape(lambda: MD.init(cfg, jax.random.PRNGKey(0)))
    moe = specs["stack_c"]["moe"]
    assert moe["wg"].shape == (61, 192, 7168, 2048)
    assert moe["remap"].shape == (61, 384)          # router space unchanged
    assert moe["router"].shape == (61, 7168, 384)
    assert "stack" not in specs                     # split=0: all compressed


def test_qwen3_paper_split_shapes():
    """Paper App. C.2: layers 28-47 merged 128 -> 64."""
    cfg = configs.get("qwen3-moe-30b-a3b").compressed(64, 28)
    specs = jax.eval_shape(lambda: MD.init(cfg, jax.random.PRNGKey(0)))
    assert specs["stack"]["moe"]["wg"].shape == (28, 128, 2048, 768)
    assert specs["stack_c"]["moe"]["wg"].shape == (20, 64, 2048, 768)


def test_compressed_bytes_reduction():
    full = configs.get("kimi-k2-1t-a32b")
    comp = full.compressed(192, 0)

    def nbytes(cfg):
        specs = jax.eval_shape(lambda: MD.init(cfg, jax.random.PRNGKey(0)))
        return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(specs))

    ratio = nbytes(full) / nbytes(comp)
    assert 1.8 < ratio < 2.1        # experts dominate a 1T MoE


def test_compressed_on_dense_raises():
    with pytest.raises(TechniqueInapplicable):
        configs.get("yi-34b").compressed(4)


def test_default_split_is_suffix():
    cfg = configs.get("qwen3-moe-30b-a3b").compressed(64)
    assert cfg.moe_split == int(48 * 0.6)
