"""Architecture registry: ``get(arch_id)`` -> ModelConfig, exact shapes from
the assignment table. One module per architecture."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "yi_34b",
    "qwen1_5_110b",
    "granite_8b",
    "phi3_medium_14b",
    "kimi_k2_1t_a32b",
    "qwen3_moe_30b_a3b",
    "phi_3_vision_4_2b",
    "zamba2_2_7b",
    "mamba2_370m",
    "whisper_base",
]

# assignment ids (with dashes/dots) -> module names
ALIASES: Dict[str, str] = {
    "yi-34b": "yi_34b",
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-8b": "granite_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-370m": "mamba2_370m",
    "whisper-base": "whisper_base",
}


def canonical(arch: str) -> str:
    return ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))


def get(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# input shapes (assignment): per-arch applicability handled in launch.shapes
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (assignment skip rule)."""
    if shape == "long_500k":
        return cfg.supports_long_context
    return True
