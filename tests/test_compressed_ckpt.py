"""Compressed artifacts: save_compressed/load_compressed roundtrip fidelity
(bf16 <-> f32 npy, int32 remap, plan/report extras) and the acceptance path —
a heterogeneous plan compresses, checkpoints, reloads via
``Engine.from_checkpoint`` and decodes token-for-token identically to the
in-memory compressed model.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint as CKPT
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.models import model as MD
from repro.models.config import config_from_dict
from repro.serving import Engine, EngineConfig

ARCH = "qwen3-moe-30b-a3b"


@pytest.fixture(scope="module")
def compressed():
    """Heterogeneous plan over the ragged serving path: different M per
    layer, mixed methods (msmoe exercises the router requirement)."""
    cfg = configs.get(ARCH).reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 4),
        PLAN.LayerSpec(1, "msmoe", 2),
    ))
    ncfg, nparams, info = CMP.compress_with_plan(cfg, params, plan,
                                                 batches=calib)
    return ncfg, nparams, plan, info


def test_config_json_roundtrip(compressed):
    ncfg, *_ = compressed
    again = config_from_dict(json.loads(json.dumps(ncfg.to_json_dict())))
    assert again == ncfg
    assert again.moe_merged_layers == (4, 2)
    assert isinstance(again.moe, type(ncfg.moe))


def test_roundtrip_dtypes_and_values(tmp_path, compressed):
    ncfg, nparams, plan, info = compressed
    CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan, report=info)
    cfg2, params2, art = CKPT.load_compressed(tmp_path)
    assert cfg2 == ncfg
    moe = params2["stack_c"]["moe"]
    # bf16 tables survive the f32 npy detour bitwise (bf16 -> f32 is exact)
    assert moe["wg"].dtype == jnp.bfloat16 == nparams["stack_c"]["moe"]["wg"].dtype
    # int32 remap preserved exactly
    assert moe["remap"].dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(moe["remap"]),
        np.asarray(nparams["stack_c"]["moe"]["remap"]))
    # every leaf identical (incl. re-padded expert tables and live counts)
    la, lb = jax.tree.leaves(nparams), jax.tree.leaves(params2)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_extras_survive_roundtrip(tmp_path, compressed):
    ncfg, nparams, plan, info = compressed
    CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan, report=info)
    _, _, art = CKPT.load_compressed(tmp_path)
    assert PLAN.CompressionPlan.from_json_dict(art["plan"]) == plan
    assert art["report"]["merged_per_layer"] == [4, 2]
    assert art["report"]["compression_ratio"] == pytest.approx(
        info["compression_ratio"])
    assert art["mesh"] is None                     # single-device provenance


def test_artifact_records_mesh_provenance(tmp_path, compressed):
    """An artifact built under a mesh carries the mesh axes in meta.json —
    provenance only, never a loading constraint (DESIGN.md §6)."""
    ncfg, nparams, plan, info = compressed
    annotated = dict(info, mesh={"axes": {"data": 4}, "devices": 4,
                                 "solve_shards": 1})
    CKPT.save_compressed(tmp_path, ncfg, nparams,
                         plan=plan.with_mesh({"data": 4}), report=annotated)
    lcfg, lparams, art = CKPT.load_compressed(tmp_path)
    assert art["mesh"] == {"axes": {"data": 4}, "devices": 4,
                           "solve_shards": 1}
    assert PLAN.CompressionPlan.from_json_dict(art["plan"]).mesh \
        == (("data", 4),)
    # ...and loading ignores it: params come back identical
    for a, b in zip(jax.tree.leaves(lparams), jax.tree.leaves(nparams)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # without a report mesh the plan's flat record is wrapped into the SAME
    # {"axes": ...} schema (one shape for every consumer)
    CKPT.save_compressed(tmp_path / "planned", ncfg, nparams,
                         plan=plan.with_mesh({"data": 4}))
    _, _, art2 = CKPT.load_compressed(tmp_path / "planned")
    assert art2["mesh"] == {"axes": {"data": 4}}


def test_artifact_stores_ragged_tables(tmp_path, compressed):
    """Heterogeneous artifacts persist each suffix layer's tables UNPADDED:
    artifact bytes reflect the plan's live budget, not max-M padding."""
    ncfg, nparams, plan, info = compressed
    d = CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan)
    meta = json.loads((d / "meta.json").read_text())
    shapes = [tuple(l["shape"]) for l in meta["leaves"]]
    f = ncfg.moe.d_ff_expert
    assert (4, ncfg.d_model, f) in shapes            # layer 0 live rows
    assert (2, ncfg.d_model, f) in shapes            # layer 1 live rows
    assert (2, 4, ncfg.d_model, f) not in shapes     # no padded stack on disk
    disk = sum(np.prod(s) for s in shapes if s)
    assert disk * 2 < info["bytes_padded"]           # strictly below padded


def test_uniform_plan_artifact_keeps_stacked_layout(tmp_path):
    """Uniform plans have no pad rows — the artifact keeps the plain stacked
    leaves and loads back unchanged."""
    cfg = configs.get(ARCH).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=1,
        batches=calib)
    CKPT.save_compressed(tmp_path, ncfg, nparams, report=info)
    cfg2, params2, art = CKPT.load_compressed(tmp_path)
    assert cfg2 == ncfg and art["plan"] is None
    np.testing.assert_array_equal(
        np.asarray(params2["stack_c"]["moe"]["wg"], np.float32),
        np.asarray(nparams["stack_c"]["moe"]["wg"], np.float32))


def test_save_compressed_rejects_uncompressed(tmp_path):
    cfg = configs.get(ARCH).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not compressed"):
        CKPT.save_compressed(tmp_path, cfg, params)


def test_load_compressed_rejects_plain_checkpoint(tmp_path):
    CKPT.save(tmp_path, 0, {"w": jnp.ones((2,))})
    with pytest.raises(ValueError, match="plain checkpoint"):
        CKPT.load_compressed(tmp_path)


@pytest.fixture(scope="module")
def compressed_int8():
    """The same heterogeneous plan executed with weight_dtype='int8'
    (DESIGN.md §8): suffix tables stored as int8 + per-channel scales."""
    cfg = configs.get(ARCH).reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    plan = PLAN.CompressionPlan((
        PLAN.LayerSpec(0, "mergemoe", 4),
        PLAN.LayerSpec(1, "msmoe", 2),
    ), weight_dtype="int8")
    ncfg, nparams, info = CMP.compress_with_plan(cfg, params, plan,
                                                 batches=calib)
    return ncfg, nparams, plan, info


def test_int8_artifact_roundtrip_bitwise(tmp_path, compressed_int8):
    """Int8 hetero artifacts store the six qexp leaves unpadded per layer
    and reload bitwise (int8 rides npy natively; zero pad rows and zero
    scales re-pad exactly)."""
    ncfg, nparams, plan, info = compressed_int8
    d = CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan, report=info)
    meta = json.loads((d / "meta.json").read_text())
    shapes = [tuple(l["shape"]) for l in meta["leaves"]]
    dtypes = [l["dtype"] for l in meta["leaves"]]
    f = ncfg.moe.d_ff_expert
    assert (4, ncfg.d_model, f) in shapes            # layer 0 live rows
    assert (2, ncfg.d_model, f) in shapes            # layer 1 live rows
    assert (2, 4, ncfg.d_model, f) not in shapes     # no padded stack on disk
    assert "int8" in dtypes                          # tables stored as int8
    cfg2, params2, art = CKPT.load_compressed(tmp_path)
    assert cfg2 == ncfg
    moe = params2["stack_c"]["moe"]
    assert "qexp" in moe and "wg" not in moe
    assert moe["qexp"]["wg"].dtype == jnp.int8
    assert moe["qexp"]["wg_scale"].dtype == jnp.float32
    la, lb = jax.tree.leaves(nparams), jax.tree.leaves(params2)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert PLAN.CompressionPlan.from_json_dict(art["plan"]).weight_dtype \
        == "int8"
    assert art["report"]["weight_dtype"] == "int8"


def test_int8_artifact_smaller_than_bf16(tmp_path, compressed,
                                         compressed_int8):
    """Same merge, int8 storage: the on-disk artifact shrinks (scales are
    fp32 in npy, int8 tables one byte/weight vs bf16's f32 npy detour —
    compare the report's live-byte accounting, which is dtype-true)."""
    _, _, _, info_bf = compressed
    _, _, _, info_q = compressed_int8
    assert info_q["bytes_compressed"] < info_bf["bytes_compressed"]
    assert info_q["compression_ratio"] > info_bf["compression_ratio"]


def test_int8_engine_from_checkpoint_token_parity(tmp_path, compressed_int8):
    """Engine.from_checkpoint serves int8 artifacts directly: the reloaded
    artifact decodes token-for-token identically to the in-memory quantized
    model through the gather path."""
    ncfg, nparams, plan, info = compressed_int8
    CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan, report=info)
    prompts = np.random.default_rng(2).integers(
        0, ncfg.vocab_size, size=(3, 12), dtype=np.int32)
    ec = EngineConfig(arch=ARCH, n_slots=2, s_max=48, prefill_buckets=(16,))

    def generate(eng):
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [r.out_tokens for r in reqs]

    mem = generate(Engine(ec, cfg=ncfg, params=nparams))
    eng2 = Engine.from_checkpoint(tmp_path, ec=ec)
    assert eng2.expert_weight_dtypes()[1] == "int8"
    assert generate(eng2) == mem


def test_engine_from_checkpoint_token_parity(tmp_path, compressed):
    """Acceptance: the reloaded artifact decodes token-for-token identically
    to the in-memory compressed model, through the continuous-batching
    engine's ragged/grouped-kernel path."""
    ncfg, nparams, plan, info = compressed
    CKPT.save_compressed(tmp_path, ncfg, nparams, plan=plan, report=info)

    prompts = np.random.default_rng(0).integers(
        0, ncfg.vocab_size, size=(3, 12), dtype=np.int32)
    # pin dispatch to the fixture's ragged config so ``eng2.cfg == ncfg``
    # stays an exact equality (the engine default is now 'gather', whose
    # token parity is covered by test_serving_engine)
    ec = EngineConfig(arch=ARCH, n_slots=2, s_max=48, prefill_buckets=(16,),
                      dispatch="ragged")

    def generate(eng):
        reqs = [eng.submit(p, max_new_tokens=8) for p in prompts]
        eng.run()
        return [r.out_tokens for r in reqs]

    mem = generate(Engine(ec, cfg=ncfg, params=nparams))
    eng2 = Engine.from_checkpoint(tmp_path, ec=ec)
    assert eng2.cfg == ncfg
    assert eng2.artifact["report"]["merged_per_layer"] == [4, 2]
    loaded = generate(eng2)
    assert loaded == mem
