"""Host-side block allocator for the paged KV cache (DESIGN.md §11).

The device holds a flat pool of fixed-size KV blocks
(``[L, n_blocks, block_size, nkv, hd]``); which rows belong to which slot is
pure host bookkeeping: a per-slot block table (``tab``), a free list, and a
per-block refcount. The allocator never touches device memory — it hands the
engine an int32 table to ship alongside the pool, and the device side treats
``n_blocks`` (one past the last real block) as a sentinel whose scatter
writes drop and whose gather reads are masked.

Prefix sharing is refcount-based: after a request's admission forward has
written its prompt rows, every FULL block strictly below the last prompt
token is registered under the exact bytes of the tokens it covers (no hash —
the key IS the token prefix, so collisions are impossible). A later request
whose prompt starts with a registered chain adopts those blocks read-only
(refcount +1 per sharer) and prefills only the suffix. Registered chains are
pinned by the registry itself (one refcount per entry) and evicted LRU when
admission runs out of free blocks.

Two invariants make sharing safe without device-side copy-on-write:

* registered blocks are FULL prompt blocks strictly below the last prompt
  token, and block boundaries are row boundaries — a sharer's first writable
  row is block-aligned at the end of the shared chain, so its scatters can
  never land in a shared block;
* every slot reserves its whole row budget (prompt + max_new − 1 rows, plus
  ``spec_k`` verify headroom in speculative mode) at admission — decode and
  verify never allocate mid-flight, and speculative rollback is a pure
  position rewind that reuses the already-owned blocks in place.

:meth:`PagedAllocator.ensure_writable` still implements full copy-on-write
bookkeeping (divorce a shared block before writing it) as a safety net; the
engine flow above never triggers it, and the property tests exercise it
directly.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np


class PagedAllocator:
    """Block-table bookkeeping for one KV pool (shared by the draft pool in
    speculative mode — both pools use the same table, so a prefix shared in
    the full-model pool is shared in the draft pool at the same block ids)."""

    def __init__(self, *, n_slots: int, n_blocks: int, block_size: int,
                 s_max: int, n_shards: int = 1):
        if s_max % block_size:
            raise ValueError(f"s_max={s_max} must be a multiple of "
                             f"kv block size {block_size}")
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n_blocks % n_shards or n_slots % n_shards:
            raise ValueError(
                f"n_blocks={n_blocks} and n_slots={n_slots} must both split "
                f"evenly over n_shards={n_shards}: a sharded pool pins "
                f"slot s to the block range of shard s // (n_slots/n_shards) "
                f"(DESIGN.md §13)")
        self.n_slots = int(n_slots)
        self.nb = int(n_blocks)
        self.bs = int(block_size)
        self.s_max = int(s_max)
        self.mb = s_max // block_size                   # table width
        # mesh serving (DESIGN.md §13): with n_shards > 1 the pool is
        # PARTITIONED — shard ``sh`` owns blocks [sh*nb_l, (sh+1)*nb_l) and
        # slots [sh*slots_per, (sh+1)*slots_per), and every allocation for a
        # slot draws only from its shard's range. That is the invariant the
        # in-program table localization relies on: each data shard's table
        # rows reference only block ids it physically holds.
        self.nsh = int(n_shards)
        self.nb_l = self.nb // self.nsh
        self.slots_per = self.n_slots // self.nsh
        # pop() order is ascending block id within each shard —
        # deterministic across runs
        self._free: List[List[int]] = [
            list(range((sh + 1) * self.nb_l - 1, sh * self.nb_l - 1, -1))
            for sh in range(self.nsh)]
        self.ref = np.zeros(self.nb, np.int64)
        # one sentinel row at index n_slots: admission pads point there so
        # their scatter writes drop on device
        self.tab = np.full((self.n_slots + 1, self.mb), self.nb, np.int32)
        self._owned: Dict[int, List[int]] = {}
        self._registry: "OrderedDict[bytes, Tuple[int, ...]]" = OrderedDict()
        self.stats = {"prefix_hits": 0, "prefix_rows_shared": 0,
                      "registry_evictions": 0, "deferrals": 0,
                      "cow_copies": 0}

    # -- capacity ----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return sum(len(f) for f in self._free)

    def blocks_for_rows(self, n_rows: int) -> int:
        return -(-int(n_rows) // self.bs)

    # -- sharding (DESIGN.md §13) ------------------------------------------

    def shard_of_slot(self, slot: int) -> int:
        return int(slot) // self.slots_per

    def shard_of_block(self, block: int) -> int:
        return int(block) // self.nb_l

    def _reg_key(self, shard: int, key: bytes) -> bytes:
        """Registry keys are shard-qualified when the pool is partitioned:
        a chain's blocks live on one shard, so only same-shard slots may
        adopt it. Unsharded pools keep the raw-prefix key (snapshot
        compatibility)."""
        if self.nsh == 1:
            return key
        return shard.to_bytes(4, "little") + key

    # -- prefix registry ---------------------------------------------------

    def lookup_prefix(self, prompt: np.ndarray,
                      shard: int = 0) -> Tuple[int, Tuple[int, ...]]:
        """Longest registered chain covering a strict prefix of ``prompt``
        that lives on ``shard`` (the only shard whose slots could adopt it
        in a partitioned pool; ignored when unsharded).

        Returns ``(shared_rows, blocks)``; ``shared_rows`` is capped below
        ``len(prompt)`` so the admission forward always has at least one
        suffix token to produce the first sampled token's logits from."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        for mm in range((len(prompt) - 1) // self.bs, 0, -1):
            key = self._reg_key(shard, prompt[:mm * self.bs].tobytes())
            chain = self._registry.get(key)
            if chain is not None:
                self._registry.move_to_end(key)
                return mm * self.bs, chain
        return 0, ()

    def register_prefix(self, slot: int, prompt: np.ndarray) -> int:
        """Pin every full prompt block of ``slot`` (strictly below the last
        prompt token) in the registry so later admissions can share it. Must
        be called only AFTER the device call that wrote the rows. Returns
        the number of chain entries added."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        blocks = self._owned.get(slot, [])
        sh = self.shard_of_slot(slot)
        added = 0
        for mm in range(1, min((len(prompt) - 1) // self.bs,
                               len(blocks)) + 1):
            key = self._reg_key(sh, prompt[:mm * self.bs].tobytes())
            if key in self._registry:
                self._registry.move_to_end(key)
                continue
            chain = tuple(blocks[:mm])
            for b in chain:
                self.ref[b] += 1
            self._registry[key] = chain
            added += 1
        return added

    def _evict_registry_one(self, shard: Optional[int] = None) -> bool:
        """Evict the LRU registry chain — restricted to chains whose blocks
        live on ``shard`` when given (evicting another shard's chain cannot
        relieve this shard's pressure)."""
        victim = None
        for key, chain in self._registry.items():       # LRU order
            if shard is None or not chain \
                    or self.shard_of_block(chain[0]) == shard:
                victim = key
                break
        if victim is None:
            return False
        chain = self._registry.pop(victim)
        for b in chain:
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self._free[self.shard_of_block(b)].append(b)
        self.stats["registry_evictions"] += 1
        return True

    # -- slot lifecycle ----------------------------------------------------

    def admit(self, slot: int, prompt: np.ndarray,
              n_rows: int) -> Optional[int]:
        """Reserve ``n_rows`` KV rows for ``slot``, adopting the longest
        registered prefix chain. Returns the shared prefix length in rows
        (0 when nothing is shared), or None when the pool cannot supply the
        blocks even after LRU registry eviction — the caller defers the
        request and retries later (FIFO head-of-line, so admission order is
        preserved)."""
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already owns blocks")
        sh = self.shard_of_slot(slot)
        free = self._free[sh]
        shared_rows, shared = self.lookup_prefix(prompt, sh)
        # Take the adoption refcounts BEFORE evicting: the eviction loop may
        # pop the very registry entries pinning this chain, and an unpinned
        # chain would fall into the free list and be handed back out by the
        # need_new loop below — duplicate block ids in the slot table.
        for b in shared:
            self.ref[b] += 1
        need_new = self.blocks_for_rows(n_rows) - len(shared)
        while len(free) < need_new and self._evict_registry_one(
                sh if self.nsh > 1 else None):
            pass
        if len(free) < need_new:
            for b in shared:
                self.ref[b] -= 1
                if self.ref[b] == 0:
                    free.append(b)
            self.stats["deferrals"] += 1
            return None
        blocks = list(shared)
        for _ in range(need_new):
            b = free.pop()
            self.ref[b] += 1
            blocks.append(b)
        self._owned[slot] = blocks
        self.tab[slot] = self.nb
        self.tab[slot, :len(blocks)] = blocks
        if shared:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_rows_shared"] += shared_rows
        return shared_rows

    def release(self, slot: int) -> None:
        """Return the slot's blocks to the pool (registry pins keep shared
        chains alive) and point its table row at the sentinel so any write
        the frozen slot still issues on device is dropped."""
        for b in self._owned.pop(slot, []):
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self._free[self.shard_of_block(b)].append(b)
        self.tab[slot] = self.nb

    def trim(self, slot: int, n_rows: int) -> int:
        """Shrink a slot's reservation to ``n_rows`` rows, releasing the
        tail blocks. The engine's reserve-ahead policy never needs this
        (speculative rollback reuses blocks in place); it exists so the
        allocator supports reclaim-on-rollback policies and is exercised by
        the property tests. Returns the number of blocks released."""
        blocks = self._owned.get(slot)
        if blocks is None:
            return 0
        keep = min(max(self.blocks_for_rows(n_rows), 0), len(blocks))
        dropped = blocks[keep:]
        for b in dropped:
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self._free[self.shard_of_block(b)].append(b)
        self._owned[slot] = blocks[:keep]
        self.tab[slot, keep:] = self.nb
        return len(dropped)

    def ensure_writable(self, slot: int, block_index: int) -> Tuple[int, int]:
        """Copy-on-write: make table entry ``block_index`` of ``slot``
        exclusively owned. Returns ``(old_block, new_block)``; when they
        differ the CALLER must copy the old block's device contents into the
        new one before writing. The engine never hits the divorce branch
        (sharers' first writable row is block-aligned past the shared
        chain), but the allocator keeps the invariant honest for any policy
        that writes into adopted blocks."""
        blocks = self._owned[slot]
        b = blocks[block_index]
        if self.ref[b] == 1:
            return b, b
        sh = self.shard_of_slot(slot)
        free = self._free[sh]
        while not free and self._evict_registry_one(
                sh if self.nsh > 1 else None):
            pass
        if not free:
            raise RuntimeError("paged KV pool exhausted during copy-on-write")
        nb_ = free.pop()
        self.ref[b] -= 1
        self.ref[nb_] = 1
        blocks[block_index] = nb_
        self.tab[slot, block_index] = nb_
        self.stats["cow_copies"] += 1
        return b, nb_

    def reset(self) -> None:
        """Drop every owner and registry entry (full pool reclaim)."""
        for slot in list(self._owned):
            self.release(slot)
        while self._evict_registry_one():
            pass

    # -- snapshot / restore (DESIGN.md §12) --------------------------------

    def state_dict(self) -> dict:
        """JSON-safe snapshot of the complete allocator state: free list
        (order preserved — it IS the allocation order), refcounts, block
        tables, per-slot ownership, and the prefix registry with its LRU
        order and exact byte keys (hex-encoded)."""
        return {
            # flattened in shard order: shard membership is a pure function
            # of block id, so load_state re-splits losslessly (the format is
            # identical to the unsharded one when n_shards == 1)
            "free": [int(b) for f in self._free for b in f],
            "ref": [int(r) for r in self.ref],
            "tab": self.tab.tolist(),
            "owned": {str(s): [int(b) for b in blocks]
                      for s, blocks in self._owned.items()},
            "registry": [[key.hex(), [int(b) for b in chain]]
                         for key, chain in self._registry.items()],
            "stats": dict(self.stats),
        }

    def load_state(self, state: dict) -> None:
        """Inverse of :meth:`state_dict`. Restores onto an allocator built
        with the same geometry; a restored allocator is indistinguishable
        from the one that snapshotted (``check_invariants`` holds)."""
        self._free = [[] for _ in range(self.nsh)]
        for b in state["free"]:
            self._free[self.shard_of_block(int(b))].append(int(b))
        self.ref = np.asarray(state["ref"], np.int64)
        self.tab = np.asarray(state["tab"], np.int32)
        self._owned = {int(s): [int(b) for b in blocks]
                       for s, blocks in state["owned"].items()}
        self._registry = OrderedDict(
            (bytes.fromhex(key), tuple(int(b) for b in chain))
            for key, chain in state["registry"])
        self.stats = dict(state["stats"])
        self.check_invariants()

    # -- invariants (asserted by the property tests) -----------------------

    def check_invariants(self) -> None:
        expected = np.zeros(self.nb, np.int64)
        for blocks in self._owned.values():
            for b in blocks:
                expected[b] += 1
        for chain in self._registry.values():
            for b in chain:
                expected[b] += 1
        assert (expected == self.ref).all(), "refcount drift"
        free = [b for f in self._free for b in f]
        assert len(set(free)) == len(free), "double-freed block"
        free_set = set(free)
        for sh, f in enumerate(self._free):
            for b in f:
                assert self.shard_of_block(b) == sh, (
                    f"block {b} on shard {sh}'s free list, belongs to "
                    f"{self.shard_of_block(b)}")
        for b in range(self.nb):
            assert (self.ref[b] == 0) == (b in free_set), (
                f"block {b}: ref={self.ref[b]} free={b in free_set}")
        for slot, blocks in self._owned.items():
            assert len(set(blocks)) == len(blocks), (
                f"slot {slot} owns a block twice: {blocks}")
            for b in blocks:
                assert self.shard_of_block(b) == self.shard_of_slot(slot), (
                    f"slot {slot} (shard {self.shard_of_slot(slot)}) owns "
                    f"block {b} of shard {self.shard_of_block(b)}")
            assert list(self.tab[slot, :len(blocks)]) == list(blocks)
            assert (self.tab[slot, len(blocks):] == self.nb).all()
        assert (self.tab[self.n_slots] == self.nb).all(), "sentinel row"
