"""Batched serving driver: continuous-batching-lite.

Requests (prompts) are grouped into fixed-size batches; each batch is
prefetched through ``prefill`` and decoded with the jitted single-token
``serve_step``. The same entry points the dry-run lowers at production scale
run here on CPU with reduced configs. Compressed (MergeMoE) checkpoints serve
through the identical path — the router remap makes merged experts
transparent to the decode loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh


@dataclasses.dataclass
class ServeConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    batch_size: int = 4
    prompt_len: int = 32
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0


class Server:
    def __init__(self, sc: ServeConfig, cfg=None, params=None):
        self.sc = sc
        self.cfg = cfg if cfg is not None else (
            configs.get(sc.arch).reduced() if sc.reduced
            else configs.get(sc.arch))
        mesh = make_host_mesh()
        set_activation_mesh(mesh)
        self.params = params if params is not None else MD.init(
            self.cfg, jax.random.PRNGKey(sc.seed))
        s_max = sc.prompt_len + sc.max_new_tokens
        self._prefill = jax.jit(ST.make_serve_prefill(self.cfg, s_max=s_max))
        self._step = jax.jit(ST.make_serve_step(self.cfg))

    def generate(self, prompts: np.ndarray,
                 extra_batch: Optional[dict] = None) -> np.ndarray:
        """prompts: [B, prompt_len] int32 -> [B, max_new_tokens] int32."""
        sc = self.sc
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_batch:
            batch.update(extra_batch)
        if self.cfg.family == "audio" and "frames" not in batch:
            batch["frames"] = jnp.zeros(
                (prompts.shape[0], self.cfg.n_audio_ctx, self.cfg.d_model),
                self.cfg.param_dtype)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        key = jax.random.PRNGKey(sc.seed)
        for t in range(sc.max_new_tokens):
            if sc.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / sc.temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            outs.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache,
                                       tok.astype(jnp.int32))
        return np.stack(outs, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    sc = ServeConfig(arch=args.arch, batch_size=args.batch_size,
                     prompt_len=args.prompt_len,
                     max_new_tokens=args.max_new_tokens)
    srv = Server(sc)
    rng = np.random.default_rng(0)
    n_batches = -(-args.requests // sc.batch_size)
    t0 = time.perf_counter()
    total_tokens = 0
    for b in range(n_batches):
        prompts = rng.integers(0, srv.cfg.vocab_size,
                               size=(sc.batch_size, sc.prompt_len),
                               dtype=np.int32)
        out = srv.generate(prompts)
        total_tokens += out.size
        print(f"[serve] batch {b}: generated {out.shape} tokens; "
              f"sample: {out[0][:8].tolist()}")
    dt = time.perf_counter() - t0
    print(f"[serve] {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
