"""Quickstart: the whole MergeMoE story in one script, CPU-runnable.

1. train a tiny Qwen3-style MoE for a few dozen steps,
2. compress it with MergeMoE (experts 8 -> 4 in the suffix layers),
3. compare held-out loss against the M-SMoE / Average / ZipIt baselines,
4. serve the compressed model through the continuous-batching engine
   (request-level admission over the ragged grouped-kernel MoE path; see
   README "Serving engine").

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import compress as CMP
from repro.launch.train import TrainConfig, train
from repro.models import model as MD
from repro.serving import Engine, EngineConfig


def main():
    print("== 1. train a tiny MoE ==")
    out = train(TrainConfig(arch="qwen3-moe-30b-a3b", reduced=True, steps=60,
                            global_batch=4, seq_len=64, lr=3e-3,
                            log_every=20))
    cfg, params = out["cfg"], out["params"]

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (4, 64),
                                           0, cfg.vocab_size)}
             for i in range(2)]
    evalb = [{"tokens": jax.random.randint(jax.random.PRNGKey(100 + i),
                                           (4, 64), 0, cfg.vocab_size)}
             for i in range(3)]

    def eval_loss(c, p):
        return float(np.mean([float(MD.loss(c, p, b)[0]) for b in evalb]))

    print("\n== 2./3. compress with every strategy (8 -> 4 experts) ==")
    print(f"  {'full':10s} loss={eval_loss(cfg, params):.4f}  (uncompressed)")
    compressed = {}
    for method in ("mergemoe", "msmoe", "average", "zipit"):
        ncfg, nparams, info = CMP.compress_model(
            cfg, params, method=method, merged_experts=4, split=1,
            batches=calib)
        compressed[method] = (ncfg, nparams)
        print(f"  {method:10s} loss={eval_loss(ncfg, nparams):.4f}  "
              f"ratio={info['compression_ratio']:.3f}  "
              f"merge={info['t_merge_s']*1e3:.0f}ms")

    print("\n== 4. serve the MergeMoE-compressed model ==")
    ncfg, nparams = compressed["mergemoe"]
    eng = Engine(EngineConfig(n_slots=2, s_max=48, prefill_buckets=(16,)),
                 cfg=ncfg, params=nparams)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(rng.integers(0, ncfg.vocab_size, size=16, dtype=np.int32),
                   max_new_tokens=12)
    for r in eng.run():
        print(f"  request {r.uid}: generated {r.out_tokens} "
              f"[{r.finish_reason}]")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
