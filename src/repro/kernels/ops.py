"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy (ONE place, the :func:`pallas_dispatch` decorator): on TPU
backends the Pallas implementations run natively; on CPU (this container)
they run through the jnp oracle by default, while tests exercise the kernel
bodies via ``interpret=True``. The decorated function body IS the oracle
call, and the Pallas implementation is resolved lazily from the named
kernel module under the same public name — so adding a kernel variant is
one decorated two-liner, not a fifth copy of the policy.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
import inspect
from typing import Any, Callable, Dict, Optional

import jax

from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class KernelInfo:
    """Registry entry for one dispatched kernel: where its Pallas impl
    lives and the contract the static checker
    (``repro.analysis.kernel_contracts``) validates against every config."""
    name: str
    module: str                      # module under repro.kernels
    fn: Callable                     # the public dispatch wrapper
    extra_static: tuple
    contract: Optional[Dict[str, Any]]


#: every ``pallas_dispatch``-decorated kernel, by public name. The analysis
#: layer iterates this — registration IS the opt-in to contract checking.
KERNEL_REGISTRY: Dict[str, KernelInfo] = {}


def pallas_dispatch(kernel_module: str, extra_static: tuple = (),
                    contract: Optional[Dict[str, Any]] = None):
    """Decorator factory implementing the interpret/TPU dispatch policy.

    ``kernel_module``: module under ``repro.kernels`` holding the Pallas
    implementation, looked up lazily (Pallas imports stay off the default
    CPU path) under the decorated function's name. ``extra_static``: names
    of oracle parameters to treat as jit-static alongside ``interpret``;
    they may be passed positionally OR by keyword — a thin unjitted shim
    rebinds positionals against the oracle's signature so jit always sees
    them as static kwargs (the pre-decorator wrappers accepted positional
    ``causal``; silently tracing it would turn ``if causal:`` into a
    TracerBoolConversionError). The decorated body is the jnp-oracle
    fallback. ``contract``: shape/dtype contract metadata consumed by the
    static kernel checker — ``kind`` names the shape family the configs
    induce, ``quantized`` marks int8-table kernels.
    """
    def deco(oracle_fn):
        name = oracle_fn.__name__
        param_names = tuple(inspect.signature(oracle_fn).parameters)

        @functools.partial(jax.jit,
                           static_argnames=("interpret",) + extra_static)
        def jitted(*args, interpret: bool = False, **kw):
            if _on_tpu() or interpret:
                mod = importlib.import_module(f"repro.kernels.{kernel_module}")
                return getattr(mod, name)(*args, interpret=not _on_tpu(),
                                          **kw)
            return oracle_fn(*args, **kw)

        def _register(public):
            KERNEL_REGISTRY[name] = KernelInfo(
                name=name, module=kernel_module, fn=public,
                extra_static=extra_static, contract=contract)
            return public

        if not extra_static:
            jitted.__name__ = name
            jitted.__doc__ = oracle_fn.__doc__
            return _register(jitted)

        def wrapper(*args, **kw):
            # keywordize everything from the first positionally-passed
            # static param onward (positional slots cannot be skipped)
            cut = next((i for i, p in enumerate(param_names[:len(args)])
                        if p in extra_static), len(args))
            for i in range(cut, len(args)):
                kw[param_names[i]] = args[i]
            return jitted(*args[:cut], **kw)

        wrapper.__name__ = name
        wrapper.__doc__ = oracle_fn.__doc__
        return _register(wrapper)
    return deco


@pallas_dispatch("swiglu", contract={"kind": "swiglu", "quantized": False})
def swiglu_mlp(x, wg, wu, wd):
    return ref.swiglu_mlp(x, wg, wu, wd)


@pallas_dispatch("grouped_mlp", contract={"kind": "grouped",
                                          "quantized": False})
def grouped_swiglu(x, wg, wu, wd, group_sizes):
    return ref.grouped_swiglu(x, wg, wu, wd, group_sizes)


@pallas_dispatch("decode_moe", contract={"kind": "gather",
                                         "quantized": False})
def gather_swiglu(x, wg, wu, wd, idx, w):
    return ref.gather_swiglu(x, wg, wu, wd, idx, w)


@pallas_dispatch("grouped_mlp", contract={"kind": "grouped_q",
                                          "quantized": True})
def grouped_swiglu_q(x, qt, group_sizes):
    """Int8 grouped SwiGLU over a ``QuantizedExpertTables`` (DESIGN.md §8)."""
    return ref.grouped_swiglu_q(x, qt, group_sizes)


@pallas_dispatch("decode_moe", contract={"kind": "gather_q",
                                         "quantized": True})
def gather_swiglu_q(x, qt, idx, w):
    """Int8 decode-mode gather SwiGLU over a ``QuantizedExpertTables``."""
    return ref.gather_swiglu_q(x, qt, idx, w)


# ---------------------------------------------------------------------------
# expert-parallel (sharded-table) views of the gather kernels
# ---------------------------------------------------------------------------

def localize_expert_ids(idx, w, e_base, e_local: int):
    """Map GLOBAL real-expert ids onto this shard's LOCAL table rows.

    ``idx``: [T, k] int32 global ids; ``e_base``: traced scalar — the first
    global row this shard stores (``axis_index * e_local`` under shard_map);
    ``e_local``: static local row count. Rows owned elsewhere clip into
    range with their combine weight zeroed, so the kernels compute a
    contribution of exactly fp 0.0 for them — the combine stays bitwise
    whatever the foreign rows gather (DESIGN.md §13).
    """
    import jax.numpy as jnp
    lid = idx - e_base
    mine = (lid >= 0) & (lid < e_local)
    return jnp.clip(lid, 0, e_local - 1), jnp.where(mine, w, 0.0)


def gather_swiglu_sharded(x, wg, wu, wd, idx, w, e_base):
    """:func:`gather_swiglu` over one EP shard's expert-table slice.

    Same per-row arithmetic; ``idx`` stays in GLOBAL expert space and is
    offset by ``e_base`` (this shard's first row) before the gather."""
    lid, w = localize_expert_ids(idx, w, e_base, wg.shape[0])
    return gather_swiglu(x, wg, wu, wd, lid, w)


def gather_swiglu_q_sharded(x, qt, idx, w, e_base):
    """Int8 variant of :func:`gather_swiglu_sharded` (qexp table slice)."""
    lid, w = localize_expert_ids(idx, w, e_base, qt.wg.shape[0])
    return gather_swiglu_q(x, qt, lid, w)


@pallas_dispatch("flash_attention", extra_static=("causal",),
                 contract={"kind": "flash", "quantized": False})
def flash_attention(q, k, v, causal: bool = True):
    return ref.flash_attention(q, k, v, causal=causal)


@pallas_dispatch("paged_attention", contract={"kind": "paged",
                                              "quantized": False})
def paged_attention(q, kp, vp, tab, lens):
    """Paged decode attention over a block pool (DESIGN.md §11)."""
    return ref.paged_attention(q, kp, vp, tab, lens)


@pallas_dispatch("paged_attention", contract={"kind": "paged_q",
                                              "quantized": True,
                                              "int8_operands": 2,
                                              "f32_min_operands": 2})
def paged_attention_q(q, kp, vp, ks, vs, tab, lens):
    """Int8-pool paged decode attention with per-(row, head) fp32 scales."""
    return ref.paged_attention_q(q, kp, vp, ks, vs, tab, lens)
