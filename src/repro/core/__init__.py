from repro.core.errors import TechniqueInapplicable, CalibrationError  # noqa: F401
from repro.core.compress import compress_model  # noqa: F401
from repro.core.merge import merge_layer, MergeResult, METHODS  # noqa: F401
from repro.core.clustering import (  # noqa: F401
    cluster_experts, merge_weights, summation_matrix, mixing_matrix)
