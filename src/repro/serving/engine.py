"""Continuous-batching serving engine.

Replaces the fixed-batch loop (``launch.serve.FixedBatchServer``) with
request-level scheduling, the deployment path the paper's serving claim is
about: merged checkpoints route fewer, fuller expert groups through the
grouped kernel at identical arithmetic.

Design (decode dataflow details in DESIGN.md §7):

* **Slots.** The engine owns a persistent slotted KV cache
  (``[L, n_slots, s_max, nkv, hd]`` + per-slot ``pos``). A request occupies
  one slot from admission to completion; eviction just marks the slot free —
  stale rows are masked by the per-slot causal mask and overwritten in place
  by the next occupant (no copying, no reallocation).
* **Paged KV (``kv_layout='paged'``, DESIGN.md §11).** The dense slot cache
  is replaced by a flat pool of fixed-size KV blocks plus per-slot block
  tables owned by a host-side allocator (``serving.paging.PagedAllocator``):
  admission reserves a request's whole row budget
  (``prompt + max_new - 1``, plus ``spec_k`` verify headroom in spec mode)
  up front, full prompt blocks are shared copy-free between requests with
  identical prefixes (refcounted, LRU-evicted under pressure), eviction
  returns blocks to the pool, and admission DEFERS (FIFO head-of-line) when
  the pool cannot supply a reservation. ``kv_dtype='int8'`` stores the pool
  quantized with per-(row, head) fp32 scales — roughly half the decode KV
  stream of bf16. The bf16 paged engine is token-for-token IDENTICAL to the
  dense engine in every mode (plain / fused block / speculative); int8 is
  tolerance-gated instead (quantization perturbs logits).
* **Admission.** Pending requests sit in a heap ordered by
  ``(arrival_time, uid)`` (FIFO by arrival, O(log n) per op). At the top of
  every engine step each free slot claims the next due request, and all
  requests admitted together that share a prompt bucket are prefilled as ONE
  batch (padded to the next power of two to bound jit specializations) and
  inserted with one scatter — admission cost no longer scales with the burst
  size.
* **Decode.** The steady-state hot loop is DEVICE-RESIDENT: one jitted call
  runs ``decode_block`` (K) scanned decode steps with on-device sampling and
  per-slot stop flags; finished slots freeze in place and ride along. The
  host reads back one ``[K, B]`` token block per call instead of one token
  per step — host dispatches drop from ~2/token to ~2/(K·B) tokens.
  ``decode_block=1`` keeps the original step-at-a-time loop (the parity
  reference). With ``dispatch='gather'`` the decode-sized MoE layers skip
  the sort-based grouped path for the per-token gather kernel.
* **Stop conditions.** Per-request ``max_new_tokens`` and optional
  ``eos_token``, evaluated on device inside the fused block; freed slots
  admit at the next block boundary.
* **Speculative decoding.** With ``spec_draft`` (or direct
  ``draft_cfg``/``draft_params``) the engine runs dual-artifact
  draft-then-verify rounds (DESIGN.md §10): the MergeMoE-compressed draft
  proposes ``spec_k`` tokens per slot, the full model verifies them in one
  multi-position forward, and acceptance/rollback happens on device — all
  inside ONE jitted call per round. Committed tokens are always full-model
  samples, so spec mode is token-for-token identical to full-model decode
  at any temperature.

* **Resilience (DESIGN.md §12).** Requests carry optional deadlines/TTLs
  and terminate with an explicit ``status`` (``ok`` / ``shed`` /
  ``failed_numeric`` / ``failed``): expired pending requests are SHED with
  a reason (deferral-aware — a request stuck behind pool pressure sheds as
  ``pool_pressure``, not a bare timeout), the pending queue can be bounded
  with a reject-new or shed-expired-first backpressure policy, and a
  numeric-health sentinel rides the fused readback block as one extra
  lane (per-slot ``isfinite`` over the logits, zero additional host
  syncs) to QUARANTINE any slot that goes non-finite — evicted
  ``failed_numeric``, pages released, healthy slots bitwise untouched.
  A seeded ``serving.faults.FaultPlan`` injects NaN poisoning, transient
  device failures (bounded retry), and pool exhaustion deterministically,
  and ``Engine.snapshot()/restore()`` serialize the COMPLETE engine state
  (scheduler, allocator, prefix registry, KV pools, counters) so a
  mid-trace crash resumes token-for-token identical.

The clock is pluggable: ``clock='steps'`` interprets ``arrival_time`` in
decode-step units (deterministic — used by tests and the CPU benchmark),
``clock='wall'`` in seconds.

Sampling keys: every request gets the key ``fold_in(PRNGKey(seed+1), uid)``
at admission and tokens draw Gumbel noise indexed by their own sequence
position (``steps.sample_tokens``), so the sampled stream for a given
(seed, uid, prompt) is IDENTICAL across engine modes — step loop, fused
block, and speculative — and across scheduling differences between them.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import errors as ERR
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh
from repro.serving.faults import FaultPlan
from repro.serving.paging import PagedAllocator
from repro.serving.spec import (build_slot_admit_spec,
                                build_slot_admit_spec_paged,
                                build_slot_decode_spec)


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-filled result/telemetry."""
    uid: int
    prompt: np.ndarray                  # [prompt_len] int32
    max_new_tokens: int
    eos_token: Optional[int] = None
    arrival_time: float = 0.0           # steps or seconds, per engine clock
    # latest clock value at which admission may still start (inclusive);
    # ``ttl`` is the relative form (deadline = arrival_time + ttl) and is
    # ignored when ``deadline`` is set. None = wait forever (DESIGN.md §12).
    deadline: Optional[float] = None
    ttl: Optional[float] = None
    # engine-filled
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finished: Optional[float] = None
    finish_reason: Optional[str] = None  # "length" | "eos" | "shed" | "numeric"
    # terminal status: "queued" until terminal, then "ok" | "shed" |
    # "failed_numeric" | "failed"
    status: str = "queued"
    shed_reason: Optional[str] = None    # "deadline" | "pool_pressure"
    # True once admission deferred this request for lack of pool blocks —
    # a later expiry sheds it as "pool_pressure" rather than "deadline"
    deferred: bool = False

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def effective_deadline(self) -> Optional[float]:
        if self.deadline is not None:
            return self.deadline
        if self.ttl is not None:
            return self.arrival_time + self.ttl
        return None


@dataclasses.dataclass
class EngineConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    n_slots: int = 4
    s_max: int = 128                    # per-slot KV capacity
    prefill_buckets: Sequence[int] = (16, 32, 64)
    temperature: float = 0.0
    seed: int = 0
    # MoE dispatch for the serving path; "gather" = ragged with the decode
    # token counts specialized to the per-token gather kernel, "ragged"
    # forces the grouped kernel everywhere. None keeps the ModelConfig's.
    dispatch: Optional[str] = "gather"
    clock: str = "steps"                # "steps" | "wall"
    # fused decode block size K: decode steps per jitted call. 1 = the
    # step-at-a-time host loop (parity reference).
    decode_block: int = 8
    # prefill all due same-bucket requests as one batch (False = the
    # batch-of-1 admission loop, kept as the parity reference)
    batch_admission: bool = True
    # retrace/implicit-transfer guard mode (repro.analysis.trace_guard):
    # "count" surfaces violations in counters["retraces"] /
    # counters["implicit_transfers"], "strict" raises TraceGuardError,
    # "off" disables (plain jax.jit)
    trace_guard: str = "count"
    # self-speculative decoding (DESIGN.md §10): directory of a
    # ``save_compressed`` DRAFT artifact (the MergeMoE-merged model). None
    # disables spec mode; tests may instead hand (draft_cfg, draft_params)
    # straight to the Engine constructor.
    spec_draft: Optional[str] = None
    # draft proposals per verify round; each round commits 1..spec_k tokens
    spec_k: int = 4
    # KV cache layout: "dense" = the [L, n_slots, s_max, nkv, hd] slot
    # cache, "paged" = the block-pool layout (DESIGN.md §11)
    kv_layout: str = "dense"
    # paged layout knobs: KV rows per block (s_max must be a multiple),
    # pool size in blocks (0 = n_slots * s_max / kv_block, i.e. dense
    # capacity), pool storage dtype ("bf16" | "int8" — int8 carries
    # per-(row, head) fp32 scales), and copy-free prompt prefix sharing
    kv_block: int = 16
    kv_blocks: int = 0
    kv_dtype: str = "bf16"
    prefix_sharing: bool = True
    # ---- resilience (DESIGN.md §12) ----
    # numeric-health sentinel over the per-slot isfinite lane of the fused
    # readback block: "off" ignores the lane, "count" quarantines poisoned
    # slots (evict failed_numeric + counters["quarantined"]), "strict"
    # additionally raises NumericHealthError after quarantining — the same
    # mode ladder as trace_guard
    numeric_sentinel: str = "count"
    # bounded pending queue (0 = unbounded) + backpressure policy when
    # full: "reject_new" raises QueueFullError at submit; "shed_expired"
    # first sheds expired pending requests, then rejects if still full
    max_pending: int = 0
    backpressure: str = "reject_new"
    # bounded retry for transient device-step failures (injected by a
    # FaultPlan or, in the field, surfaced by the runtime): how many times
    # one step call may fail before DeviceStepError, and the exponential
    # backoff base between attempts (0 = retry immediately; tests keep 0)
    device_retries: int = 2
    retry_backoff_s: float = 0.0
    # ---- expert-parallel mesh serving (DESIGN.md §13) ----
    # mesh spec for sharded decode (``launch.mesh.parse_mesh_spec`` form,
    # e.g. "data=2,model=2"). None serves single-device (the default). The
    # "model" axis EP-shards the expert tables — MoE layers switch to the
    # all-to-all pair-exchange dispatch of ``models/moe_ep`` — and the
    # "data" axis shards slots + KV, so attention never crosses the wire.
    # Token-for-token identical to the single-device engine under the
    # default fp32 combine wire.
    mesh: Optional[str] = None
    # EP combine-wire dtype: "fp32" (bitwise-exact return all-to-all) or
    # "int8" (``distributed.compressed_psum`` of the pair-output table —
    # roughly 4x less combine wire, tolerance-gated instead of bitwise)
    combine_wire_dtype: str = "fp32"
    # ---- periodic background snapshots (§12) ----
    # > 0: persist :meth:`Engine.save_snapshot` to ``snapshot_dir`` every N
    # engine steps (as counted by the step clock), so a crash loses at most
    # N steps of committed work; 0 disables
    snapshot_every_steps: int = 0
    snapshot_dir: Optional[str] = None


class Engine:
    """Continuous-batching engine over a slotted KV cache."""

    def __init__(self, ec: EngineConfig, cfg=None, params=None,
                 draft_cfg=None, draft_params=None,
                 faults: Optional[FaultPlan] = None):
        self.ec = ec
        cfg = cfg if cfg is not None else (
            configs.get(ec.arch).reduced() if ec.reduced
            else configs.get(ec.arch))

        def _serve_dispatch(c):
            """Apply the engine's MoE dispatch override to a ModelConfig
            (shared by the full and draft configs so both artifacts serve
            under the same kernel policy)."""
            if c.moe is None or ec.dispatch is None:
                return c
            moe = dataclasses.replace(c.moe, dispatch=ec.dispatch)
            if ec.dispatch == "gather":
                # the gather ceiling must cover the decode token count
                # (T = n_slots) or big-slot engines would silently fall back
                # to ragged on every decode step
                moe = dataclasses.replace(
                    moe, gather_max_tokens=max(moe.gather_max_tokens,
                                               ec.n_slots))
            return c.replace(moe=moe)

        cfg = _serve_dispatch(cfg)
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"continuous batching serves token-only families "
                f"(dense/moe), not {cfg.family}")
        if ec.decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        if ec.numeric_sentinel not in ("off", "count", "strict"):
            raise ValueError(f"numeric_sentinel must be 'off', 'count' or "
                             f"'strict', got {ec.numeric_sentinel!r}")
        if ec.backpressure not in ("reject_new", "shed_expired"):
            raise ValueError(f"backpressure must be 'reject_new' or "
                             f"'shed_expired', got {ec.backpressure!r}")
        if ec.max_pending < 0 or ec.device_retries < 0:
            raise ValueError("max_pending and device_retries must be >= 0")
        if ec.combine_wire_dtype not in ("fp32", "int8"):
            raise ValueError(f"combine_wire_dtype must be 'fp32' or 'int8', "
                             f"got {ec.combine_wire_dtype!r}")
        if ec.snapshot_every_steps is None:    # None == 0 == disabled
            ec.snapshot_every_steps = 0
        if ec.snapshot_every_steps < 0:
            raise ValueError("snapshot_every_steps must be >= 0")
        if ec.snapshot_every_steps > 0 and not ec.snapshot_dir:
            raise ValueError("snapshot_every_steps > 0 requires snapshot_dir")
        self.cfg = cfg

        # ---- mesh-sharded serving (DESIGN.md §13) ----
        # ec.mesh builds an explicit (data, model) device mesh and swaps
        # every device program for its shard_map'd ``steps.make_*_mesh``
        # form. Activation sharding constraints (numerics.constrain) are
        # GSPMD-only and illegal inside shard_map bodies, so mesh mode
        # clears the activation mesh — the mesh programs manage layout
        # explicitly via their in/out specs.
        self._mesh = None
        if ec.mesh is not None:
            from repro.launch.mesh import parse_mesh_spec
            shape, axes = parse_mesh_spec(ec.mesh)
            self._mesh = jax.make_mesh(shape, axes)
            set_activation_mesh(None)
        else:
            mesh = make_host_mesh()
            set_activation_mesh(mesh)
        self._dp = (1 if self._mesh is None
                    else int(self._mesh.shape.get("data", 1)))
        if ec.n_slots % self._dp:
            raise ValueError(
                f"n_slots={ec.n_slots} must divide evenly over the mesh "
                f"'data' axis ({self._dp}): slots and their KV shard there")
        self.params = params if params is not None else MD.init(
            cfg, jax.random.PRNGKey(ec.seed))
        if self._mesh is not None:
            from repro.launch import sharding as SH
            SH.validate_ep_params(self.params, self._mesh)
            self.params = jax.device_put(self.params, SH.named(
                SH.serve_param_pspecs(self.params, self._mesh), self._mesh))

        # host<->device crossing telemetry: device_calls counts jitted
        # dispatches, host_syncs counts device->host readbacks, tokens_out
        # counts generated tokens (dispatches-per-token = their ratio);
        # tokens_drafted/accepted/rolled_back are spec-round bookkeeping
        # (zero outside spec mode); retraces/implicit_transfers are
        # maintained by the trace guard (DESIGN.md §9: both must stay 0
        # after warmup)
        self.counters: Dict[str, int] = {
            "device_calls": 0, "host_syncs": 0, "tokens_out": 0,
            "tokens_drafted": 0, "tokens_accepted": 0,
            "tokens_rolled_back": 0,
            # resilience telemetry (§12): all three stay 0 on a healthy,
            # uncontended trace — check_bench gates that on every
            # happy-path benchmark row
            "shed": 0, "quarantined": 0, "transient_retries": 0}
        from repro.analysis.trace_guard import TraceGuard
        self._guard = TraceGuard(ec.trace_guard, counters=self.counters)
        self._buckets = tuple(sorted(set(int(b) for b in ec.prefill_buckets)))
        # the ONLY prompt pad lengths admission may compile; bucket_for
        # fails closed on non-membership and admit_trace_budget counts this
        # same table, so the padding policy and the trace budget cannot
        # drift apart (steps.admit_pad_shapes is the single source of truth)
        self._pad_shapes = ST.admit_pad_shapes(self._buckets, ec.s_max)
        admit_budget = ST.admit_trace_budget(self._buckets, ec.s_max,
                                             ec.n_slots)

        # ---- KV layout: dense slot cache or paged block pool (§11) ----
        self._alloc: Optional[PagedAllocator] = None
        self._tab_dirty = False
        if ec.kv_layout == "paged":
            n_blocks = ec.kv_blocks if ec.kv_blocks > 0 else (
                ec.n_slots * ec.s_max // ec.kv_block)
            # the allocator validates s_max % kv_block (and, sharded, that
            # blocks and slots split evenly over the data axis so every
            # slot's reservation stays inside its shard's block range);
            # init_paged_cache validates kv_dtype
            self._alloc = PagedAllocator(
                n_slots=ec.n_slots, n_blocks=n_blocks,
                block_size=ec.kv_block, s_max=ec.s_max, n_shards=self._dp)
            self.cache = MD.init_paged_cache(
                cfg, ec.n_slots, ec.s_max, n_blocks=n_blocks,
                block_size=ec.kv_block, kv_dtype=ec.kv_dtype)
            self._tab_dirty = True
            admit_fn = (ST.make_slot_admit_paged(cfg)
                        if self._mesh is None else None)
        elif ec.kv_layout == "dense":
            if ec.kv_dtype != "bf16":
                raise ValueError(
                    f"kv_dtype={ec.kv_dtype!r} requires kv_layout='paged' "
                    f"(the dense slot cache stores the model dtype)")
            self.cache = MD.init_slot_cache(cfg, ec.n_slots, ec.s_max)
            admit_fn = (ST.make_slot_admit(cfg)
                        if self._mesh is None else None)
        else:
            raise ValueError(f"kv_layout must be 'dense' or 'paged', got "
                             f"{ec.kv_layout!r}")
        if self._mesh is not None:
            self.cache = self._place_cache(self.cache)
            admit_fn = (
                ST.make_slot_admit_paged_mesh(cfg, self._mesh, self.params,
                                              self.cache)
                if self._alloc is not None else
                ST.make_slot_admit_mesh(cfg, self._mesh, self.params,
                                        self.cache))
        # admission legitimately compiles one specialization per
        # (pad shape, pow2-group) pair; decode entry points get exactly ONE
        self._admit_step = self._guard.wrap_jit(
            "slot_admit", admit_fn, expected_traces=admit_budget)
        if self._mesh is not None:
            decode_fn = ST.make_slot_decode_mesh(
                cfg, self._mesh, self.params, self.cache,
                ec.combine_wire_dtype)
            multi_fn = ST.make_slot_decode_multi_mesh(
                cfg, ec.decode_block, ec.temperature, self._mesh,
                self.params, self.cache, ec.combine_wire_dtype)
        else:
            decode_fn = ST.make_slot_decode(cfg)
            multi_fn = ST.make_slot_decode_multi(cfg, ec.decode_block,
                                                 ec.temperature)
        self._decode = self._guard.wrap_jit(
            "slot_decode", decode_fn, expected_traces=1)
        self._decode_multi = self._guard.wrap_jit(
            "slot_decode_multi", multi_fn, expected_traces=1)

        # ---- speculative decoding (dual artifact, DESIGN.md §10) ----
        self.draft_artifact: Optional[dict] = None
        if ec.spec_draft is not None and draft_params is None:
            from repro.ckpt import checkpoint as CKPT
            draft_cfg, draft_params, self.draft_artifact = \
                CKPT.load_compressed(ec.spec_draft)
        self.spec = draft_params is not None
        self.draft_cfg = self.draft_params = None
        self.cache_draft = None
        if self.spec:
            if draft_cfg is None:
                raise ValueError("draft_params given without draft_cfg")
            draft_cfg = _serve_dispatch(draft_cfg)
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab_size} != full model vocab "
                    f"{cfg.vocab_size}: the draft must be a compression of "
                    f"the served model, not a different tokenizer")
            if ec.spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            self.draft_cfg, self.draft_params = draft_cfg, draft_params
            if self._mesh is not None:
                from repro.launch import sharding as SH
                SH.validate_ep_params(self.draft_params, self._mesh)
                self.draft_params = jax.device_put(
                    self.draft_params,
                    SH.named(SH.serve_param_pspecs(self.draft_params,
                                                   self._mesh), self._mesh))
            if self._alloc is not None:
                # the draft pool mirrors the full pool's block geometry and
                # shares the ONE allocator table (paging.PagedAllocator
                # docstring): a prefix shared in the full pool is shared in
                # the draft pool at the same block ids
                self.cache_draft = MD.init_paged_cache(
                    draft_cfg, ec.n_slots, ec.s_max, n_blocks=self._alloc.nb,
                    block_size=ec.kv_block, kv_dtype=ec.kv_dtype)
                admit_spec_fn = build_slot_admit_spec_paged(
                    cfg, draft_cfg, ec.temperature)
            else:
                self.cache_draft = MD.init_slot_cache(draft_cfg, ec.n_slots,
                                                      ec.s_max)
                admit_spec_fn = build_slot_admit_spec(cfg, draft_cfg,
                                                      ec.temperature)
            # the builders are wrapped directly (not via the steps.make_*
            # aliases) so the lint analyzer's maker-root walk sees the
            # closure bodies; one spec round per trace, same budget as the
            # single-model entries
            if self._mesh is not None:
                self.cache_draft = self._place_cache(self.cache_draft)
                admit_spec_fn = (
                    ST.make_slot_admit_spec_paged_mesh(
                        cfg, draft_cfg, ec.temperature, self._mesh,
                        self.params, self.draft_params, self.cache,
                        self.cache_draft)
                    if self._alloc is not None else
                    ST.make_slot_admit_spec_mesh(
                        cfg, draft_cfg, ec.temperature, self._mesh,
                        self.params, self.draft_params, self.cache,
                        self.cache_draft))
                decode_spec_fn = ST.make_slot_decode_spec_mesh(
                    cfg, draft_cfg, ec.spec_k, ec.temperature, self._mesh,
                    self.params, self.draft_params, self.cache,
                    self.cache_draft, ec.combine_wire_dtype)
            else:
                decode_spec_fn = build_slot_decode_spec(
                    cfg, draft_cfg, ec.spec_k, ec.temperature)
            self._decode_spec = self._guard.wrap_jit(
                "slot_decode_spec", decode_spec_fn, expected_traces=1)
            self._admit_spec = self._guard.wrap_jit(
                "slot_admit_spec", admit_spec_fn,
                expected_traces=admit_budget)

        self._slot_req: List[Optional[Request]] = [None] * ec.n_slots
        self._last_tok = np.zeros((ec.n_slots,), np.int32)
        self._active = np.zeros((ec.n_slots,), bool)
        # heap of (arrival_time, uid, seq, Request): admission is FIFO by
        # arrival regardless of submission order, O(log n) per push/pop.
        # The monotonic ``seq`` breaks (arrival, uid) ties so heapq never
        # falls through to comparing Request objects. It is a plain int
        # counter (not itertools.count) so snapshot()/restore() can
        # serialize it.
        self._pending: List[Tuple[float, int, int, Request]] = []
        self._seq_n = 0
        self._next_uid = 0
        self._step_count = 0
        self._t0: Optional[float] = None
        # uids of every pending/active request: duplicates are rejected at
        # submission because the sampling key is fold_in(base, uid) — an
        # in-flight collision would alias two requests' Gumbel streams
        self._inflight: set = set()
        # requests shed at SUBMIT time (backpressure) waiting to be
        # returned from the next step's finished list, so run() reports
        # every terminal request exactly once
        self._done_early: List[Request] = []
        # seeded fault-injection plan (serving.faults); None serves clean
        self._faults = faults
        self._zero_poison = np.zeros((ec.n_slots,), bool)
        # per-slot sampling keys: fold_in(base, uid) assigned at admission,
        # so the key travels with the REQUEST — the sampled stream for a
        # (seed, uid, prompt) is identical across engine modes/scheduling
        self._key_base = jax.random.PRNGKey(ec.seed + 1)
        self._slot_keys = np.zeros((ec.n_slots, 2), np.uint32)
        # plan/report extras when booted via from_checkpoint
        self.artifact: Optional[dict] = None
        # step count at the last periodic snapshot (snapshot_every_steps)
        self._last_snap = 0

    def _place_cache(self, cache):
        """Device-place a KV cache tree on the engine mesh per the serve
        layout (slots on "data"; block table replicated)."""
        from repro.launch import sharding as SH
        return jax.device_put(cache, SH.named(
            SH.slot_cache_pspecs(cache, self._mesh), self._mesh))

    @property
    def mesh(self):
        """The serving device mesh (None in single-device mode)."""
        return self._mesh

    # ------------------------------------------------------------------ API

    @classmethod
    def from_checkpoint(cls, directory, ec: Optional[EngineConfig] = None,
                        step: int | None = None) -> "Engine":
        """Boot an engine directly from a ``save_compressed`` artifact.

        The artifact's own ModelConfig (including per-layer merged-expert
        counts) and parameters are used verbatim; ``ec`` only controls
        serving knobs (slots, buckets, dispatch — gather by default). The
        executed plan and compression report are exposed as
        ``engine.artifact``."""
        from repro.ckpt import checkpoint as CKPT
        cfg, params, artifact = CKPT.load_compressed(directory, step=step)
        if ec is None:
            ec = EngineConfig(arch=cfg.name, reduced=False)
        eng = cls(ec, cfg=cfg, params=params)
        eng.artifact = artifact
        return eng

    # -------------------------------------------- snapshot / restore (§12)

    def _req_state(self, r: Request) -> Dict:
        return {
            "uid": int(r.uid), "prompt": [int(t) for t in r.prompt],
            "max_new_tokens": int(r.max_new_tokens),
            "eos_token": None if r.eos_token is None else int(r.eos_token),
            "arrival_time": float(r.arrival_time),
            "deadline": None if r.deadline is None else float(r.deadline),
            "ttl": None if r.ttl is None else float(r.ttl),
            "out_tokens": [int(t) for t in r.out_tokens],
            "t_admitted": r.t_admitted, "t_first_token": r.t_first_token,
            "status": r.status, "deferred": bool(r.deferred),
        }

    def snapshot(self) -> Dict:
        """Serialize the COMPLETE engine state: scheduler (pending heap +
        in-flight requests), slot occupancy, sampling keys, counters, the
        PagedAllocator (free list, refcounts, tables, prefix registry with
        LRU order), and both KV pools — everything needed for
        :meth:`restore` to finish the trace token-for-token identical to an
        uninterrupted run. The host part is JSON-safe; the ``arrays`` part
        holds np copies of the device caches (bf16 preserved exactly).
        Terminal requests are the caller's to keep — they are not engine
        state and are not serialized."""
        reqs: Dict[int, Request] = {}
        for _, _, _, r in self._pending:
            reqs[r.uid] = r
        for r in self._slot_req:
            if r is not None:
                reqs[r.uid] = r
        host = {
            "version": 1,
            "step_count": int(self._step_count),
            "next_uid": int(self._next_uid),
            "seq": int(self._seq_n),
            "counters": {k: int(v) for k, v in self.counters.items()},
            "requests": [self._req_state(r) for _, r in sorted(reqs.items())],
            "pending": [[float(a), int(u), int(s)]
                        for a, u, s, _ in self._pending],
            "slots": [None if r is None else int(r.uid)
                      for r in self._slot_req],
            "last_tok": [int(t) for t in self._last_tok],
            "active": [bool(a) for a in self._active],
            "slot_keys": self._slot_keys.tolist(),
            "alloc": (None if self._alloc is None
                      else self._alloc.state_dict()),
        }
        arrays = {"cache": jax.tree.map(
            lambda a: np.asarray(jax.device_get(a)), self.cache)}
        if self.cache_draft is not None:
            arrays["cache_draft"] = jax.tree.map(
                lambda a: np.asarray(jax.device_get(a)), self.cache_draft)
        return {"ec": dataclasses.asdict(self.ec), "host": host,
                "arrays": arrays}

    def save_snapshot(self, directory):
        """Persist :meth:`snapshot` through the checkpoint layer (atomic,
        COMMIT-marked, digest-verified on load). Returns the committed
        directory."""
        from repro.ckpt import checkpoint as CKPT
        snap = self.snapshot()
        ecd = dict(snap["ec"])
        ecd["prefill_buckets"] = list(ecd["prefill_buckets"])
        return CKPT.save(directory, self._step_count, snap["arrays"],
                         extras={"engine": {"ec": ecd,
                                            "host": snap["host"]}},
                         keep=0)

    def _maybe_snapshot(self) -> None:
        """Periodic background checkpointing (§12): with
        ``snapshot_every_steps > 0``, persist the full engine snapshot to
        ``snapshot_dir`` through the staged-commit checkpoint path whenever
        the step clock has advanced that far since the last one. Called at
        every step boundary, so a crash between snapshots loses at most one
        interval of committed work — :meth:`restore` on the directory
        resumes token-for-token."""
        every = self.ec.snapshot_every_steps
        if every > 0 and self._step_count - self._last_snap >= every:
            self.save_snapshot(self.ec.snapshot_dir)
            self._last_snap = self._step_count

    @classmethod
    def restore(cls, snap, cfg=None, params=None, draft_cfg=None,
                draft_params=None, faults: Optional[FaultPlan] = None,
                verify: bool = True) -> "Engine":
        """Rebuild an engine from :meth:`snapshot` output (dict) or a
        :meth:`save_snapshot` directory (path). Model parameters are NOT
        part of the snapshot — pass the same ``params``/``draft_params``
        the snapshotted engine served (or rely on the seeded ``MD.init``
        default for test-sized models). Disk restores verify the recorded
        ``tree_digest`` and refuse corrupted snapshots unless
        ``verify=False``."""
        if not isinstance(snap, dict):
            from repro.ckpt import checkpoint as CKPT
            arrays, extras = CKPT.load(snap, verify=verify)
            eng_x = extras.get("engine")
            if eng_x is None:
                raise ValueError(f"{snap} holds no engine snapshot "
                                 f"(missing 'engine' extras)")
            snap = {"ec": eng_x["ec"], "host": eng_x["host"],
                    "arrays": arrays}
        ecd = dict(snap["ec"])
        ecd["prefill_buckets"] = tuple(ecd["prefill_buckets"])
        eng = cls(EngineConfig(**ecd), cfg=cfg, params=params,
                  draft_cfg=draft_cfg, draft_params=draft_params,
                  faults=faults)
        eng._load_snapshot(snap)
        return eng

    def _load_snapshot(self, snap: Dict) -> None:
        host = snap["host"]
        if host.get("version") != 1:
            raise ValueError(f"unknown snapshot version "
                             f"{host.get('version')!r}")
        self._step_count = int(host["step_count"])
        self._next_uid = int(host["next_uid"])
        self._seq_n = int(host["seq"])
        self.counters.update({k: int(v)
                              for k, v in host["counters"].items()})
        reqs: Dict[int, Request] = {}
        for st in host["requests"]:
            r = Request(
                uid=int(st["uid"]),
                prompt=np.asarray(st["prompt"], np.int32),
                max_new_tokens=int(st["max_new_tokens"]),
                eos_token=(None if st["eos_token"] is None
                           else int(st["eos_token"])),
                arrival_time=float(st["arrival_time"]),
                deadline=(None if st["deadline"] is None
                          else float(st["deadline"])),
                ttl=None if st["ttl"] is None else float(st["ttl"]))
            r.out_tokens = [int(t) for t in st["out_tokens"]]
            r.t_admitted = st["t_admitted"]
            r.t_first_token = st["t_first_token"]
            r.status = st["status"]
            r.deferred = bool(st["deferred"])
            reqs[r.uid] = r
        self._pending = [(float(a), int(u), int(s), reqs[int(u)])
                         for a, u, s in host["pending"]]
        heapq.heapify(self._pending)
        self._slot_req = [None if u is None else reqs[int(u)]
                          for u in host["slots"]]
        self._last_tok = np.asarray(host["last_tok"], np.int32)
        self._active = np.asarray(host["active"], bool)
        self._slot_keys = np.asarray(host["slot_keys"], np.uint32)
        self._inflight = set(reqs)
        if self._alloc is not None:
            if host["alloc"] is None:
                raise ValueError("snapshot has no allocator state but the "
                                 "restored engine is paged")
            self._alloc.load_state(host["alloc"])
            self._tab_dirty = True
        arrays = snap["arrays"]
        self.cache = jax.tree.map(jnp.asarray, arrays["cache"])
        if self.cache_draft is not None:
            self.cache_draft = jax.tree.map(jnp.asarray,
                                            arrays["cache_draft"])
        if self._mesh is not None:
            self.cache = self._place_cache(self.cache)
            if self.cache_draft is not None:
                self.cache_draft = self._place_cache(self.cache_draft)
        # the restored step count is the new snapshot epoch — without this a
        # periodic-snapshot engine would re-snapshot at its very first step
        self._last_snap = self._step_count

    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def idle(self) -> bool:
        return (not self._pending and not self._active.any()
                and not self._done_early)

    @property
    def steps(self) -> int:
        """Decode steps taken so far (the 'steps' clock's current time)."""
        return self._step_count

    @property
    def host_dispatches_per_token(self) -> float:
        """Host<->device crossings (jit dispatches + readbacks) per
        generated token so far."""
        c = self.counters
        return (c["device_calls"] + c["host_syncs"]) / max(c["tokens_out"], 1)

    def _validate_request(self, prompt: np.ndarray,
                          max_new_tokens: int) -> None:
        """Reject requests that cannot be served, with the reason spelled
        out. A prompt must carry only real vocabulary ids (out-of-range ids
        would silently clamp at the embedding gather and serve garbage),
        must fit its prefill bucket AND leave generation room in the slot;
        anything longer used to be silently clamped by ``bucket_for`` and
        would corrupt the slot — now it is an error at SUBMISSION time (the
        only place the caller can react). All raises are typed
        (``core.errors``) and subclass ``ValueError`` for compatibility."""
        if prompt.size == 0:
            raise ERR.RequestValidationError("empty prompt")
        if max_new_tokens < 1:
            raise ERR.RequestValidationError("max_new_tokens must be >= 1")
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ERR.InvalidTokenError(
                f"prompt token ids must lie in [0, {self.cfg.vocab_size}) "
                f"(vocab size of the served model); got ids spanning "
                f"[{lo}, {hi}]")
        big = min(max(self._buckets, default=1), self.ec.s_max)
        if prompt.size > self.ec.s_max:
            raise ERR.RequestValidationError(
                f"prompt length {prompt.size} cannot fit any prefill bucket: "
                f"the largest admissible bucket is capped by slot capacity "
                f"s_max={self.ec.s_max} (declared buckets "
                f"{tuple(self._buckets)} top out at {big}); shorten the "
                f"prompt or raise s_max")
        # a request consumes prompt + max_new - 1 KV rows: positions
        # 0 .. prompt+max_new-2 are written (the FINAL sampled token is
        # emitted but never fed back, so its KV row is never needed). The
        # bound is therefore s_max + 1, not s_max — the old check rejected
        # the exactly-fitting request at the boundary.
        if prompt.size + max_new_tokens > self.ec.s_max + 1:
            raise ERR.RequestValidationError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"needs {prompt.size + max_new_tokens - 1} KV rows, more "
                f"than slot capacity s_max={self.ec.s_max} (the final "
                f"sampled token occupies no row, so the bound is "
                f"prompt + max_new <= s_max + 1)")
        # speculative verify writes up to spec_k lookahead rows past the
        # committed stream (rows pos0 .. pos0+spec_k with pos0 up to
        # prompt+max_new-2), so spec mode needs that much extra headroom —
        # without this check the last verify rounds of a capacity-filling
        # request scatter past s_max (dense: clipped into the last row,
        # paged: dropped at the sentinel), silently corrupting or staling
        # the KV its own acceptance then reads
        if self.spec and (prompt.size + max_new_tokens + self.ec.spec_k
                          > self.ec.s_max + 1):
            raise ERR.RequestValidationError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"+ spec_k ({self.ec.spec_k}) exceeds s_max + 1 = "
                f"{self.ec.s_max + 1}: speculative verify needs spec_k KV "
                f"rows of lookahead headroom past the committed stream; "
                f"shorten the request, lower spec_k, or raise s_max")

    def submit(self, prompt, max_new_tokens: int, eos_token: int | None = None,
               arrival_time: float = 0.0, uid: int | None = None,
               deadline: float | None = None,
               ttl: float | None = None) -> Request:
        """Queue one request. ``deadline``/``ttl`` bound how long it may
        WAIT for admission (engine-clock units); past it the engine sheds
        the request with a reason instead of serving stale work. Raises
        typed errors (``core.errors``): RequestValidationError /
        InvalidTokenError for unservable requests, DuplicateUidError for an
        in-flight uid collision, QueueFullError when the bounded pending
        queue rejects under the backpressure policy."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self._validate_request(prompt, max_new_tokens)
        if uid is not None and uid in self._inflight:
            raise ERR.DuplicateUidError(
                f"uid {uid} is already in flight (pending or active): "
                f"in-flight uids must be unique — the sampling key is "
                f"fold_in(base, uid), so a duplicate would alias two "
                f"requests' Gumbel noise streams (DESIGN.md §10/§12)")
        self._apply_backpressure()
        if uid is None:
            uid = self._next_uid
        self._next_uid = max(self._next_uid, uid) + 1
        req = Request(uid=uid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_token=eos_token, arrival_time=arrival_time,
                      deadline=deadline, ttl=ttl)
        self._enqueue(req)
        return req

    def _enqueue(self, req: Request) -> None:
        if req.uid in self._inflight:
            raise ERR.DuplicateUidError(
                f"uid {req.uid} is already in flight (pending or active)")
        self._inflight.add(req.uid)
        self._seq_n += 1
        heapq.heappush(self._pending,
                       (req.arrival_time, req.uid, self._seq_n, req))

    def _apply_backpressure(self) -> None:
        """Enforce the bounded pending queue (§12 shed policy). With
        ``backpressure='shed_expired'`` a full queue first sheds every
        already-expired pending request (they could never be admitted
        anyway), making room without dropping live work; ``'reject_new'``
        — and a still-full queue after shedding — raises QueueFullError."""
        if not self.ec.max_pending \
                or len(self._pending) < self.ec.max_pending:
            return
        if self.ec.backpressure == "shed_expired":
            now = self._now()
            kept = []
            for entry in self._pending:
                r = entry[-1]
                dl = r.effective_deadline
                if dl is not None and now > dl:
                    self._shed(r, now,
                               "pool_pressure" if r.deferred else "deadline")
                    self._done_early.append(r)
                else:
                    kept.append(entry)
            if len(kept) < len(self._pending):
                self._pending = kept
                heapq.heapify(self._pending)
        if len(self._pending) >= self.ec.max_pending:
            raise ERR.QueueFullError(
                f"pending queue full "
                f"({len(self._pending)}/{self.ec.max_pending}) and "
                f"backpressure policy {self.ec.backpressure!r} could not "
                f"make room")

    def _shed(self, req: Request, now: float, reason: str) -> None:
        """Terminate a pending request without serving it (§12). Shed
        requests keep any tokens they never had (none — shedding only
        happens before admission), carry ``status='shed'`` plus the
        reason, and count toward ``counters['shed']``."""
        req.status = "shed"
        req.shed_reason = reason
        req.finish_reason = "shed"
        req.t_finished = now
        self.counters["shed"] += 1
        self._inflight.discard(req.uid)

    def step(self, now: float | None = None) -> List[Request]:
        """Admit due requests, run ONE decode step, evict finished.
        Returns the requests that finished during this step. This is the
        step-at-a-time reference loop; :meth:`step_block` is the fused
        production path (``run`` picks by ``decode_block``)."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        quarantined: List[Request] = []
        if self._active.any():
            # host->device conversions happen HERE, before the guard arms:
            # inside the guarded call every argument is already device-side
            self._sync_tab()
            toks = jnp.asarray(self._last_tok)
            act = jnp.asarray(self._active)
            poison = jnp.asarray(self._poison_mask(1))
            logits, aux, self.cache = self._with_retries(
                "decode", "slot_decode",
                lambda: self._guard.run("slot_decode", self._decode,
                                        self.params, self.cache, toks, act,
                                        poison))
            self.counters["device_calls"] += 1
            sentinel = self.ec.numeric_sentinel != "off"
            aux_np = None
            if self.ec.temperature <= 0.0:
                aux_np = np.asarray(aux)    # ONE readback: (greedy, finite)
                self.counters["host_syncs"] += 1
                next_toks = aux_np[:, 0]
            else:
                next_toks = self._sample(logits, None, self._slot_keys,
                                         self._positions())
                self.counters["host_syncs"] += 1
                if sentinel:
                    # reference-loop-only extra readback: the fused paths
                    # carry the sentinel inside their one block transfer
                    aux_np = np.asarray(aux)
                    self.counters["host_syncs"] += 1
            for slot in np.flatnonzero(self._active):
                req = self._slot_req[slot]
                if sentinel and aux_np is not None and not aux_np[slot, 1]:
                    quarantined.append(self._quarantine(slot, now))
                    finished.append(req)
                    continue
                tok = int(next_toks[slot])
                req.out_tokens.append(tok)
                self.counters["tokens_out"] += 1
                self._last_tok[slot] = tok
                if self._is_done(req, tok):
                    self._evict(slot, now)
                    finished.append(req)
        self._step_count += 1
        self._maybe_snapshot()
        self._raise_if_strict(quarantined)
        return finished

    def step_block(self, now: float | None = None) -> List[Request]:
        """Admit due requests, then run ``decode_block`` fused decode steps
        in ONE device call (DESIGN.md §7). Returns finished requests; their
        ``t_finished`` is the block-start clock plus the inner step they
        stopped at, so step accounting matches the per-step loop."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        K = self.ec.decode_block
        if not self._active.any():
            # nothing to decode: advance one step so arrival admission keeps
            # fine-grained timing while the engine drains the future queue
            self._step_count += 1
            self._maybe_snapshot()
            return finished
        n = self.ec.n_slots
        rem = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        slots = np.flatnonzero(self._active)
        for s in slots:
            req = self._slot_req[s]
            rem[s] = req.max_new_tokens - len(req.out_tokens)
            eos[s] = -1 if req.eos_token is None else req.eos_token
        # convert np inputs OUTSIDE the guarded region (explicit H2D); the
        # guarded fused block itself must touch the host zero times
        self._sync_tab()
        args = (self.params, self.cache, jnp.asarray(self._last_tok),
                jnp.asarray(self._active), jnp.asarray(rem),
                jnp.asarray(eos), jnp.asarray(self._slot_keys),
                jnp.asarray(self._poison_mask(K)))
        block, _, self.cache = self._with_retries(
            "decode", "slot_decode_multi",
            lambda: self._guard.run("slot_decode_multi", self._decode_multi,
                                    *args))
        self.counters["device_calls"] += 1
        # ONE readback: [K, B, (tok, emit, finite)] — the numeric sentinel
        # lane rides the same transfer (§12: zero additional host syncs)
        block_np = np.asarray(block)
        self.counters["host_syncs"] += 1
        sentinel = self.ec.numeric_sentinel != "off"
        quarantined: List[Request] = []
        for s in slots:
            req = self._slot_req[s]
            for j in range(K):
                if not block_np[j, s, 1]:
                    break
                t_j = now + j if self.ec.clock == "steps" else self._now()
                if sentinel and not block_np[j, s, 2]:
                    # tokens 0..j-1 already matched the fault-free stream;
                    # token j was sampled from non-finite logits — truncate
                    # there and quarantine the slot
                    quarantined.append(self._quarantine(s, t_j))
                    finished.append(req)
                    break
                tok = int(block_np[j, s, 0])
                req.out_tokens.append(tok)
                self.counters["tokens_out"] += 1
                self._last_tok[s] = tok
                if self._is_done(req, tok):
                    # steps clock: finish = block start + inner step. Wall
                    # clock has no per-inner-step timestamps (the block is
                    # one device call) — stamp the post-block wall time.
                    self._evict(s, t_j)
                    finished.append(req)
                    break
        self._step_count += K
        self._maybe_snapshot()
        self._raise_if_strict(quarantined)
        return finished

    def step_spec(self, now: float | None = None) -> List[Request]:
        """Admit due requests, then run ONE fused draft/verify round
        (DESIGN.md §10): ``spec_k`` draft-model decode steps, one full-model
        verify forward, acceptance/rollback — all in one device call.
        Returns finished requests. The step clock advances by ``spec_k``
        per round (the round's draft depth), so Poisson arrival traces in
        step units drain at the fused block's granularity, like §7."""
        now = self._now() if now is None else now
        finished = self._admit(now)
        K = self.ec.spec_k
        if not self._active.any():
            self._step_count += 1
            self._maybe_snapshot()
            return finished
        n = self.ec.n_slots
        rem = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        slots = np.flatnonzero(self._active)
        for s in slots:
            req = self._slot_req[s]
            rem[s] = req.max_new_tokens - len(req.out_tokens)
            eos[s] = -1 if req.eos_token is None else req.eos_token
        self._sync_tab()
        args = (self.params, self.draft_params, self.cache, self.cache_draft,
                jnp.asarray(self._last_tok), jnp.asarray(self._active),
                jnp.asarray(rem), jnp.asarray(eos),
                jnp.asarray(self._slot_keys),
                jnp.asarray(self._poison_mask(K)))
        block, _, self.cache, self.cache_draft = self._with_retries(
            "decode", "slot_decode_spec",
            lambda: self._guard.run("slot_decode_spec", self._decode_spec,
                                    *args))
        self.counters["device_calls"] += 1
        # ONE readback: rows 0..K-1 = (token, emitted, finite) like
        # step_block (sentinel lane over the VERIFY logits), row K =
        # (accepted drafts, drafted, 1) per slot
        block_np = np.asarray(block)
        self.counters["host_syncs"] += 1
        sentinel = self.ec.numeric_sentinel != "off"
        quarantined: List[Request] = []
        for s in slots:
            req = self._slot_req[s]
            for j in range(K):
                if not block_np[j, s, 1]:
                    break
                t_j = now + j if self.ec.clock == "steps" else self._now()
                if sentinel and not block_np[j, s, 2]:
                    quarantined.append(self._quarantine(s, t_j))
                    finished.append(req)
                    break
                tok = int(block_np[j, s, 0])
                req.out_tokens.append(tok)
                self.counters["tokens_out"] += 1
                self._last_tok[s] = tok
                if self._is_done(req, tok):
                    self._evict(s, t_j)
                    finished.append(req)
                    break
            n_match = int(block_np[K, s, 0])
            drafted = int(block_np[K, s, 1])
            self.counters["tokens_drafted"] += drafted
            self.counters["tokens_accepted"] += n_match
            self.counters["tokens_rolled_back"] += drafted - n_match
        self._step_count += K
        self._maybe_snapshot()
        self._raise_if_strict(quarantined)
        return finished

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the full model accepted so far."""
        return (self.counters["tokens_accepted"]
                / max(self.counters["tokens_drafted"], 1))

    def run(self, requests: Sequence[Request] | None = None) -> List[Request]:
        """Drive until every pending/submitted request completes."""
        if requests:
            # externally built Request objects get the same admission
            # contract as submit() — an oversized prompt must fail here, not
            # deep inside a prefill scatter. Validate the WHOLE batch before
            # enqueuing anything, so a rejected call leaves the engine
            # exactly as it found it (no half-enqueued requests).
            seen = set()
            for r in requests:
                self._validate_request(np.asarray(r.prompt, np.int32),
                                       r.max_new_tokens)
                if r.uid in self._inflight or r.uid in seen:
                    raise ERR.DuplicateUidError(
                        f"uid {r.uid} is already in flight (or appears "
                        f"twice in this batch): in-flight uids must be "
                        f"unique — the sampling key is fold_in(base, uid)")
                seen.add(r.uid)
            for r in requests:
                self._enqueue(r)
        if self.spec:
            advance = self.step_spec
        elif self.ec.decode_block > 1:
            advance = self.step_block
        else:
            advance = self.step
        done: List[Request] = []
        while not self.idle:
            done.extend(advance())
        return sorted(done, key=lambda r: r.uid)

    def expert_weight_dtypes(self, params=None) -> Tuple[str, str]:
        """(prefix, suffix/uncompressed) expert-table storage dtypes,
        inferred from the parameter tree ('int8' when a stack carries the
        quantized ``qexp`` subtree, DESIGN.md §8). ``params`` defaults to
        the served model; pass ``self.draft_params`` for the draft."""
        params = self.params if params is None else params

        def one(stack_key):
            stack = params.get(stack_key)
            if stack is None or "moe" not in stack:
                return "bf16"
            return "int8" if "qexp" in stack["moe"] else "bf16"
        return one("stack"), one("stack_c" if "stack_c" in params
                                 else "stack")

    @property
    def kv_dtype_served(self) -> str:
        """KV storage dtype actually in the cache ('int8' only for the
        quantized paged pool)."""
        return ("int8" if self._alloc is not None
                and self.ec.kv_dtype == "int8" else "bf16")

    @property
    def paging_stats(self) -> Dict[str, int]:
        """Allocator telemetry (prefix hits/rows shared, deferrals, registry
        evictions, CoW copies, free blocks); empty in dense layout."""
        if self._alloc is None:
            return {}
        return dict(self._alloc.stats, free_blocks=self._alloc.free_blocks)

    def _bench_tab(self) -> jax.Array:
        """Scratch identity block table for the admission-bypassing
        benchmarks: block ``j`` of slot ``s`` maps to pool block
        ``(s*mb + j) % n_blocks`` (the default pool size makes the modulus a
        no-op; a smaller pool aliases blocks across slots, which is fine for
        a throughput measurement — the bytes moved per step are identical)."""
        n, mb, nb = self.ec.n_slots, self._alloc.mb, self._alloc.nb
        tab = np.full((n + 1, mb), nb, np.int32)
        tab[:n] = np.arange(n * mb, dtype=np.int32).reshape(n, mb) % nb
        return jnp.asarray(tab)

    def modeled_decode_traffic(self, pos: int | None = None) -> Dict[str, float]:
        """Analytic HBM bytes for one steady-state decode step of this
        engine (``launch.hlo_analysis.decode_traffic_model`` at the served
        config, weight dtypes read off the actual parameter tree, KV dtype
        off the cache layout). ``pos`` defaults to mid-cache, matching
        :meth:`bench_decode`'s scratch state."""
        from repro.launch.hlo_analysis import decode_traffic_model
        prefix_dt, suffix_dt = self.expert_weight_dtypes()
        return decode_traffic_model(
            self.cfg, n_slots=self.ec.n_slots,
            pos=self.ec.s_max // 2 if pos is None else pos,
            weight_dtype=suffix_dt, prefix_weight_dtype=prefix_dt,
            kv_dtype=self.kv_dtype_served, **self._mesh_model_kwargs())

    def bench_decode(self, iters: int = 50,
                     k_steps: int | None = None) -> Dict[str, float]:
        """Steady-state decode throughput with every slot active, bypassing
        admission — isolates the jitted fused loop from scheduler overhead.

        Runs ``iters`` fused ``k_steps``-step blocks (default: the engine's
        ``decode_block``) on a scratch copy of the cache and returns
        ``{"tok_per_s", "dispatches_per_s", "host_dispatches_per_token",
        "k_steps"}`` — tokens/sec AND host dispatches/sec, since the fused
        loop improves the latter even where CPU model math dominates the
        former — plus the MODELED HBM traffic of the served config
        (``hbm_bytes_per_token``, ``moe_expert_bytes_per_token``) and the
        bandwidth-roofline ceiling it implies
        (``roofline_tok_per_s = 1/max(t_memory, t_compute)`` from
        ``hlo_analysis.roofline_terms``, with ``roofline_fraction`` = the
        measured tok/s against it; on CPU that fraction is noise — the
        modeled bytes are the portable signal). The ``pos`` reset needed to
        keep the scratch cache in bounds is fused INTO the jitted block (no
        host-side clamp op inside the timed loop, which previously added a
        dispatch per iteration and skewed the measurement)."""
        K = int(self.ec.decode_block if k_steps is None else k_steps)
        n = self.ec.n_slots
        s_max = self.ec.s_max
        if K >= s_max // 2:
            raise ValueError(f"k_steps={K} too large for s_max={s_max}")
        multi = (ST.make_slot_decode_multi_mesh(
                     self.cfg, K, self.ec.temperature, self._mesh,
                     self.params, self.cache, self.ec.combine_wire_dtype)
                 if self._mesh is not None else
                 ST.make_slot_decode_multi(self.cfg, K, self.ec.temperature))

        def block(params, cache, toks, act, rem, eos, keys, poison):
            # keep pos in bounds ON DEVICE: reset to mid-cache before the
            # scanned steps would run past the last slot row
            pos = cache["pos"]
            pos = jnp.where(pos + K >= s_max, s_max // 2, pos)
            return multi(params, dict(cache, pos=pos), toks, act, rem, eos,
                         keys, poison)

        fn = jax.jit(block)
        cache = jax.tree.map(jnp.copy, self.cache)
        cache["pos"] = jnp.full((n,), s_max // 2, jnp.int32)
        if self._alloc is not None:
            cache["tab"] = self._bench_tab()
        toks = jnp.zeros((n,), jnp.int32)
        act = jnp.ones((n,), bool)
        rem = jnp.full((n,), np.iinfo(np.int32).max // 2, jnp.int32)
        eos = jnp.full((n,), -1, jnp.int32)
        poison = jnp.zeros((n,), bool)
        # seeded like every other sampled path (EngineConfig.seed), so a
        # temperature>0 benchmark decode is reproducible run to run
        keys = jax.random.split(jax.random.PRNGKey(self.ec.seed), n)
        out, _, cache = fn(self.params, cache, toks, act, rem, eos, keys,
                           poison)
        jax.block_until_ready(out)                                   # warm
        # the timed loop runs under transfer_guard("disallow"): a benchmark
        # number that silently included an implicit host transfer per block
        # would overstate dispatch savings — better to fail loudly here
        with jax.transfer_guard("disallow"):
            t0 = time.perf_counter()
            for _ in range(iters):
                out, _, cache = fn(self.params, cache, toks, act, rem, eos,
                                   keys, poison)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
        tok_per_s = n * K * iters / dt
        from repro.launch.hlo_analysis import roofline_terms
        traffic = self.modeled_decode_traffic()
        terms = roofline_terms(traffic["flops_per_token"],
                               traffic["bytes_per_token"],
                               traffic["interconnect_bytes_per_token"])
        roof = 1.0 / max(terms["t_memory_s"], terms["t_compute_s"],
                         terms["t_collective_s"], 1e-30)
        return {
            "tok_per_s": tok_per_s,
            "dispatches_per_s": iters / dt,
            # 1 jitted call + 1 readback per block — same crossings-counting
            # definition as Engine.host_dispatches_per_token
            "host_dispatches_per_token": 2.0 / (n * K),
            "k_steps": K,
            # modeled traffic (TPU roofline target, not a host measurement)
            "hbm_bytes_per_token": traffic["bytes_per_token"],
            "moe_expert_bytes_per_token":
                traffic["moe_expert_bytes_per_token"],
            "interconnect_bytes_per_token":
                traffic["interconnect_bytes_per_token"],
            "roofline_tok_per_s": roof,
            "roofline_fraction": tok_per_s / roof,
        }

    def modeled_spec_decode_traffic(self, mean_committed: float,
                                    pos: int | None = None,
                                    n_slots: int | None = None
                                    ) -> Dict[str, float]:
        """Analytic HBM bytes per COMMITTED token for one draft/verify
        round of this engine (``hlo_analysis.spec_decode_traffic_model``,
        weight dtypes read off both parameter trees). ``mean_committed``
        is the measured tokens committed per slot per round — acceptance
        is an empirical property of the (draft, model) pair, so the model
        takes it as input rather than guessing. ``n_slots`` lets callers
        re-model the same artifacts at deployment batch sizes (the
        expert-stream saturation point moves with it, DESIGN.md §10)."""
        from repro.launch.hlo_analysis import spec_decode_traffic_model
        prefix_dt, suffix_dt = self.expert_weight_dtypes()
        d_prefix_dt, d_suffix_dt = self.expert_weight_dtypes(
            self.draft_params)
        return spec_decode_traffic_model(
            self.cfg, self.draft_cfg, k_draft=self.ec.spec_k,
            n_slots=self.ec.n_slots if n_slots is None else n_slots,
            pos=self.ec.s_max // 2 if pos is None else pos,
            mean_committed=mean_committed,
            weight_dtype=suffix_dt, prefix_weight_dtype=prefix_dt,
            draft_weight_dtype=d_suffix_dt,
            draft_prefix_weight_dtype=d_prefix_dt,
            kv_dtype=self.kv_dtype_served, **self._mesh_model_kwargs())

    def _mesh_model_kwargs(self) -> Dict[str, float]:
        """EP/DP degrees of the serving mesh for the analytic traffic
        models (1/1 when single-device)."""
        if self._mesh is None:
            return {}
        return dict(ep_degree=int(self._mesh.shape.get("model", 1)),
                    dp_degree=int(self._mesh.shape.get("data", 1)),
                    combine_wire_dtype=self.ec.combine_wire_dtype)

    def bench_spec_decode(self, iters: int = 50) -> Dict[str, float]:
        """Steady-state speculative throughput with every slot active,
        bypassing admission — the spec-mode sibling of :meth:`bench_decode`.

        Runs ``iters`` fused draft/verify rounds on scratch copies of both
        caches. The next round's input token (the last committed verify
        sample) is computed ON DEVICE inside the jitted wrapper, so the
        timed loop has zero host readbacks — the per-round blocks are
        collected device-side and summed after the clock stops. Returns
        measured committed tok/s, per-round acceptance telemetry, and the
        modeled spec traffic of the served artifact pair at the MEASURED
        acceptance (``spec_bytes_per_token``, ``modeled_speedup`` vs the
        full-model decode roofline; on CPU the measured tok/s is
        FLOPs-bound and the modeled bytes are the portable signal, same
        stance as :meth:`bench_decode`)."""
        if not self.spec:
            raise ValueError("bench_spec_decode requires spec mode "
                             "(spec_draft / draft_params)")
        K = self.ec.spec_k
        n = self.ec.n_slots
        s_max = self.ec.s_max
        if K + 1 >= s_max // 2:
            raise ValueError(f"spec_k={K} too large for s_max={s_max}")
        spec = (ST.make_slot_decode_spec_mesh(
                    self.cfg, self.draft_cfg, K, self.ec.temperature,
                    self._mesh, self.params, self.draft_params, self.cache,
                    self.cache_draft, self.ec.combine_wire_dtype)
                if self._mesh is not None else
                ST.make_slot_decode_spec(self.cfg, self.draft_cfg, K,
                                         self.ec.temperature))

        def round_(params, dparams, cache, dcache, toks, act, rem, eos,
                   keys, poison):
            # keep pos in bounds ON DEVICE; both caches share one pos by
            # construction, so reset both from the full model's
            pos = cache["pos"]
            pos = jnp.where(pos + K + 1 >= s_max, s_max // 2, pos)
            block, _, cache, dcache = spec(
                params, dparams, dict(cache, pos=pos), dict(dcache, pos=pos),
                toks, act, rem, eos, keys, poison)
            # next input token = last committed verify sample, computed on
            # device so the timed loop never reads the block back
            emit = block[:K, :, 1]
            n_c = jnp.sum(emit, axis=0)
            last = jnp.take_along_axis(
                block[:K, :, 0], jnp.maximum(n_c - 1, 0)[None, :], axis=0)[0]
            toks = jnp.where(n_c > 0, last, toks)
            return block, toks, cache, dcache

        fn = jax.jit(round_)
        cache = jax.tree.map(jnp.copy, self.cache)
        cache["pos"] = jnp.full((n,), s_max // 2, jnp.int32)
        dcache = jax.tree.map(jnp.copy, self.cache_draft)
        if self._alloc is not None:
            cache["tab"] = dcache["tab"] = self._bench_tab()
        toks = jnp.zeros((n,), jnp.int32)
        act = jnp.ones((n,), bool)
        rem = jnp.full((n,), np.iinfo(np.int32).max // 2, jnp.int32)
        eos = jnp.full((n,), -1, jnp.int32)
        poison = jnp.zeros((n,), bool)
        keys = jax.random.split(jax.random.PRNGKey(self.ec.seed), n)
        block, toks, cache, dcache = fn(self.params, self.draft_params,
                                        cache, dcache, toks, act, rem, eos,
                                        keys, poison)
        jax.block_until_ready(block)                                 # warm
        blocks = []
        with jax.transfer_guard("disallow"):
            t0 = time.perf_counter()
            for _ in range(iters):
                block, toks, cache, dcache = fn(
                    self.params, self.draft_params, cache, dcache, toks,
                    act, rem, eos, keys, poison)
                blocks.append(block)
            jax.block_until_ready(block)
            dt = time.perf_counter() - t0
        committed = drafted = accepted = 0
        for b in blocks:
            bn = np.asarray(b)
            committed += int(bn[:K, :, 1].sum())
            accepted += int(bn[K, :, 0].sum())
            drafted += int(bn[K, :, 1].sum())
        mean_committed = committed / (iters * n)
        traffic = self.modeled_spec_decode_traffic(mean_committed)
        return {
            "tok_per_s": committed / dt,
            "rounds_per_s": iters / dt,
            "acceptance_rate": accepted / max(drafted, 1),
            "mean_committed_per_round": mean_committed,
            # 1 jitted call + 1 readback per round
            "host_dispatches_per_token": 2.0 * iters / max(committed, 1),
            "k_draft": K,
            "spec_bytes_per_token": traffic["bytes_per_token"],
            "baseline_bytes_per_token": traffic["baseline_bytes_per_token"],
            "modeled_speedup": traffic["modeled_speedup"],
        }

    # ------------------------------------------------------------ internals

    def _now(self) -> float:
        if self.ec.clock == "steps":
            return float(self._step_count)
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    def bucket_for(self, n: int) -> int:
        """Prefill pad length for an ``n``-token prompt (the jit
        specialization it will compile into): the smallest member of
        ``steps.admit_pad_shapes`` covering ``n``. Lengths beyond ``s_max``
        have no admissible shape and raise (``submit`` rejects them up front
        with the full context — this is the fail-closed backstop for callers
        probing bucket shapes directly). FAILS CLOSED on table
        non-membership too: returning any length outside the table would
        silently blow the trace budget the guard enforces, so drift between
        the two is an error here, never a retrace later."""
        if n > self.ec.s_max:
            raise ValueError(
                f"no prefill bucket fits {n} tokens (s_max={self.ec.s_max})")
        for b in self._pad_shapes:
            if n <= b:
                return b
        raise AssertionError(
            f"admission pad-shape table {self._pad_shapes} covers no "
            f"length <= s_max={self.ec.s_max}; steps.admit_pad_shapes "
            f"broke its own invariant")

    def _positions(self) -> np.ndarray:
        """Sequence position the NEXT sampled token will occupy, per slot —
        the host-side mirror of the device loops' post-step ``cache['pos']``
        (prompt length + tokens generated so far)."""
        q = np.zeros((self.ec.n_slots,), np.int32)
        for s in np.flatnonzero(self._active):
            req = self._slot_req[s]
            q[s] = req.n_prompt + len(req.out_tokens)
        return q

    def _sample(self, logits, greedy, keys, positions) -> np.ndarray:
        """Host-side sampling fallback for the step-at-a-time loop and
        (non-spec) admission. Runs the SAME ``steps.sample_tokens`` the
        fused device loops run, on the same (key, position) pairs, so
        host- and device-sampled streams agree bitwise at any
        temperature."""
        if self.ec.temperature <= 0.0:
            return np.asarray(greedy)
        toks = ST.sample_tokens(jnp.asarray(logits), self.ec.temperature,
                                jnp.asarray(keys), jnp.asarray(positions))
        return np.asarray(toks)

    def _is_done(self, req: Request, tok: int) -> bool:
        if req.eos_token is not None and tok == req.eos_token:
            req.finish_reason = "eos"
            return True
        if len(req.out_tokens) >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _sync_tab(self) -> None:
        """Ship the allocator's host-side block table to the device cache(s)
        when it changed. This is an EXPLICIT host->device transfer issued
        outside the guarded jitted calls — the table rides into them as an
        ordinary device argument, so the trace guard's implicit-transfer
        check stays clean. Both pools (full + draft) share the one table."""
        if self._alloc is None or not self._tab_dirty:
            return
        tab = jnp.asarray(self._alloc.tab)
        self.cache = dict(self.cache, tab=tab)
        if self.cache_draft is not None:
            self.cache_draft = dict(self.cache_draft, tab=tab)
        self._tab_dirty = False

    def _reserve_rows(self, req: Request) -> int:
        """KV rows a request must own for its whole lifetime: every written
        position (``prompt + max_new - 1``, see ``_validate_request``) plus
        ``spec_k`` verify-lookahead rows in speculative mode. Reserved in
        FULL at admission so decode/verify never allocate mid-flight and
        speculative rollback is a pure position rewind over owned blocks."""
        return (req.n_prompt + req.max_new_tokens - 1
                + (self.ec.spec_k if self.spec else 0))

    def _admit(self, now: float) -> List[Request]:
        """Fill free slots with due pending requests (prefill + insert +
        first token), batching same-bucket admissions. Returns requests that
        finish AT admission (e.g. max_new_tokens == 1).

        Paged layout: each claim first reserves its block budget with the
        allocator, adopting any registered prefix chain (the returned shared
        row count shrinks the prompt suffix that is actually forwarded). A
        failed reservation DEFERS the FIFO head — nothing behind it may jump
        the queue — until eviction returns blocks to the pool.

        Deadlines (§12): a due request whose effective deadline has passed
        is SHED here instead of admitted — with reason ``pool_pressure``
        when an earlier cycle deferred it (it waited on blocks, not on the
        clock), else ``deadline``. Shed requests ride the finished list so
        ``run()`` returns every terminal request."""
        finished: List[Request] = []
        if self._done_early:
            finished.extend(self._done_early)
            self._done_early.clear()
        free = [s for s in range(self.ec.n_slots) if not self._active[s]]
        claimed: List[Tuple[Request, int, int]] = []
        while self._pending and self._pending[0][0] <= now:
            req = self._pending[0][-1]
            dl = req.effective_deadline
            if dl is not None and now > dl:
                heapq.heappop(self._pending)
                self._shed(req, now,
                           "pool_pressure" if req.deferred else "deadline")
                finished.append(req)
                continue
            if not free:
                break
            shared = 0
            if self._faults is not None \
                    and self._faults.exhausted(self._step_count):
                # injected pool exhaustion: defer the head exactly like a
                # real failed reservation (works in dense layout too)
                req.deferred = True
                break
            if self._alloc is not None:
                shared = self._alloc.admit(free[0], req.prompt,
                                           self._reserve_rows(req))
                if shared is None:
                    req.deferred = True
                    break                       # pool exhausted: defer head
                self._tab_dirty = True
            heapq.heappop(self._pending)
            claimed.append((req, free.pop(0), shared))
        if not claimed:
            return finished
        if self.ec.batch_admission:
            # paged grouping buckets by the SUFFIX length (the tokens the
            # admission forward actually runs); dense shared is always 0,
            # so this is the full prompt length there
            groups: Dict[int, List[Tuple[Request, int, int]]] = {}
            for req, slot, shared in claimed:
                groups.setdefault(self.bucket_for(req.n_prompt - shared),
                                  []).append((req, slot, shared))
            for bucket in sorted(groups):
                self._admit_group(bucket, groups[bucket], now, finished)
        else:
            for req, slot, shared in claimed:
                self._admit_group(self.bucket_for(req.n_prompt - shared),
                                  [(req, slot, shared)], now, finished)
        return finished

    def _admit_group(self, bucket: int,
                     group: List[Tuple[Request, int, int]],
                     now: float, finished: List[Request]) -> None:
        """Prefill + insert + first token for one bucket's admissions as a
        single fused device call (``steps.make_slot_admit`` /
        ``make_slot_admit_paged``).

        The batch is padded to the next power of two so admission compiles
        at most ``len(pad_shapes) * (log2(n_slots)+1)`` specializations
        instead of one per (bucket, group-size) pair; pad rows carry an
        out-of-bounds slot index, which JAX scatter semantics drop (paged:
        the sentinel table row), so they never touch the cache. Paged rows
        forward only the prompt SUFFIX past their shared-prefix rows; new
        prefix chains are registered for sharing only AFTER the device call
        that wrote the rows (a same-cycle sharer must never adopt unwritten
        blocks)."""
        B = len(group)
        Bp = 1
        while Bp < B:
            Bp *= 2
        toks = np.zeros((Bp, bucket), np.int32)
        lengths = np.ones((Bp,), np.int32)
        slots = np.full((Bp,), self.ec.n_slots, np.int32)   # pads: OOB, dropped
        pos0 = np.zeros((Bp,), np.int32)
        keys = np.zeros((Bp, 2), np.uint32)
        for i, (req, slot, shared) in enumerate(group):
            suffix = req.prompt[shared:]
            toks[i, :suffix.size] = suffix
            lengths[i] = suffix.size
            slots[i] = slot
            pos0[i] = shared
            # the request's sampling key, derived from its uid so the
            # sampled stream is scheduling-independent (module docstring)
            self._slot_keys[slot] = np.asarray(
                jax.random.fold_in(self._key_base, req.uid), np.uint32)
            keys[i] = self._slot_keys[slot]
        self._sync_tab()
        paged_args = ((jnp.asarray(pos0),) if self._alloc is not None
                      else ())
        if self.spec:
            logits, first_dev, self.cache, self.cache_draft = \
                self._with_retries(
                    "admit", "slot_admit_spec",
                    lambda: self._admit_spec(
                        self.params, self.draft_params, self.cache,
                        self.cache_draft, jnp.asarray(toks),
                        jnp.asarray(lengths), jnp.asarray(slots),
                        *paged_args, jnp.asarray(keys)))
            self.counters["device_calls"] += 1
            first = np.asarray(first_dev[:B])
        else:
            logits, greedy, self.cache = self._with_retries(
                "admit", "slot_admit",
                lambda: self._admit_step(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(lengths), jnp.asarray(slots), *paged_args))
            self.counters["device_calls"] += 1
            # the first token occupies position ``n_prompt`` (= shared
            # prefix rows + suffix length) — same noise index the device
            # paths use for it
            first = self._sample(logits[:B], greedy[:B], keys[:B],
                                 pos0[:B] + lengths[:B])
        self.counters["host_syncs"] += 1
        if self._alloc is not None and self.ec.prefix_sharing:
            # AFTER the device call: the rows now exist. Sharing begins at
            # the NEXT admission cycle — every cycle's allocator
            # reservations (lookup_prefix) run in _admit before any group's
            # device call, so same-cycle duplicates never adopt each other
            for req, slot, shared in group:
                self._alloc.register_prefix(slot, req.prompt)
        for i, (req, slot, shared) in enumerate(group):
            tok = int(first[i])
            req.out_tokens.append(tok)
            self.counters["tokens_out"] += 1
            req.t_admitted = now
            req.t_first_token = now
            self._slot_req[slot] = req
            self._last_tok[slot] = tok
            self._active[slot] = True
            if self._is_done(req, tok):
                self._evict(slot, now)
                finished.append(req)

    def _evict(self, slot: int, now: float, status: str = "ok") -> None:
        req = self._slot_req[slot]
        if req is not None:
            req.t_finished = now
            req.status = status
            self._inflight.discard(req.uid)
        self._slot_req[slot] = None
        self._active[slot] = False
        if self._alloc is not None:
            # blocks return to the pool (registry pins keep shared prefix
            # chains alive); the slot's table row goes to the sentinel so
            # any write the frozen slot still issues on device is dropped
            self._alloc.release(slot)
            self._tab_dirty = True

    # ------------------------------------------------- resilience (§12)

    def _poison_mask(self, k: int) -> np.ndarray:
        """Fault-injection NaN mask for the decode block starting at the
        current step and spanning ``k`` steps; all-False without a plan
        (a bitwise no-op inside the jitted block)."""
        if self._faults is None:
            return self._zero_poison
        return self._faults.poison_mask(self._step_count, k,
                                        self.ec.n_slots)

    def _with_retries(self, site: str, name: str, call: Callable):
        """Run one device-step call through the fault plan's transient-
        failure site with the engine's bounded retry/backoff budget. Each
        injected failure consumes one retry; exceeding
        ``EngineConfig.device_retries`` raises DeviceStepError. Without a
        plan (or when nothing fires) this is a plain passthrough."""
        fails = (self._faults.transient_failures(site, self._step_count)
                 if self._faults is not None else 0)
        for attempt in range(fails):
            if attempt >= self.ec.device_retries:
                raise ERR.DeviceStepError(
                    f"{name} at site {site!r}, step {self._step_count}: "
                    f"still failing after {attempt} retries (budget "
                    f"device_retries={self.ec.device_retries})")
            self.counters["transient_retries"] += 1
            if self.ec.retry_backoff_s > 0:
                time.sleep(self.ec.retry_backoff_s * (2 ** attempt))
        return call()

    def _quarantine(self, slot: int, now: float) -> Request:
        """Evict a slot whose sentinel lane reported non-finite logits: its
        request terminates ``failed_numeric`` with its tokens truncated at
        the poisoned step (everything before it matches the fault-free
        stream bitwise), and its pages return to the pool. Healthy slots
        are untouched — their computation is batch-independent."""
        req = self._slot_req[slot]
        req.finish_reason = "numeric"
        self.counters["quarantined"] += 1
        self._evict(slot, now, status="failed_numeric")
        return req

    def _raise_if_strict(self, quarantined: List[Request]) -> None:
        """Strict sentinel mode: raise AFTER the replay loop finished, so
        the engine state (evictions, counters, pages) is consistent and the
        caller can snapshot or continue with the healthy slots."""
        if quarantined and self.ec.numeric_sentinel == "strict":
            raise ERR.NumericHealthError(
                f"non-finite logits quarantined uid(s) "
                f"{sorted(r.uid for r in quarantined)} at step "
                f"{self._step_count}; slots evicted failed_numeric")


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def poisson_trace(n_requests: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests per clock
    unit: decode steps or seconds, matching the engine clock)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n_requests)
    return np.cumsum(gaps)
