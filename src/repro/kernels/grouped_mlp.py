"""Grouped (per-expert) SwiGLU Pallas kernel — megablocks-style MoE compute.

Tokens arrive SORTED by expert (``x: [T, d]``, ``group_sizes: [E]``). The
wrapper pads each expert's segment to a multiple of the token block so every
grid block maps to exactly one expert; a scalar-prefetched ``block_expert``
table then indexes the expert weight tables in the BlockSpec index maps —
the dense one-hot dispatch einsum (GShard path) is replaced by pure gathers.

This is the TPU-native realization of the paper's deployment claim: after
MergeMoE halves the expert count, each merged expert's token group DOUBLES,
so blocks are fuller and fewer — better MXU utilization at identical
arithmetic (see EXPERIMENTS.md §Perf, MoE serving iteration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(be_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=F32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=F32)

    @pl.when(j == nf - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_t", "block_f",
                                             "interpret"))
def grouped_swiglu(x, wg, wu, wd, group_sizes, block_t: int = 128,
                   block_f: int = 512, interpret: bool = False):
    """x: [T, d] sorted by expert; wg/wu: [E, d, f]; wd: [E, f, d];
    group_sizes: [E] int32 summing to T. Returns [T, d]."""
    T, d = x.shape
    E, _, f = wg.shape
    bt = block_t
    bf = _block(f, block_f)
    nf = f // bf

    # ---- pad each expert segment to a multiple of bt (static worst case:
    # T + E*(bt-1) rows), build block -> expert map + row scatter indices.
    # Zero-sized groups (routine after aggressive merging: the remap empties
    # every absorbed expert's bucket) make `starts`/`padded_starts` contain
    # duplicate entries, which a searchsorted-based mapping must special-case;
    # instead both the row->expert and block->expert tables are built with
    # ``jnp.repeat(..., total_repeat_length=...)``, which emits each expert id
    # exactly size/blocks-per-expert times and is duplicate-proof by
    # construction (trailing padding repeats the last id onto all-zero rows,
    # whose output is discarded).
    starts = jnp.cumsum(group_sizes) - group_sizes            # [E]
    padded_sizes = ((group_sizes + bt - 1) // bt) * bt
    padded_starts = jnp.cumsum(padded_sizes) - padded_sizes
    Tp = T + E * (bt - 1)
    Tp = ((Tp + bt - 1) // bt) * bt
    nb = Tp // bt

    # destination row for each source row (stable within its expert segment)
    eid = jnp.repeat(jnp.arange(E, dtype=jnp.int32), group_sizes,
                     total_repeat_length=T)
    dest = padded_starts[eid] + (jnp.arange(T) - starts[eid])
    xp = jnp.zeros((Tp, d), x.dtype).at[dest].set(x)

    # block -> expert table (blocks beyond the last padded segment rerun the
    # last non-empty expert on zero rows — harmless, output discarded)
    block_expert = jnp.repeat(jnp.arange(E, dtype=jnp.int32),
                              padded_sizes // bt,
                              total_repeat_length=nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, bf, d), lambda i, j, be: (be[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bt, d), F32)],
    )
    yp = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        interpret=interpret,
    )(block_expert, xp, wg, wu, wd)
    return yp[dest]
