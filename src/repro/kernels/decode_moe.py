"""Decode-mode (gather-dispatch) MoE SwiGLU Pallas kernel.

The grouped kernel (``grouped_mlp.py``) is built for prefill-sized token
counts: it sorts tokens by expert, pads every expert segment to a token
block, and walks block-aligned groups. At decode the MoE layer sees only
``n_slots`` tokens (a handful), so that path is pure overhead — the argsort,
bincount, segment padding (``T + E·(bt-1)`` rows for T≈4!) and scatter cost
more than the math.

This kernel is the small-T specialization: the grid is ``(T, k)`` — one
token per row-block, one of its top-k experts per inner step — and a
scalar-prefetched ``idx`` table lets each step's BlockSpec index maps gather
the three weight tables of exactly the expert that token routed to. No
sorting, no padding, no scatter: the only HBM traffic is the k expert rows a
token actually needs, which after MergeMoE merging means fewer distinct rows
re-read across the batch. The per-token combine weight rides in SMEM and the
k contributions accumulate in an fp32 VMEM scratch, mirroring the ragged
path's fp32 scatter-add so the two dispatches agree (tests assert parity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(idx_ref, x_ref, w_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref,
            *, k: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                           # [1, d]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=F32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    # downcast to the model dtype before the fp32-weighted combine — the
    # exact arithmetic of the ragged path (grouped matmul emits x.dtype rows,
    # the combine scatter-adds them in fp32)
    y = jnp.dot(h, wd_ref[0], preferred_element_type=F32).astype(x.dtype)
    acc_ref[...] += w_ref[0] * y.astype(F32)

    @pl.when(j == k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_swiglu(x, wg, wu, wd, idx, w, interpret: bool = False):
    """x: [T, d]; wg/wu: [E, d, f]; wd: [E, f, d]; idx: [T, k] int32 in REAL
    expert space; w: [T, k] combine weights. Returns [T, d] where row t is
    ``Σ_j w[t, j] · SwiGLU_{idx[t, j]}(x[t])``.

    ``idx`` entries are clipped to [0, E): routing fails closed upstream
    (``moe.route`` masks remap targets >= live, DESIGN.md §5), so the clip is
    pure out-of-bounds defense for the weight-row gather, matching the
    oracle."""
    T, d = x.shape
    E, _, f = wg.shape
    k = idx.shape[-1]
    if T == 0:
        return jnp.zeros((0, d), x.dtype)
    idx = jnp.clip(idx.astype(jnp.int32), 0, E - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, k),
        in_specs=[
            pl.BlockSpec((1, d), lambda t, j, ix: (t, 0)),
            pl.BlockSpec((1, 1), lambda t, j, ix: (t, j),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, d, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, d, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, f, d), lambda t, j, ix: (ix[t, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda t, j, ix: (t, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), F32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(idx, x, w.astype(F32), wg, wu, wd)


def _kernel_q(idx_ref, x_ref, qg_ref, qu_ref, qd_ref,
              sg_ref, su_ref, sd_ref, o_ref):
    """Int8 variant of :func:`_kernel`: the three gathered weight blocks are
    int8 plus fp32 per-output-channel scale rows, dequantized in VMEM — one
    byte per weight over HBM instead of two. The dequantized weights stay
    fp32 through the whole SwiGLU and each (token, expert-slot) contribution
    is emitted to its own ``[T, k, d]`` output row at the model dtype; the
    wrapper applies the fp32 combine weights OUTSIDE the kernel with exactly
    the oracle's ops. Rationale: accumulating ``acc += w*y`` in-kernel is an
    FMA-contraction site (XLA:CPU fuses the multiply-add with one fewer
    rounding), which would put the interpret-mode result 1 ulp away from
    any jnp oracle — structurally unfixable, so the combine lives outside
    (DESIGN.md §8). The emitted rows are k·T·d·2 bytes — noise next to the
    k expert row-sets the kernel exists to stream."""
    x32 = x_ref[...].astype(F32)                             # [1, d]
    wg = qg_ref[0].astype(F32) * sg_ref[0]
    wu = qu_ref[0].astype(F32) * su_ref[0]
    wd = qd_ref[0].astype(F32) * sd_ref[0]
    g = jnp.dot(x32, wg)
    u = jnp.dot(x32, wu)
    h = jax.nn.silu(g) * u
    o_ref[...] = jnp.dot(h, wd)[None].astype(o_ref.dtype)


def gather_swiglu_q(x, qt, idx, w, interpret: bool = False):
    """Int8 decode-mode gather SwiGLU. Same contract as
    :func:`gather_swiglu` with the weight tables replaced by a
    :class:`repro.core.quant.QuantizedExpertTables` (int8 tables + keepdim
    fp32 scales); per token the kernel streams k int8 expert row-sets — the
    decode hot loop's dominant HBM term at half the bf16 width. Bitwise
    equal to ``ref.gather_swiglu_q`` in interpret mode. Deliberately
    UNJITTED, same reasoning as ``grouped_swiglu_q`` (production jits at
    the ``ops`` layer)."""
    T, d = x.shape
    E, _, f = qt.wg.shape
    k = idx.shape[-1]
    if T == 0:
        return jnp.zeros((0, d), x.dtype)
    idx = jnp.clip(idx.astype(jnp.int32), 0, E - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, k),
        in_specs=[
            pl.BlockSpec((1, d), lambda t, j, ix: (t, 0)),
            pl.BlockSpec((1, d, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, d, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, f, d), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, 1, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, 1, f), lambda t, j, ix: (ix[t, j], 0, 0)),
            pl.BlockSpec((1, 1, d), lambda t, j, ix: (ix[t, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda t, j, ix: (t, j, 0)),
        scratch_shapes=[],
    )
    y = pl.pallas_call(
        _kernel_q,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, k, d), x.dtype),
        interpret=interpret,
    )(idx, x, qt.wg, qt.wu, qt.wd,
      qt.wg_scale, qt.wu_scale, qt.wd_scale)
    # the oracle's combine, verbatim: fp32 weights over model-dtype rows
    out = jnp.sum(y.astype(F32) * w.reshape(T, k, 1).astype(F32), axis=1)
    return out.astype(x.dtype)
