"""Lint rule catalog (DESIGN.md §9).

Each rule is a small object with ``rule_id``, ``doc`` and
``check(module, analyzer) -> Iterable[Finding]``. Rules RA001–RA004 and
RA006 fire only inside jit-reachable functions (see ``lint.Analyzer``);
RA005/RA007/RA008 are whole-tree hygiene rules.

Taint model: within a reachable function, a value is "traced" when it is
produced by a ``jnp.``/``jax.``/``lax.`` call (or by a ``pl.load``/ref
subscript inside a kernel), or derived from such a value through
assignment, arithmetic, subscripting, or tuple unpacking. Function
parameters are NOT assumed traced: this tree's makers close over static
Python config (``moe._capacity`` computes ``int(...)`` on config floats
inside a jit-reachable helper, and that is fine). The cost is that a
host-sync on a *parameter* escapes RA002/RA003 — acceptable, because the
dynamic trace guard (leg 3) catches the resulting retrace/transfer at
test time, and the fixture tests pin the positives we do promise to catch.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.lint import Analyzer, Finding, ModuleInfo, _dotted

__all__ = ["RULES", "TaintTracker"]

_TRACED_PREFIXES = ("jnp.", "jax.", "lax.", "pl.", "pltpu.")
# np.* calls that are static/host-safe even in traced code
_NP_ALLOWED = {
    "np.iinfo", "np.finfo", "np.dtype", "np.float32", "np.float16",
    "np.int8", "np.int32", "np.int64", "np.bool_", "np.pi", "np.inf",
    "np.prod", "np.log2", "np.ceil", "np.sqrt",  # scalar math on config
}
_HOST_CASTS = {"int", "float", "bool"}
_SYNC_METHODS = {"item", "tolist", "to_py"}
# jnp/jax calls that return STATIC host values, not arrays
_NONARRAY_CALLS = {
    "jnp.dtype", "jnp.shape", "jnp.ndim", "jnp.issubdtype", "jnp.iinfo",
    "jnp.finfo", "jax.dtypes.canonicalize_dtype", "jax.eval_shape",
    "jax.tree_util.tree_structure", "jax.default_backend",
}
# attribute reads that are static under tracing even on a traced value
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}


class TaintTracker(ast.NodeVisitor):
    """Single-pass, order-sensitive taint over one function body.

    Visits statements in source order; names assigned from traced
    expressions become tainted for subsequent statements. One pass is
    enough in practice — hot-path functions here are straight-line or
    loop bodies whose carried values are assigned before use.
    """

    def __init__(self, mod: ModuleInfo, fn: ast.AST):
        self.mod = mod
        self.tainted: Set[str] = set()
        # ref-style params of pallas kernels (x_ref, o_ref, acc_ref) are
        # traced by construction
        args = getattr(fn, "args", None)
        if args is not None:
            for a in args.args + args.kwonlyargs:
                if a.arg.endswith("_ref") or a.arg.endswith("_refs"):
                    self.tainted.add(a.arg)
        for node in self._body_nodes(fn):
            if isinstance(node, ast.Assign):
                if self.is_traced(node.value):
                    for t in node.targets:
                        self._taint_target(t)
            elif isinstance(node, ast.AugAssign):
                if self.is_traced(node.value) or self.is_traced(node.target):
                    self._taint_target(node.target)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if self.is_traced(node.value):
                    self._taint_target(node.target)
            elif isinstance(node, ast.For):
                if self.is_traced(node.iter):
                    self._taint_target(node.target)

    @staticmethod
    def _body_nodes(fn: ast.AST) -> Iterable[ast.AST]:
        stack = list(ast.iter_child_nodes(fn))
        out = []
        while stack:
            node = stack.pop(0)
            out.append(node)
            for child in ast.iter_child_nodes(node):
                if not isinstance(child,
                                  (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                    stack.append(child)
        return out

    def _taint_target(self, t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            self.tainted.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                self._taint_target(e)
        elif isinstance(t, ast.Starred):
            self._taint_target(t.value)

    def is_traced(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted:
                full = self.mod.expand(dotted)
                if dotted in _NONARRAY_CALLS or full in _NONARRAY_CALLS:
                    return False
                if full.startswith(("jax.numpy.", "jax.lax.")) or any(
                        dotted.startswith(p) for p in _TRACED_PREFIXES) or \
                        full.startswith("jax."):
                    # jax.* producers yield arrays; a few (tree_util etc.)
                    # don't, but treating them as traced only adds caution
                    return True
            # method call on a traced object (x.astype(...), x.sum())
            if isinstance(node.func, ast.Attribute) and self.is_traced(
                    node.func.value):
                return True
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            # x.shape / x.dtype are trace-static even when x is traced
            if node.attr in _STATIC_ATTRS:
                return False
            return self.is_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_traced(node.left) or self.is_traced(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_traced(node.operand)
        if isinstance(node, ast.Compare):
            # identity tests (`x is None`) return a host bool, never a tracer
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return self.is_traced(node.left) or any(
                self.is_traced(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return any(self.is_traced(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.is_traced(node.body) or self.is_traced(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_traced(e) for e in node.elts)
        return False


def _reachable_funcs(mod: ModuleInfo, analyzer: Analyzer):
    for q, fn in mod.funcs.items():
        if (mod.name, q) in analyzer.reachable:
            yield q, fn


def _own_stmts(fn: ast.AST) -> Iterable[ast.AST]:
    """Nodes of fn excluding nested defs/lambdas (linted separately if
    reachable)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                stack.append(child)


class _Rule:
    rule_id = "RA000"
    doc = ""

    def check(self, mod: ModuleInfo,
              analyzer: Analyzer) -> Iterable[Finding]:
        raise NotImplementedError

    def _f(self, mod: ModuleInfo, node: ast.AST, msg: str) -> Finding:
        return Finding(self.rule_id, mod.path, node.lineno,
                       node.col_offset, msg)


class HostSyncMethod(_Rule):
    rule_id = "RA001"
    doc = (".item()/.tolist() in jit-reachable code forces a device→host "
           "sync and a trace-time concretization error")

    def check(self, mod, analyzer):
        for q, fn in _reachable_funcs(mod, analyzer):
            for node in _own_stmts(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SYNC_METHODS
                        and not node.args and not node.keywords):
                    yield self._f(
                        mod, node,
                        f"host-sync `.{node.func.attr}()` inside "
                        f"jit-reachable `{q}`")


class HostCastOnTraced(_Rule):
    rule_id = "RA002"
    doc = ("int()/float()/bool() on a traced value concretizes the tracer "
           "(ConcretizationTypeError under jit, silent sync outside)")

    def check(self, mod, analyzer):
        for q, fn in _reachable_funcs(mod, analyzer):
            taint = TaintTracker(mod, fn)
            for node in _own_stmts(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id in _HOST_CASTS
                        and node.args
                        and taint.is_traced(node.args[0])):
                    yield self._f(
                        mod, node,
                        f"`{node.func.id}()` on traced value inside "
                        f"jit-reachable `{q}`")


class TracerBranch(_Rule):
    rule_id = "RA003"
    doc = ("if/while/assert on a traced value calls __bool__ on a tracer; "
           "use lax.cond / lax.select / jnp.where")

    def check(self, mod, analyzer):
        for q, fn in _reachable_funcs(mod, analyzer):
            taint = TaintTracker(mod, fn)
            for node in _own_stmts(fn):
                test = None
                kind = None
                if isinstance(node, ast.If):
                    test, kind = node.test, "if"
                elif isinstance(node, ast.While):
                    test, kind = node.test, "while"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                elif isinstance(node, ast.IfExp):
                    test, kind = node.test, "conditional expression"
                if test is not None and taint.is_traced(test):
                    yield self._f(
                        mod, node,
                        f"Python `{kind}` on traced value inside "
                        f"jit-reachable `{q}`; use lax.cond/jnp.where")


class NumpyOnTraced(_Rule):
    rule_id = "RA004"
    doc = ("np.* on traced values inside jit-reachable code triggers "
           "device→host transfer at trace time; use jnp")

    def check(self, mod, analyzer):
        np_alias = {a for a, full in mod.import_alias.items()
                    if full == "numpy"}
        if not np_alias:
            return
        for q, fn in _reachable_funcs(mod, analyzer):
            taint = TaintTracker(mod, fn)
            for node in _own_stmts(fn):
                if not isinstance(node, ast.Call):
                    continue
                dotted = _dotted(node.func)
                if not dotted:
                    continue
                root = dotted.split(".", 1)[0]
                if root not in np_alias:
                    continue
                canon = "np." + dotted.split(".", 1)[1] if "." in dotted \
                    else "np"
                if canon in _NP_ALLOWED:
                    continue
                arg_traced = any(taint.is_traced(a) for a in node.args) or \
                    any(taint.is_traced(kw.value) for kw in node.keywords)
                if arg_traced:
                    yield self._f(
                        mod, node,
                        f"`{dotted}` on traced value inside jit-reachable "
                        f"`{q}`; use jnp")


class DebugLeftIn(_Rule):
    rule_id = "RA005"
    doc = ("jax.debug.print / pdb / breakpoint() left in library code "
           "(kernels and serving paths must stay clean)")

    def check(self, mod, analyzer):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted is None:
                    continue
                full = mod.expand(dotted)
                if full.startswith("jax.debug.") or \
                        dotted.startswith("jax.debug."):
                    yield self._f(mod, node,
                                  f"`{dotted}` left in library code")
                elif dotted in ("breakpoint", "pdb.set_trace",
                                "ipdb.set_trace"):
                    yield self._f(mod, node,
                                  f"debugger call `{dotted}` left in "
                                  f"library code")
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                names = [a.name for a in node.names]
                modname = getattr(node, "module", None)
                if "pdb" in names or "ipdb" in names or modname in (
                        "pdb", "ipdb"):
                    yield self._f(mod, node, "pdb import left in "
                                  "library code")


class ShapeBranchNotStatic(_Rule):
    rule_id = "RA006"
    doc = ("directly-jitted function branches on a parameter that is not "
           "in static_argnames — every distinct value retraces or fails")

    def check(self, mod, analyzer):
        for key, statics in analyzer.jit_statics.items():
            m, q = key
            if m != mod.name:
                continue
            fn = mod.funcs.get(q)
            if fn is None:
                continue
            params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
            dyn = params - statics - {"self"}
            for node in _own_stmts(fn):
                test = None
                if isinstance(node, (ast.If, ast.While)):
                    test = node.test
                if test is None:
                    continue
                if isinstance(test, ast.Compare) and all(
                        isinstance(op, (ast.Is, ast.IsNot))
                        for op in test.ops):
                    continue  # identity checks resolve at trace time
                for sub in ast.walk(test):
                    if isinstance(sub, ast.Name) and sub.id in dyn:
                        # only flag scalar-looking branch params; x.shape /
                        # x.ndim are trace-static and fine
                        if self._shape_derived(test, sub.id):
                            continue
                        yield self._f(
                            mod, node,
                            f"jitted `{q}` branches on parameter "
                            f"`{sub.id}` not listed in static_argnames")
                        break

    @staticmethod
    def _shape_derived(test: ast.AST, name: str) -> bool:
        """True when every use of ``name`` in the test goes through
        .shape/.ndim/.dtype/len() — those are static under tracing."""
        for sub in ast.walk(test):
            if isinstance(sub, ast.Name) and sub.id == name:
                return False
            if isinstance(sub, ast.Attribute) and sub.attr in (
                    "shape", "ndim", "dtype", "size") and isinstance(
                        sub.value, ast.Name) and sub.value.id == name:
                # strip this branch by not descending: crude — accept
                return True
            if isinstance(sub, ast.Call) and isinstance(
                    sub.func, ast.Name) and sub.func.id == "len":
                if any(isinstance(a, ast.Name) and a.id == name
                       for a in sub.args):
                    return True
        return False


class RawPallasCall(_Rule):
    rule_id = "RA007"
    doc = ("pl.pallas_call outside repro/kernels bypasses the "
           "pallas_dispatch policy (oracle fallback, interpret flag, "
           "contract registration)")

    def check(self, mod, analyzer):
        if mod.name.startswith("repro.kernels"):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dotted = _dotted(node.func)
                if dotted and dotted.rsplit(".", 1)[-1] == "pallas_call":
                    yield self._f(
                        mod, node,
                        "direct pallas_call outside repro/kernels; route "
                        "through pallas_dispatch in kernels/ops.py")


class KernelImplImport(_Rule):
    rule_id = "RA008"
    doc = ("importing kernel impl modules (repro.kernels.* other than ops) "
           "outside the kernels package bypasses dispatch policy")

    def check(self, mod, analyzer):
        if mod.name.startswith(("repro.kernels", "repro.analysis")):
            return
        for node in ast.walk(mod.tree):
            targets: List[str] = []
            if isinstance(node, ast.Import):
                targets = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module == "repro.kernels":
                    targets = [f"repro.kernels.{a.name}"
                               for a in node.names]
                else:
                    targets = [node.module]
            for t in targets:
                if t.startswith("repro.kernels") and t not in (
                        "repro.kernels", "repro.kernels.ops"):
                    yield self._f(
                        mod, node,
                        f"import of kernel impl `{t}` outside the kernels "
                        f"package; use repro.kernels.ops")


RULES = [
    HostSyncMethod(),
    HostCastOnTraced(),
    TracerBranch(),
    NumpyOnTraced(),
    DebugLeftIn(),
    ShapeBranchNotStatic(),
    RawPallasCall(),
    KernelImplImport(),
]
