"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
while smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(axis_sizes, axis_names):
    """Version-compat AbstractMesh constructor.

    JAX <= 0.4.x takes ``AbstractMesh(shape_tuple=(("data", 16), ...))``;
    newer releases take ``AbstractMesh(axis_sizes, axis_names)``. Spec
    derivation (sharding rules, dry-run lowering) only needs shape + names,
    so either form is equivalent.
    """
    import inspect
    from jax.sharding import AbstractMesh

    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    if len(axis_sizes) != len(axis_names):
        raise ValueError(f"{len(axis_sizes)} sizes vs {len(axis_names)} names")
    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(axis_sizes, axis_names)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes carrying batch data-parallelism (pod included when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


# ---------------------------------------------------------------------------
# compression meshes (DESIGN.md §6)
# ---------------------------------------------------------------------------

def parse_mesh_spec(spec: str):
    """Parse a ``--mesh`` CLI spec into (shape, axes).

    Accepted forms: ``"data=4"``, ``"data=2,model=2"``, ``"4"`` (all-data),
    ``"4x2"`` (data x model). Axis names must come from
    {pod, data, model} so the existing sharding rules apply unchanged."""
    spec = spec.strip()
    known = ("pod", "data", "model")
    if "=" in spec:
        shape, axes = [], []
        for part in spec.split(","):
            name, _, size = part.partition("=")
            name = name.strip()
            if name not in known:
                raise ValueError(f"unknown mesh axis {name!r}; one of {known}")
            axes.append(name)
            shape.append(int(size))
        return tuple(shape), tuple(axes)
    sizes = tuple(int(s) for s in spec.replace("x", " ").split())
    if len(sizes) == 1:
        return sizes, ("data",)
    if len(sizes) == 2:
        return sizes, ("data", "model")
    raise ValueError(f"cannot parse mesh spec {spec!r}")


def make_compression_mesh(spec: str | None = None):
    """Mesh for the compression pipeline over the host's devices.

    Default: every device on the "data" axis (calibration capture is pure
    data-parallelism; the "model" axis only shards the solve stage)."""
    if spec is None:
        return jax.make_mesh((jax.device_count(),), ("data",))
    return jax.make_mesh(*parse_mesh_spec(spec))


def mesh_shape_dict(mesh) -> dict:
    """{axis: size} — the JSON-able mesh record plans/artifacts carry."""
    return {str(k): int(v) for k, v in mesh.shape.items()}


def expert_axis_size(mesh) -> int:
    """Size of the expert-parallel ("model") axis — the number of shards the
    per-expert compression solves split across (DESIGN.md §6)."""
    return int(mesh.shape.get("model", 1))
