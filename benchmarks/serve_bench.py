"""Continuous-batching serving benchmark -> benchmarks/BENCH_serve.json.

Serves an identical Poisson request trace through the engine in two modes —

* **before**: the pre-PR hot loop (``decode_block=1`` step-at-a-time decode,
  ragged dispatch, batch-of-1 admission): one jitted call + one host sync per
  decode STEP;
* **after**: the fused loop (``decode_block=K`` device-resident scan with
  on-device sampling/stop flags, gather-dispatch decode MoE, batched
  same-bucket admission): one call + one sync per K steps —

for both the uncompressed checkpoint and the same weights MergeMoE-compressed
to half the experts, and records tokens/sec, p50/p95 request latency, and
host dispatches per generated token. Every mode pair is asserted
token-for-token identical (greedy), and the JSON carries the parity bits the
CI smoke gate checks. On TPU the compressed rows route fewer, fuller expert
groups through the grouped/gather kernels; on CPU (this container) the jnp
oracles stand in at identical shapes, so the trustworthy CPU signals are the
host-dispatch counts and the fused-loop overhead reduction.

**Int8 rows (DESIGN.md §8).** The same trace additionally runs with int8
expert tables — the uncompressed model quantized in place ("full-int8") and
the M = N/2 merge executed with ``weight_dtype='int8'`` — and every row
records the MODELED decode HBM traffic
(``launch.hlo_analysis.decode_traffic_model``) at both the served smoke
config and the full-scale architecture. Quality rides in
``int8.top1_match_*``: per-position greedy top-1 agreement with the bf16
weights on the bf16 trace's contexts, gated against ``--int8-tolerance``.

The GATED traffic metric is the modeled **expert stream** per token — the
"k full expert SwiGLU tables streamed from HBM per token" term that is
this change's target and decode's dominant cost at scale: both int8 rows
must sit >= ``EXPERT_STREAM_GATE`` (1.7x) below the bf16 M = N/2 row at
the full-scale arch. TOTAL modeled HBM/token is recorded alongside
(``hbm_reduction_vs_bf16_half``): int8 cannot move the bf16 attention/KV/
head floors, so totals drop ~1.55x (full) / ~1.68x (M = N/2) — quote the
expert-stream ratio only for the expert stream.

**Spec rows (DESIGN.md §10).** A dedicated trace additionally runs through
the SPECULATIVE engine — MergeMoE-compressed draft proposes K tokens/slot,
full model verifies all K in one multi-position forward, accept/rollback on
device — for a greedy K-sweep on the M = N/2 merge, the int8 headline
deployment shape, the same-weights int8 draft (the coupled sampler's
regression detector), and a temperature-0.7 row exercising the
Gumbel-coupled exact-match path. Gated: every spec row is token-for-token
identical to the fused full-model reference on the same trace (greedy and
sampled), acceptance clears the per-draft floors, and the MODELED
deployment speedup (``hlo_analysis.spec_decode_traffic_model`` at the
recorded reference acceptance and ``SPEC_GATE_SLOTS``) is >= 1. Measured
CPU tok/s is recorded ungated, same stance as the int8 rows: the smoke
container is FLOPs-bound while the deployment claim is HBM-bound.

**Paged KV rows (DESIGN.md §11).** The fused engine additionally serves the
identical trace with the KV cache held in a paged block pool (bf16 and int8
storage). Gated: the bf16 pool is token-for-token identical to the dense
engine on the trace AND on a duplicate-prompt prefix-sharing trace; the
int8 pool clears a teacher-forced per-position top-1 floor
(``KV_INT8_TOLERANCE``) against the bf16 trace; and the full-scale modeled
decode KV stream of the int8 pool sits >= ``KV_STREAM_GATE`` below dense
bf16. Prefix-share hit rates and ``kv_bytes_per_token`` ride in every row.

    PYTHONPATH=src python benchmarks/serve_bench.py --requests 16
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.core import quant as Q
from repro.launch.hlo_analysis import decode_traffic_model
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace

OUT_PATH = Path(__file__).resolve().parent / "BENCH_serve.json"

# cache depth for the full-scale modeled-traffic rows (mid-stream decode)
FULL_SCALE_POS = 512

# both int8 rows must cut the full-scale modeled EXPERT STREAM at least
# this far below the bf16 M=N/2 row (see module docstring for why the
# expert stream, not the total, is the gated term)
EXPERT_STREAM_GATE = 1.7

# --- paged + int8 KV cache (DESIGN.md §11) ---------------------------------
# the int8 KV pool must cut the full-scale modeled decode KV STREAM at
# least this far below dense bf16 (per-row: 2·hd·2 bytes -> 2·(hd+4); at
# hd=128 that is 512/264 ≈ 1.94x, so 1.7 leaves honest slack)
KV_STREAM_GATE = 1.7
# teacher-forced per-position top-1 floor for the int8-KV engine vs the
# bf16 trace (the bf16 paged engine is gated BITWISE instead)
KV_INT8_TOLERANCE = 0.95
PAGED_KV_BLOCK = 16

# --- speculative decoding (DESIGN.md §10) ----------------------------------
# deployment batch for the gated modeled spec speedup: the verify pass adds
# k·top_k routing draws per slot, so on a many-expert MoE the speedup only
# materializes once the expert stream is near saturation — model it at a
# deployment batch, not the 4-slot smoke batch (the n_slots sweep is
# recorded so the crossover is explicit)
SPEC_MODELED_SLOTS = (4, 16, 64)
SPEC_GATE_SLOTS = 64
# reference per-token acceptance for the gated modeled speedup: MergeMoE
# solves its merge matrices to track the full model's outputs, which on
# TRAINED weights puts the draft in the high-agreement regime typical of
# strong spec-decode drafts. The smoke models are random-init — their
# experts are not redundant, so merged-draft acceptance sits just above
# chance (measured + recorded per row, floor-gated below); the speedup
# GATE therefore evaluates the traffic arithmetic at this recorded
# reference point rather than at a random-init artifact.
SPEC_REFERENCE_ACCEPTANCE = 0.85
SPEC_SPEEDUP_GATE = 1.0
# measured-acceptance floors on the smoke trace: the int8-full draft is the
# SAME weights quantized, so a healthy coupled sampler accepts most of its
# proposals — if the Gumbel key schedule, the verify forward, or the
# acceptance rule breaks, this collapses to ~1/vocab and the floor trips.
# Merged drafts on random-init weights only clear an above-chance margin.
SPEC_ACCEPT_FLOOR_SELF = 0.5
SPEC_ACCEPT_FLOOR_MERGED_CHANCE_MULT = 2.0   # floor = mult / vocab_size


def spec_mean_committed(acceptance: float, k: int) -> float:
    """Expected tokens committed per slot per round at per-token acceptance
    ``acceptance``: commits are capped at k (repro.serving.spec), so
    E[min(a+1, k)] = sum_{i<k} acceptance^i under i.i.d. acceptance."""
    return float(sum(acceptance ** i for i in range(k)))


def run_trace(cfg, params, *, label, decode_block, dispatch, batch_admission,
              requests, prompt_lens, arrivals, max_new_tokens, n_slots, s_max,
              buckets, repeats=3, bench_iters=50, run_bench=True,
              temperature=0.0, engine_kw=None):
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets,
                              decode_block=decode_block, dispatch=dispatch,
                              batch_admission=batch_admission,
                              temperature=temperature,
                              **(engine_kw or {})),
                 cfg=cfg, params=params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(l), dtype=np.int32)
               for l in prompt_lens]

    # warmup: compile the decode block and every prefill specialization —
    # each bucket at each power-of-two admission-group size the trace can
    # produce — on throwaway requests before the timed trace
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run()
    for l in sorted(set(eng.bucket_for(len(p)) for p in prompts)):
        for burst in (n_slots, 2, 1):
            for _ in range(burst):
                eng.submit(np.zeros(min(l, s_max - 4), np.int32),
                           max_new_tokens=1)
            eng.run()
    for c in eng.counters:
        eng.counters[c] = 0

    # trace tok/s is host-loop noisy at smoke scale -> best of ``repeats``
    best_dt, done = None, None
    for _ in range(repeats):
        # shift arrivals past the current step clock so the trace stays
        # staggered and latency = finish - arrival holds without an offset
        base = float(eng.steps)
        for i in range(requests):
            eng.submit(prompts[i], max_new_tokens=max_new_tokens,
                       arrival_time=base + float(arrivals[i]))
        t0 = time.perf_counter()
        d = eng.run()
        dt = time.perf_counter() - t0
        if best_dt is None or dt < best_dt:
            best_dt, done = dt, d

    toks = sum(len(r.out_tokens) for r in done)
    lat = [r.t_finished - r.arrival_time for r in done]
    # parity-isolation runs only need tokens, not a steady-state timing pass
    steady = (eng.bench_decode(iters=bench_iters) if run_bench
              else {"tok_per_s": 0.0, "dispatches_per_s": 0.0,
                    "host_dispatches_per_token": 0.0,
                    "hbm_bytes_per_token": 0.0,
                    "moe_expert_bytes_per_token": 0.0,
                    "roofline_tok_per_s": 0.0, "roofline_fraction": 0.0})
    rec = {
        "label": label,
        "experts": (cfg.moe_merged or cfg.moe.n_experts) if cfg.moe else 0,
        "weight_dtype": eng.expert_weight_dtypes()[1],
        "dispatch": dispatch,
        "decode_block": decode_block,
        "batch_admission": batch_admission,
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(best_dt, 3),
        "tok_per_s": round(toks / best_dt, 1),
        # trace-loop counters cover all repeats (the ratio is what matters)
        "host_dispatches_per_token": round(eng.host_dispatches_per_token, 4),
        "steady_decode_tok_per_s": round(steady["tok_per_s"], 1),
        "steady_dispatches_per_s": round(steady["dispatches_per_s"], 1),
        "steady_host_dispatches_per_token": round(
            steady["host_dispatches_per_token"], 4),
        # modeled decode HBM traffic of the SERVED (smoke) config
        "hbm_bytes_per_token": round(steady["hbm_bytes_per_token"], 1),
        "moe_expert_bytes_per_token": round(
            steady["moe_expert_bytes_per_token"], 1),
        "roofline_tok_per_s": round(steady["roofline_tok_per_s"], 1),
        "roofline_fraction": steady["roofline_fraction"],
        "mean_latency_steps": round(float(np.mean(lat)), 2),
        "p50_latency_steps": round(float(np.percentile(lat, 50)), 2),
        "p95_latency_steps": round(float(np.percentile(lat, 95)), 2),
        # trace-guard counters over the post-warmup timed trace: any
        # nonzero value means a decode retrace or an implicit host
        # transfer crept into the steady state (DESIGN.md §9)
        "retraces": int(eng.counters["retraces"]),
        "implicit_transfers": int(eng.counters["implicit_transfers"]),
        # resilience counters (DESIGN.md §12): a HAPPY-PATH row must show
        # zero sheds, zero quarantines, zero transient retries — nonzero
        # here means the scheduler shed live work or the sentinel fired
        # without an injected fault
        "shed": int(eng.counters["shed"]),
        "quarantined": int(eng.counters["quarantined"]),
        "transient_retries": int(eng.counters["transient_retries"]),
        # KV layout + modeled KV stream of the served config (DESIGN.md §11)
        "kv_layout": eng.ec.kv_layout,
        "kv_dtype": eng.kv_dtype_served,
        "kv_bytes_per_token": round(
            eng.modeled_decode_traffic()["kv_bytes_per_token"], 1),
    }
    if eng.paging_stats:
        rec["paging"] = eng.paging_stats
    print(f"[{label:>22}] {rec['tok_per_s']:8.1f} tok/s trace  "
          f"{rec['steady_decode_tok_per_s']:8.1f} tok/s steady  "
          f"{rec['host_dispatches_per_token']:.3f} disp/tok  "
          f"(p95 latency {rec['p95_latency_steps']} steps)")
    # tokens in submission order (uids are per-engine; position is the
    # cross-engine-stable key, and repeats are deterministic replicas).
    # prompts ride along so quality metrics replay the EXACT contexts this
    # trace served, with no parallel regeneration to drift out of sync.
    tokens = [list(r.out_tokens) for r in sorted(done, key=lambda r: r.uid)]
    return rec, tokens, prompts


def top1_match(cfg_a, params_a, cfg_b, params_b, prompts, token_lists) -> float:
    """Per-position greedy top-1 agreement between two parameterizations on
    IDENTICAL contexts: the reference trace's sequences are teacher-forced
    through both models and the argmax compared position by position.

    Teacher forcing is the right quality metric here: free-running decode
    compounds — one near-tie flip early in a request makes every later
    token diverge — so a trace-vs-trace comparison measures divergence
    POSITION, not per-token quality. The engines' bitwise contracts stay
    free-running (the ``parity`` section); quality across the quantization
    boundary is this per-position tolerance (DESIGN.md §8)."""
    import dataclasses
    import jax.numpy as jnp

    def pin_ragged(c):
        return c.replace(moe=dataclasses.replace(c.moe, dispatch="ragged")) \
            if c.moe is not None else c

    ca, cb = pin_ragged(cfg_a), pin_ragged(cfg_b)
    agree = total = 0
    for p, t in zip(prompts, token_lists):
        if not t:
            continue
        seq = jnp.asarray(np.concatenate(
            [np.asarray(p, np.int32), np.asarray(t[:-1], np.int32)])[None])
        pred = []
        for c, prm in ((ca, params_a), (cb, params_b)):
            logits, _, _ = MD.forward(c, prm, {"tokens": seq})
            pred.append(np.argmax(np.asarray(logits[0], np.float32), -1))
        start = len(p) - 1
        agree += int((pred[0][start:start + len(t)]
                      == pred[1][start:start + len(t)]).sum())
        total += len(t)
    return agree / max(total, 1)


def paged_top1_match(cfg, params, prompts, token_lists, *, s_max,
                     kv_block=PAGED_KV_BLOCK) -> float:
    """Teacher-forced per-position greedy top-1 agreement of the INT8 paged
    KV cache against the bf16 trace, on the trace's exact contexts.

    The bf16 trace's tokens are greedy, so they ARE the dense model's
    per-position argmax under teacher forcing; feeding that same stream
    through an int8-pool paged decode and comparing argmax position by
    position isolates the KV-quantization error from free-running
    divergence (same stance as :func:`top1_match` for int8 weights)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.serving.paging import PagedAllocator

    c = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged")) \
        if cfg.moe is not None else cfg
    B = len(prompts)
    P = max(len(p) for p in prompts)
    NEW = max(len(t) for t in token_lists)
    toks = np.zeros((B, P), np.int32)
    lens = np.zeros((B,), np.int32)
    forced = np.zeros((B, NEW), np.int32)
    for i, (p, t) in enumerate(zip(prompts, token_lists)):
        toks[i, :len(p)] = p
        lens[i] = len(p)
        forced[i, :len(t)] = t
    alloc = PagedAllocator(n_slots=B, n_blocks=B * s_max // kv_block,
                           block_size=kv_block, s_max=s_max)
    cache = MD.init_paged_cache(c, B, s_max, n_blocks=alloc.nb,
                                block_size=kv_block, kv_dtype="int8")
    for i, (p, t) in enumerate(zip(prompts, token_lists)):
        alloc.admit(i, np.asarray(p, np.int32), len(p) + max(len(t) - 1, 0))
    cache["tab"] = jnp.asarray(alloc.tab)
    logits, cache = MD.admit_slots_paged(
        c, params, cache, jnp.asarray(toks), jnp.asarray(lens),
        jnp.arange(B), jnp.zeros((B,), jnp.int32))
    pred = [np.argmax(np.asarray(logits, np.float32), -1)]
    act = jnp.ones((B,), bool)
    for j in range(NEW - 1):
        lg, cache = MD.decode_step_slots(c, params, cache,
                                         jnp.asarray(forced[:, j]), act)
        pred.append(np.argmax(np.asarray(lg, np.float32), -1))
    pred = np.stack(pred, 1)                               # [B, NEW]
    agree = total = 0
    for i, t in enumerate(token_lists):
        agree += int((pred[i, :len(t)] == np.asarray(t, np.int32)).sum())
        total += len(t)
    return agree / max(total, 1)


def prefix_share_trace(cfg, params, *, n_slots, s_max, decode_block,
                       max_new_tokens) -> dict:
    """Duplicate-prompt trace through the PAGED engine: each distinct prompt
    is submitted twice (second arrival after the first admitted), so every
    second copy should adopt the first's registered full prompt blocks.
    Returns hit-rate telemetry plus a bitwise check that sharers decode the
    same tokens as their originals (shared rows are READ-identical)."""
    rng = np.random.default_rng(11)
    n_distinct = 6
    plen = min(2 * PAGED_KV_BLOCK, s_max - max_new_tokens - 1)
    base_prompts = [rng.integers(0, cfg.vocab_size, size=plen, dtype=np.int32)
                    for _ in range(n_distinct)]
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=(plen,),
                              decode_block=decode_block,
                              kv_layout="paged", kv_block=PAGED_KV_BLOCK),
                 cfg=cfg, params=params)
    for i, p in enumerate(base_prompts):
        eng.submit(p, max_new_tokens=max_new_tokens,
                   arrival_time=float(2 * i))
        eng.submit(p, max_new_tokens=max_new_tokens,
                   arrival_time=float(2 * i) + 40.0)      # after the first
    done = eng.run()
    outs = {}
    for r in done:
        outs.setdefault(r.prompt.tobytes(), []).append(r.out_tokens)
    stats = eng.paging_stats
    sharers = n_distinct                                   # one per repeat
    return {
        "requests": 2 * n_distinct,
        "prompt_len": plen,
        "prefix_hits": stats["prefix_hits"],
        "prefix_rows_shared": stats["prefix_rows_shared"],
        "hit_rate": round(stats["prefix_hits"] / sharers, 3),
        "deferrals": stats["deferrals"],
        "parity_duplicates_bitwise": bool(all(
            len(v) == 2 and v[0] == v[1] for v in outs.values())),
    }


def full_scale_traffic(arch: str, n_slots: int) -> dict:
    """Modeled decode HBM bytes/token of the four serving variants at the
    FULL-SCALE architecture (the smoke engine serves the reduced config; the
    bandwidth claim is about the real one). Same model for every row:
    ``hlo_analysis.decode_traffic_model`` at ``FULL_SCALE_POS``."""
    cfg = configs.get(arch)
    N = cfg.moe.n_experts
    half = cfg.compressed_per_layer((N // 2,) * cfg.n_layers, 0)
    rows = {
        "bf16_full": decode_traffic_model(cfg, n_slots=n_slots,
                                          pos=FULL_SCALE_POS),
        "bf16_half": decode_traffic_model(half, n_slots=n_slots,
                                          pos=FULL_SCALE_POS),
        "int8_full": decode_traffic_model(cfg, n_slots=n_slots,
                                          pos=FULL_SCALE_POS,
                                          weight_dtype="int8"),
        "int8_half": decode_traffic_model(half, n_slots=n_slots,
                                          pos=FULL_SCALE_POS,
                                          weight_dtype="int8"),
    }
    out = {k: {"hbm_bytes_per_token": round(v["bytes_per_token"]),
               "moe_expert_bytes_per_token":
                   round(v["moe_expert_bytes_per_token"])}
           for k, v in rows.items()}
    base = rows["bf16_half"]
    for k in ("int8_full", "int8_half"):
        out[k]["expert_stream_reduction_vs_bf16_half"] = round(
            base["moe_expert_bytes_per_token"]
            / rows[k]["moe_expert_bytes_per_token"], 3)
        out[k]["hbm_reduction_vs_bf16_half"] = round(
            base["bytes_per_token"] / rows[k]["bytes_per_token"], 3)
    return out


def full_scale_spec_traffic(arch: str, *, k: int, mean_committed: float,
                            draft: str) -> dict:
    """Modeled spec-decode traffic at the FULL-SCALE architecture across
    the deployment-batch sweep (``hlo_analysis.spec_decode_traffic_model``).
    ``draft`` picks the draft artifact: 'bf16_half' / 'int8_half' (the
    M=N/2 merge) or 'int8_full' (the same weights quantized)."""
    from repro.launch.hlo_analysis import spec_decode_traffic_model
    cfg = configs.get(arch)
    half = cfg.compressed_per_layer(
        (cfg.moe.n_experts // 2,) * cfg.n_layers, 0)
    draft_cfg, ddt = {"bf16_half": (half, "bf16"),
                      "int8_half": (half, "int8"),
                      "int8_full": (cfg, "int8")}[draft]
    out = {}
    for n in SPEC_MODELED_SLOTS:
        m = spec_decode_traffic_model(
            cfg, draft_cfg, k_draft=k, n_slots=n, pos=FULL_SCALE_POS,
            mean_committed=mean_committed, draft_weight_dtype=ddt)
        out[str(n)] = {
            "spec_bytes_per_token": round(m["bytes_per_token"]),
            "baseline_bytes_per_token": round(m["baseline_bytes_per_token"]),
            "modeled_speedup": round(m["modeled_speedup"], 3),
        }
    return out


def run_spec_trace(cfg, params, draft_cfg, draft_params, *, arch, label, k,
                   temperature, requests, prompt_lens, arrivals,
                   max_new_tokens, n_slots, s_max, buckets, draft_tag,
                   bench_iters=0):
    """Serve the trace through a SPECULATIVE engine (draft proposes ``k``
    tokens per round, full model verifies; DESIGN.md §10) and record
    acceptance telemetry next to the usual trace metrics. The modeled
    full-scale speedup is evaluated at BOTH the measured acceptance (what
    these random-init smoke artifacts actually deliver) and the recorded
    reference acceptance (the trained-model regime the gate checks)."""
    eng = Engine(EngineConfig(arch=arch, n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets, temperature=temperature,
                              spec_k=k),
                 cfg=cfg, params=params, draft_cfg=draft_cfg,
                 draft_params=draft_params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(l), dtype=np.int32)
               for l in prompt_lens]
    # warmup mirrors run_trace: compile the spec round and every dual-model
    # admission specialization before the timed trace
    eng.submit(prompts[0], max_new_tokens=2)
    eng.run()
    for l in sorted(set(eng.bucket_for(len(p)) for p in prompts)):
        for burst in (n_slots, 2, 1):
            for _ in range(burst):
                eng.submit(np.zeros(min(l, s_max - 4), np.int32),
                           max_new_tokens=1)
            eng.run()
    for c in eng.counters:
        eng.counters[c] = 0

    base = float(eng.steps)
    for i in range(requests):
        eng.submit(prompts[i], max_new_tokens=max_new_tokens,
                   arrival_time=base + float(arrivals[i]))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0

    toks = sum(len(r.out_tokens) for r in done)
    acc = eng.acceptance_rate
    steady = (eng.bench_spec_decode(iters=bench_iters) if bench_iters
              else None)
    rec = {
        "label": label,
        "k_draft": k,
        "temperature": temperature,
        "draft": draft_tag,
        "requests": len(done),
        "tokens": toks,
        "tok_per_s": round(toks / dt, 1),
        "host_dispatches_per_token": round(eng.host_dispatches_per_token, 4),
        "tokens_drafted": int(eng.counters["tokens_drafted"]),
        "tokens_accepted": int(eng.counters["tokens_accepted"]),
        "tokens_rolled_back": int(eng.counters["tokens_rolled_back"]),
        "acceptance_rate": round(acc, 4),
        "modeled_full_scale_at_measured": full_scale_spec_traffic(
            arch, k=k, mean_committed=spec_mean_committed(acc, k),
            draft=draft_tag),
        "retraces": int(eng.counters["retraces"]),
        "implicit_transfers": int(eng.counters["implicit_transfers"]),
        "shed": int(eng.counters["shed"]),
        "quarantined": int(eng.counters["quarantined"]),
        "transient_retries": int(eng.counters["transient_retries"]),
    }
    if steady is not None:
        rec["steady_spec_tok_per_s"] = round(steady["tok_per_s"], 1)
        rec["steady_acceptance_rate"] = round(steady["acceptance_rate"], 4)
        rec["steady_host_dispatches_per_token"] = round(
            steady["host_dispatches_per_token"], 4)
    print(f"[{label:>22}] {rec['tok_per_s']:8.1f} tok/s trace  "
          f"acceptance {rec['acceptance_rate']:.3f}  "
          f"({rec['tokens_accepted']}/{rec['tokens_drafted']} drafts, "
          f"{rec['host_dispatches_per_token']:.3f} disp/tok)")
    tokens = [list(r.out_tokens) for r in sorted(done, key=lambda r: r.uid)]
    return rec, tokens


# --- expert-parallel sharded decode (DESIGN.md §13) ------------------------
# forced-multi-device CPU mesh for the differential rows: 4 host devices as
# (data=2, model=2) — expert tables split 2-ways, slots/KV split 2-ways
EP_MESH = "data=2,model=2"
EP_DEVICES = 4
EP_MODES = ("dense_block", "paged_block")
# full-scale modeled-traffic point for the gated EP claim: kimi-k2 1T at a
# deployment EP degree (E=384 experts split 16 ways, 24 tables/device)
EP_FULL_SCALE_ARCH = "kimi-k2-1t-a32b"
EP_FULL_SCALE_EP = 16
EP_FULL_SCALE_DP = 4
EP_FULL_SCALE_SLOTS = 64
# per-device modeled expert stream must drop at least this fraction of the
# EP degree below the single-device stream (uniform routing gives >= ep
# exactly — the shard split plus fewer draws per data shard; the 0.8 slack
# absorbs future non-uniform routing models)
EP_STREAM_GATE_FRACTION = 0.8


def ep_section() -> dict:
    """The BENCH_serve.json ``ep`` section: the tests/_ep_child.py trace
    served single-device and on the forced (data=2, model=2) CPU mesh
    (separate subprocesses — device count is locked at JAX init), parity
    bits per mode, and the modeled per-device expert-stream + interconnect
    bytes at full kimi-k2 scale that carry the deployment claim."""
    repo = Path(__file__).resolve().parents[1]

    def child(mesh=None, devices=None):
        env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
        env.pop("XLA_FLAGS", None)
        if devices:
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={devices}"
        cmd = [sys.executable, "tests/_ep_child.py",
               "--modes", ",".join(EP_MODES)]
        if mesh:
            cmd += ["--mesh", mesh]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=str(repo), timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"ep child failed:\n{r.stdout}\n{r.stderr}")
        return json.loads(r.stdout)

    single = child()
    meshed = child(mesh=EP_MESH, devices=EP_DEVICES)

    modes = {}
    for m in EP_MODES:
        s, d = single[m], meshed[m]
        strip = lambda rec: {k: v for k, v in rec.items() if k != "perf"}
        modes[m] = {
            "parity_bitwise": strip(s) == strip(d),
            "tokens": s["tokens_out"],
            "single": s["perf"],
            "mesh": d["perf"],
        }
        print(f"[{'ep/' + m:>22}] single {s['perf']['tok_per_s']:6.1f} tok/s"
              f"  mesh({EP_MESH}) {d['perf']['tok_per_s']:6.1f} tok/s  "
              f"parity={modes[m]['parity_bitwise']}")

    # full-scale modeled traffic: per-device expert stream + interconnect
    fcfg = configs.get(EP_FULL_SCALE_ARCH)
    kw = dict(n_slots=EP_FULL_SCALE_SLOTS, pos=FULL_SCALE_POS)
    t1 = decode_traffic_model(fcfg, **kw)
    tm = decode_traffic_model(fcfg, **kw, ep_degree=EP_FULL_SCALE_EP,
                              dp_degree=EP_FULL_SCALE_DP)
    tm8 = decode_traffic_model(fcfg, **kw, ep_degree=EP_FULL_SCALE_EP,
                               dp_degree=EP_FULL_SCALE_DP,
                               combine_wire_dtype="int8")
    gate = EP_FULL_SCALE_EP * EP_STREAM_GATE_FRACTION
    sec = {
        "mesh": EP_MESH,
        "devices": EP_DEVICES,
        "modes": modes,
        "parity_ok": bool(all(v["parity_bitwise"] for v in modes.values())),
        "full_scale": {
            "arch": EP_FULL_SCALE_ARCH,
            "ep_degree": EP_FULL_SCALE_EP,
            "dp_degree": EP_FULL_SCALE_DP,
            "n_slots": EP_FULL_SCALE_SLOTS,
            "expert_stream_bytes_per_token_1dev": round(
                t1["moe_expert_bytes_per_token"]),
            "expert_stream_bytes_per_token": round(
                tm["moe_expert_bytes_per_token"]),
            "expert_stream_reduction": round(
                tm["expert_stream_reduction"], 3),
            "hbm_bytes_per_token": round(tm["bytes_per_token"]),
            "interconnect_bytes_per_token": round(
                tm["interconnect_bytes_per_token"]),
            # opt-in int8 combine wire: the return leg shrinks 4x, the
            # dispatch leg (bf16 activations) and all-gather stay put
            "interconnect_bytes_per_token_int8_wire": round(
                tm8["interconnect_bytes_per_token"]),
            "wire_savings_int8": round(
                tm["interconnect_bytes_per_token"]
                / max(tm8["interconnect_bytes_per_token"], 1e-9), 3),
        },
        "expert_stream_gate": gate,
    }
    sec["expert_stream_ok"] = bool(
        sec["full_scale"]["expert_stream_reduction"] >= gate)
    print(f"[{'ep/full-scale':>22}] expert stream "
          f"{sec['full_scale']['expert_stream_reduction']}x/dev below "
          f"single-device (gate {gate}x); interconnect "
          f"{sec['full_scale']['interconnect_bytes_per_token']}B/tok fp32 "
          f"wire, {sec['full_scale']['interconnect_bytes_per_token_int8_wire']}"
          f"B/tok int8 wire")
    return sec


# --- fault injection + resilience (DESIGN.md §12) --------------------------
# Deterministic degraded-mode trace: a seeded FaultPlan injects ONE NaN
# poisoning (slot 0, first fused block), ONE transient device failure
# burst (2 consecutive fails at step 8, inside the default retry budget),
# and ONE allocator exhaustion (step 8, deferring the FIFO head, whose TTL
# then expires -> pool-pressure shed). The geometry is pinned — not taken
# from argparse — so the fault arithmetic below is exact on every run:
# observed counters must equal the injected counts, healthy slots must be
# bitwise identical to the fault-free run, and the same seed must replay
# the identical fault trace (digest + tokens).
#
# The shed is attributable to the INJECTED exhaustion, not to plain
# overload: uid 3 finishes early (FAULT_SHORT_NEW tokens), so a slot is
# free at step 8 and the fault-free control admits uid 4 well inside its
# deadline (8 <= 12) and serves all eight requests — only the degraded
# run, whose step-8 admission is deferred by the injected empty pool,
# sees uid 4 expire by the next boundary (16 > 12).
FAULT_SEED = 0
FAULT_N_SLOTS = 4
FAULT_K = 8
FAULT_S_MAX = 32
FAULT_PROMPT_LEN = 8
FAULT_MAX_NEW = 12
FAULT_SHORT_UID = 3    # finishes in the first block: frees the slot the
FAULT_SHORT_NEW = 2    # clean run admits uid 4 into at step 8
FAULT_ARRIVALS = (0.0, 0.0, 0.0, 0.0, 2.0, 2.0, 4.0, 6.0)
FAULT_TTL_UID = 4      # arrival 2 + TTL 10 = deadline 12: alive when the
FAULT_TTL = 10.0       # injected exhaustion defers it (step 8), expired
                       # by the next admission boundary (16)


def fault_plan():
    from repro.serving.faults import FaultPlan, FaultSpec
    return FaultPlan(seed=FAULT_SEED, specs=(
        FaultSpec(site="decode", kind="nan_logits", steps=(2,), slots=(0,)),
        FaultSpec(site="decode", kind="transient", steps=(8,), fails=2),
        FaultSpec(site="alloc", kind="exhaust", steps=(8,)),
    ))


def _fault_engine(cfg, params, plan):
    """Fused engine for the degraded trace. Warmup compiles every shape the
    trace needs with NO plan attached, then the step clock rewinds to 0 and
    the plan arms — the fault arithmetic is in absolute engine steps, and
    the trace-guard counters stay a meaningful zero-gate."""
    eng = Engine(EngineConfig(n_slots=FAULT_N_SLOTS, s_max=FAULT_S_MAX,
                              prefill_buckets=(FAULT_PROMPT_LEN,),
                              decode_block=FAULT_K, dispatch="gather",
                              batch_admission=True),
                 cfg=cfg, params=params)
    for burst in (FAULT_N_SLOTS, 3, 2, 1):
        for _ in range(burst):
            eng.submit(np.zeros(FAULT_PROMPT_LEN, np.int32),
                       max_new_tokens=1)
        eng.run()
    for c in eng.counters:
        eng.counters[c] = 0
    eng._step_count = 0
    eng._faults = plan
    return eng


def _run_fault_trace(cfg, params, plan):
    """Serve the pinned degraded trace; returns (engine, done-by-uid)."""
    eng = _fault_engine(cfg, params, plan)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=FAULT_PROMPT_LEN,
                            dtype=np.int32) for _ in FAULT_ARRIVALS]
    for i, (p, a) in enumerate(zip(prompts, FAULT_ARRIVALS)):
        eng.submit(p,
                   max_new_tokens=(FAULT_SHORT_NEW if i == FAULT_SHORT_UID
                                   else FAULT_MAX_NEW),
                   arrival_time=a, uid=i,
                   ttl=FAULT_TTL if i == FAULT_TTL_UID else None)
    done = {r.uid: r for r in eng.run()}
    return eng, done


def restore_equals_uninterrupted(cfg, params, *, draft=None,
                                 engine_kw=None) -> bool:
    """Mid-trace snapshot/restore parity (DESIGN.md §12): interrupt a small
    trace after one fused call, restore into a fresh engine, and require
    the union of pre-crash and post-restore outputs to equal the
    uninterrupted run token-for-token (statuses included)."""

    def mk():
        return Engine(EngineConfig(n_slots=2, s_max=FAULT_S_MAX,
                                   prefill_buckets=(FAULT_PROMPT_LEN,),
                                   decode_block=FAULT_K,
                                   **(engine_kw or {})),
                      cfg=cfg, params=params,
                      draft_cfg=draft[0] if draft else None,
                      draft_params=draft[1] if draft else None)

    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, size=FAULT_PROMPT_LEN,
                            dtype=np.int32) for _ in range(3)]

    def submit(eng):
        for i, (p, a) in enumerate(zip(prompts, (0.0, 0.0, 5.0))):
            eng.submit(p, max_new_tokens=10, arrival_time=a, uid=i)

    def key(done):
        return {r.uid: (list(r.out_tokens), r.status) for r in done}

    ref = mk()
    submit(ref)
    want = key(ref.run())
    eng = mk()
    submit(eng)
    pre = eng.step_spec() if eng.spec else eng.step_block()
    snap = eng.snapshot()
    restored = Engine.restore(snap, cfg=cfg, params=params,
                              draft_cfg=draft[0] if draft else None,
                              draft_params=draft[1] if draft else None)
    return key(list(pre) + restored.run()) == want


def fault_section(cfg, params, ncfg, nparams) -> dict:
    """The BENCH_serve.json ``faults`` section: degraded-mode accounting
    (observed counters == injected counts, exactly), healthy-slot bitwise
    parity vs the fault-free run, same-seed replay determinism, and
    snapshot/restore parity in dense/paged/spec modes."""
    from collections import Counter

    plan = fault_plan()
    eng, done = _run_fault_trace(cfg, params, plan)
    clean_eng, clean = _run_fault_trace(cfg, params, None)
    replay_plan = fault_plan()
    _, replay = _run_fault_trace(cfg, params, replay_plan)

    fired = plan.counts()
    injected_fails = sum(ev.get("fails", 0) for ev in plan.trace
                         if ev["kind"] == "transient")
    observed = {"quarantined": int(eng.counters["quarantined"]),
                "transient_retries": int(eng.counters["transient_retries"]),
                "shed": int(eng.counters["shed"])}
    statuses = Counter(r.status for r in done.values())
    shed_reasons = Counter(r.shed_reason for r in done.values()
                           if r.shed_reason)
    healthy = [u for u, r in done.items() if r.status == "ok"]
    quarantined_uids = [u for u, r in done.items()
                        if r.status == "failed_numeric"]
    sec = {
        "seed": FAULT_SEED,
        "requests": len(FAULT_ARRIVALS),
        "injected": dict(fired, transient_fails=injected_fails),
        "observed": observed,
        "statuses": dict(statuses),
        "shed_reasons": dict(shed_reasons),
        "quarantined_uids": quarantined_uids,
        # quarantine blast radius: every surviving request's stream is
        # bitwise what the fault-free engine served it
        "healthy_parity_bitwise": bool(all(
            done[u].out_tokens == clean[u].out_tokens for u in healthy)),
        # the poisoned slot's stream truncates AT the fault: a bitwise
        # prefix of its fault-free stream, never divergent garbage
        "quarantined_prefix_of_clean": bool(all(
            done[u].out_tokens
            == clean[u].out_tokens[:len(done[u].out_tokens)]
            and len(done[u].out_tokens) < len(clean[u].out_tokens)
            for u in quarantined_uids)),
        # clean-engine control: no injected faults -> no degraded counters
        "clean_run_counters_zero": bool(
            clean_eng.counters["shed"] == 0
            and clean_eng.counters["quarantined"] == 0
            and clean_eng.counters["transient_retries"] == 0),
        # same seed -> same fault trace (digest) AND same served tokens
        "fault_trace_digest": plan.trace_digest(),
        "replay_digest_equal": bool(
            replay_plan.trace_digest() == plan.trace_digest()),
        "replay_tokens_bitwise": bool(all(
            replay[u].out_tokens == done[u].out_tokens
            and replay[u].status == done[u].status for u in done)),
        # the degraded engine keeps the hot-loop contract: injected faults
        # must not smuggle retraces or implicit transfers into the loop
        "retraces": int(eng.counters["retraces"]),
        "implicit_transfers": int(eng.counters["implicit_transfers"]),
        "restore": {
            "dense": restore_equals_uninterrupted(cfg, params),
            "paged": restore_equals_uninterrupted(
                cfg, params, engine_kw=dict(kv_layout="paged",
                                            kv_block=PAGED_KV_BLOCK)),
            "spec": restore_equals_uninterrupted(
                cfg, params, draft=(ncfg, nparams),
                engine_kw=dict(spec_k=4)),
        },
    }
    sec["accounting_exact"] = bool(
        observed["quarantined"] == fired.get("nan_logits", 0)
        and observed["shed"] == fired.get("exhaust", 0)
        and observed["transient_retries"] == injected_fails
        and statuses.get("ok", 0) == len(FAULT_ARRIVALS) - 2
        and statuses.get("shed", 0) == 1
        and statuses.get("failed_numeric", 0) == 1
        and shed_reasons.get("pool_pressure", 0) == 1)
    sec["ok"] = bool(
        sec["accounting_exact"]
        and sec["healthy_parity_bitwise"]
        and sec["quarantined_prefix_of_clean"]
        and sec["clean_run_counters_zero"]
        and sec["replay_digest_equal"]
        and sec["replay_tokens_bitwise"]
        and sec["retraces"] == 0 and sec["implicit_transfers"] == 0
        and all(sec["restore"].values()))
    print(f"[{'faults/degraded':>22}] injected {sec['injected']} -> "
          f"observed {sec['observed']}; statuses {sec['statuses']}; "
          f"healthy parity={sec['healthy_parity_bitwise']} "
          f"replay={sec['replay_digest_equal']} restore={sec['restore']}")
    return sec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="fused K (the 'after' engine)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per decode step)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--bench-iters", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--int8-tolerance", type=float, default=0.85,
                    help="minimum top-1 greedy token match of the int8 rows "
                         "vs their bf16 counterparts on the trace")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    M = cfg.moe.n_experts // 2
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=M, split=0,
        batches=calib)
    # int8 variants: the uncompressed model quantized in place, and the SAME
    # merge executed through the plan path with weight_dtype='int8' (the
    # solves are deterministic, so the merged tables match the bf16 row
    # before quantization)
    params_q = Q.quantize_model_experts(params)
    plan_q = PLAN.uniform(cfg, method="mergemoe", merged_experts=M, split=0,
                          weight_dtype="int8")
    qcfg, qparams, qinfo = CMP.compress_with_plan(
        cfg, params, plan_q, batches=calib, calib_policy="head")

    rng = np.random.default_rng(args.seed + 1)
    lens = rng.choice([8, 16, 24, 32], size=args.requests)
    lens = np.minimum(lens, args.s_max - args.max_new_tokens - 1)
    arrivals = poisson_trace(args.requests, rate=args.rate,
                             seed=args.seed + 2)
    common = dict(requests=args.requests, prompt_lens=lens, arrivals=arrivals,
                  max_new_tokens=args.max_new_tokens, n_slots=args.n_slots,
                  s_max=args.s_max, buckets=(8, 16, 24, 32),
                  repeats=args.repeats, bench_iters=args.bench_iters)
    K = args.decode_block
    before = dict(decode_block=1, dispatch="ragged", batch_admission=False)
    after = dict(decode_block=K, dispatch="gather", batch_admission=True)

    print(f"== serve_bench: {args.requests} requests, Poisson rate "
          f"{args.rate}/step, {args.n_slots} slots, K={K} ==")
    rows, toks = {}, {}
    for tag, c, p in (("full", cfg, params), ("compressed", ncfg, nparams)):
        rb, tb, _ = run_trace(c, p, label=f"{tag}/before(K1,ragged)",
                           **before, **common)
        ra, ta, served_prompts = run_trace(c, p, label=f"{tag}/after(K{K},gather)",
                           **after, **common)
        # gather==ragged isolation at the same fused K, and batched==serial
        # admission isolation at the same dispatch
        rr, tr, _ = run_trace(c, p, label=f"{tag}/after(K{K},ragged)",
                           **dict(after, dispatch="ragged"),
                           **dict(common, repeats=1, run_bench=False))
        rs, ts, _ = run_trace(c, p, label=f"{tag}/after(serial-admit)",
                           **dict(after, batch_admission=False),
                           **dict(common, repeats=1, run_bench=False))
        rows[tag] = {"before": rb, "after": ra}
        toks[tag] = {"before": tb, "after": ta, "ragged": tr, "serial": ts}

    # int8 rows: the fused/after engine over the identical trace, expert
    # tables stored int8 (dequant fused into the kernels, DESIGN.md §8)
    for tag, c, p in (("full-int8", cfg, params_q),
                      ("compressed-int8", qcfg, qparams)):
        ri, ti, _ = run_trace(c, p, label=f"{tag}/after(K{K},gather)",
                           **after, **common)
        rows[tag] = {"after": ri}
        toks[tag] = {"after": ti}

    # --- paged + int8 KV rows (DESIGN.md §11) -------------------------------
    # the fused/after engine over the identical trace, KV held in a paged
    # block pool. bf16 pool is gated BITWISE vs the dense engine; the int8
    # pool is tolerance-gated (teacher-forced) below.
    paged_kw = dict(kv_layout="paged", kv_block=PAGED_KV_BLOCK)
    rp, tp, _ = run_trace(cfg, params, label=f"paged/bf16(K{K})",
                          **after, **dict(common, repeats=1),
                          engine_kw=paged_kw)
    rpi, tpi, _ = run_trace(cfg, params, label=f"paged/int8kv(K{K})",
                            **after, **dict(common, repeats=1),
                            engine_kw=dict(paged_kw, kv_dtype="int8"))
    rows["paged"] = {"bf16": rp, "int8": rpi}
    toks["paged"] = {"bf16": tp, "int8": tpi}

    # --- speculative decoding rows (DESIGN.md §10) --------------------------
    # dedicated trace: acceptance needs enough committed tokens to be a
    # stable CI signal, so floor the request count / generation length
    spec_requests = max(args.requests, 6)
    spec_new = max(args.max_new_tokens, 12)
    spec_rng = np.random.default_rng(args.seed + 3)
    spec_lens = np.minimum(spec_rng.choice([8, 16, 24, 32], size=spec_requests),
                           args.s_max - spec_new - 1)
    spec_arrivals = poisson_trace(spec_requests, rate=args.rate,
                                  seed=args.seed + 4)
    trace_kw = dict(requests=spec_requests, prompt_lens=spec_lens,
                    arrivals=spec_arrivals, max_new_tokens=spec_new,
                    n_slots=args.n_slots, s_max=args.s_max,
                    buckets=(8, 16, 24, 32))
    spec_kw = dict(trace_kw, arch=args.arch)
    # full-engine references over the SAME trace: the spec engine's bitwise
    # contract is against the production fused loop, greedy AND sampled.
    # run_trace rebuilds prompts deterministically from the lens, and the
    # warmup submit pattern matches run_spec_trace's, so request uids — and
    # with them the position-indexed Gumbel keys — line up across engines.
    ref_g, ref_g_toks, _ = run_trace(
        cfg, params, label=f"spec-ref/greedy(K{K})", **after,
        **dict(trace_kw, repeats=1, run_bench=False))
    ref_t, ref_t_toks, _ = run_trace(
        cfg, params, label=f"spec-ref/t0.7(K{K})", **after,
        **dict(trace_kw, repeats=1, run_bench=False, temperature=0.7))
    spec_rows, spec_toks = {}, {}
    for key, dcfg, dparams, kd, temp, tag, iters in (
            # greedy K-sweep on the MergeMoE M=N/2 draft (the paper artifact)
            ("k2_bf16_half", ncfg, nparams, 2, 0.0, "bf16_half", 0),
            ("k4_bf16_half", ncfg, nparams, 4, 0.0, "bf16_half", 0),
            # headline deployment shape: int8 M=N/2 draft + steady bench
            ("k4_int8_half", qcfg, qparams, 4, 0.0, "int8_half",
             args.bench_iters),
            # same-weights (quantized) draft: the coupled sampler's sharp
            # regression detector — acceptance collapses if the key
            # schedule, verify forward, or acceptance rule breaks
            ("k4_int8_full", cfg, params_q, 4, 0.0, "int8_full", 0),
            # temperature>0: exercises the Gumbel-coupled exact-match path
            ("k4_t07_bf16_half", ncfg, nparams, 4, 0.7, "bf16_half", 0)):
        r, t = run_spec_trace(cfg, params, dcfg, dparams, label=f"spec/{key}",
                              k=kd, temperature=temp, draft_tag=tag,
                              bench_iters=iters, **spec_kw)
        spec_rows[key], spec_toks[key] = r, t
    merged_floor = SPEC_ACCEPT_FLOOR_MERGED_CHANCE_MULT / cfg.vocab_size
    ref_committed = spec_mean_committed(SPEC_REFERENCE_ACCEPTANCE, 4)
    modeled_ref = full_scale_spec_traffic(args.arch, k=4,
                                          mean_committed=ref_committed,
                                          draft="int8_half")
    spec = {
        "requests": spec_requests,
        "max_new_tokens": spec_new,
        "rows": spec_rows,
        "ref_greedy_tok_per_s": ref_g["tok_per_s"],
        "ref_t07_tok_per_s": ref_t["tok_per_s"],
        # trace tok/s vs the fused full-model engine on the same trace —
        # recorded, not gated: CPU smoke is FLOPs-bound while the deployment
        # claim is HBM-bound (same stance as the int8 rows)
        "trace_tok_per_s_vs_ref": {
            key: round(r["tok_per_s"] / ref_g["tok_per_s"], 3)
            for key, r in spec_rows.items() if r["temperature"] == 0.0},
        "parity_greedy_bitwise": all(
            spec_toks[key] == ref_g_toks for key, r in spec_rows.items()
            if r["temperature"] == 0.0),
        "parity_t07_bitwise": spec_toks["k4_t07_bf16_half"] == ref_t_toks,
        "acceptance_floor_self": SPEC_ACCEPT_FLOOR_SELF,
        "acceptance_floor_merged": round(merged_floor, 6),
        # gated modeled deployment speedup at the recorded reference
        # acceptance and deployment batch (see constants at top)
        "reference_acceptance": SPEC_REFERENCE_ACCEPTANCE,
        "modeled_full_scale_at_reference": modeled_ref,
        "gate_slots": SPEC_GATE_SLOTS,
        "speedup_gate": SPEC_SPEEDUP_GATE,
        "modeled_speedup_at_reference":
            modeled_ref[str(SPEC_GATE_SLOTS)]["modeled_speedup"],
    }
    spec["acceptance_ok"] = bool(
        spec_rows["k4_int8_full"]["acceptance_rate"] >= SPEC_ACCEPT_FLOOR_SELF
        and all(spec_rows[k]["acceptance_rate"] >= merged_floor
                for k in ("k2_bf16_half", "k4_bf16_half", "k4_int8_half",
                          "k4_t07_bf16_half")))
    spec["speedup_ok"] = bool(
        spec["modeled_speedup_at_reference"] >= SPEC_SPEEDUP_GATE)

    bf16_tags = ("full", "compressed")
    parity = {
        "fused_vs_step_bitwise": all(
            toks[t]["before"] == toks[t]["after"] for t in bf16_tags),
        "gather_vs_ragged_bitwise": all(
            toks[t]["after"] == toks[t]["ragged"] for t in bf16_tags),
        "batched_vs_serial_admission_bitwise": all(
            toks[t]["after"] == toks[t]["serial"] for t in bf16_tags),
    }
    fb, fa = rows["full"]["before"], rows["full"]["after"]
    cb, ca = rows["compressed"]["before"], rows["compressed"]["after"]
    qf, qc = rows["full-int8"]["after"], rows["compressed-int8"]["after"]
    fs = full_scale_traffic(args.arch, args.n_slots)
    int8 = {
        "full": qf,
        "compressed": qc,
        # quality at equal tolerance: per-position greedy top-1 agreement
        # with the bf16 weights, teacher-forced on the bf16 rows' trace —
        # prompts come FROM that trace (run_trace returns the prompts it
        # served), never regenerated in parallel
        "top1_match_full": round(top1_match(
            cfg, params_q, cfg, params,
            served_prompts, toks["full"]["after"]), 4),
        "top1_match_compressed": round(top1_match(
            qcfg, qparams, ncfg, nparams,
            served_prompts, toks["compressed"]["after"]), 4),
        "tolerance": args.int8_tolerance,
        # smoke-config modeled-traffic reduction (expert stream)
        "expert_stream_reduction_vs_bf16_half_smoke": round(
            ca["moe_expert_bytes_per_token"]
            / max(qc["moe_expert_bytes_per_token"], 1e-9), 3),
        # full-scale modeled traffic — the deployment claim
        "modeled_full_scale": fs,
    }
    int8["parity_ok"] = bool(
        int8["top1_match_full"] >= args.int8_tolerance
        and int8["top1_match_compressed"] >= args.int8_tolerance)
    int8["expert_stream_gate"] = EXPERT_STREAM_GATE
    int8["expert_stream_ok"] = bool(all(
        fs[k]["expert_stream_reduction_vs_bf16_half"] >= EXPERT_STREAM_GATE
        for k in ("int8_full", "int8_half")))

    # --- paged KV section (DESIGN.md §11) -----------------------------------
    share = prefix_share_trace(cfg, params, n_slots=args.n_slots,
                               s_max=args.s_max, decode_block=K,
                               max_new_tokens=args.max_new_tokens)
    kv_top1 = round(paged_top1_match(cfg, params, served_prompts,
                                     toks["full"]["after"],
                                     s_max=args.s_max), 4)
    full_cfg = configs.get(args.arch)
    kv_bf16 = decode_traffic_model(
        full_cfg, n_slots=args.n_slots,
        pos=FULL_SCALE_POS)["kv_bytes_per_token"]
    kv_int8 = decode_traffic_model(
        full_cfg, n_slots=args.n_slots, pos=FULL_SCALE_POS,
        kv_dtype="int8")["kv_bytes_per_token"]
    paged = {
        "kv_block": PAGED_KV_BLOCK,
        "bf16": rp,
        "int8": rpi,
        # free-running bitwise contract: the bf16 paged engine must decode
        # token-for-token what the dense engine decoded on the same trace
        "parity_bf16_bitwise": toks["paged"]["bf16"] == toks["full"]["after"],
        # int8-KV quality: teacher-forced per-position top-1 vs the bf16
        # trace (free-running agreement would measure divergence position,
        # not per-token quality — same stance as the int8-weight rows)
        "top1_match_int8_kv": kv_top1,
        "tolerance": KV_INT8_TOLERANCE,
        "prefix_sharing": share,
        # full-scale modeled decode KV stream — the deployment claim
        "modeled_full_scale_kv": {
            "bf16_bytes_per_token": round(kv_bf16),
            "int8_bytes_per_token": round(kv_int8),
            "kv_stream_reduction": round(kv_bf16 / kv_int8, 3),
        },
        "kv_stream_gate": KV_STREAM_GATE,
    }
    paged["kv_stream_ok"] = bool(
        paged["modeled_full_scale_kv"]["kv_stream_reduction"]
        >= KV_STREAM_GATE)
    paged["parity_ok"] = bool(
        paged["parity_bf16_bitwise"]
        and share["parity_duplicates_bitwise"]
        and kv_top1 >= KV_INT8_TOLERANCE)

    # --- expert-parallel sharded decode (DESIGN.md §13) ---------------------
    ep = ep_section()

    # --- fault injection + resilience (DESIGN.md §12) -----------------------
    faults = fault_section(cfg, params, ncfg, nparams)
    summary = {
        "arch": args.arch,
        "n_slots": args.n_slots,
        "decode_block": K,
        "requests": args.requests,
        "max_new_tokens": args.max_new_tokens,
        "full": rows["full"],
        "compressed": rows["compressed"],
        "int8": int8,
        "spec": spec,
        "paged": paged,
        "ep": ep,
        "faults": faults,
        "parity": parity,
        "compression_ratio": round(info["compression_ratio"], 3),
        "compression_ratio_int8": round(qinfo["compression_ratio"], 3),
        "speedup": {
            "host_dispatch_reduction_fused": round(
                fb["host_dispatches_per_token"]
                / fa["host_dispatches_per_token"], 2),
            "steady_dispatch_reduction_fused": round(
                fb["steady_host_dispatches_per_token"]
                / fa["steady_host_dispatches_per_token"], 2),
            "steady_tok_per_s_fused": round(
                fa["steady_decode_tok_per_s"]
                / fb["steady_decode_tok_per_s"], 3),
            "trace_tok_per_s_fused": round(
                fa["tok_per_s"] / fb["tok_per_s"], 3),
            "steady_tok_per_s_compressed_after": round(
                ca["steady_decode_tok_per_s"]
                / fa["steady_decode_tok_per_s"], 3),
            "trace_tok_per_s_compressed_after": round(
                ca["tok_per_s"] / fa["tok_per_s"], 3),
        },
    }
    print(f"== fused K={K}: {summary['speedup']['host_dispatch_reduction_fused']}x "
          f"fewer host dispatches/token on the trace "
          f"({summary['speedup']['steady_dispatch_reduction_fused']}x steady), "
          f"{summary['speedup']['trace_tok_per_s_fused']}x trace tok/s, "
          f"{summary['speedup']['steady_tok_per_s_fused']}x steady tok/s ==")
    print(f"== int8: full-scale expert stream "
          f"{fs['int8_full']['expert_stream_reduction_vs_bf16_half']}x (full) / "
          f"{fs['int8_half']['expert_stream_reduction_vs_bf16_half']}x (M=N/2) "
          f"below the bf16 M=N/2 row; top-1 match "
          f"{int8['top1_match_full']} / {int8['top1_match_compressed']} "
          f"(tolerance {args.int8_tolerance}) ==")
    print(f"== spec: parity greedy={spec['parity_greedy_bitwise']} "
          f"t0.7={spec['parity_t07_bitwise']}; acceptance self-draft "
          f"{spec_rows['k4_int8_full']['acceptance_rate']} "
          f"(floor {SPEC_ACCEPT_FLOOR_SELF}), merged "
          f"{spec_rows['k4_int8_half']['acceptance_rate']} "
          f"(floor {spec['acceptance_floor_merged']}); modeled speedup "
          f"{spec['modeled_speedup_at_reference']}x at "
          f"{SPEC_GATE_SLOTS} slots / acceptance "
          f"{SPEC_REFERENCE_ACCEPTANCE} (gate {SPEC_SPEEDUP_GATE}x) ==")
    print(f"== paged KV: bf16 parity={paged['parity_bf16_bitwise']}; "
          f"int8-KV top-1 {kv_top1} (tolerance {KV_INT8_TOLERANCE}); "
          f"prefix hit rate {share['hit_rate']} "
          f"({share['prefix_rows_shared']} rows shared, duplicates bitwise="
          f"{share['parity_duplicates_bitwise']}); full-scale KV stream "
          f"{paged['modeled_full_scale_kv']['kv_stream_reduction']}x below "
          f"dense bf16 (gate {KV_STREAM_GATE}x) ==")
    print(f"== ep: parity={ep['parity_ok']} on {EP_MESH}; full-scale "
          f"expert stream {ep['full_scale']['expert_stream_reduction']}x/dev "
          f"below single-device at EP={EP_FULL_SCALE_EP} "
          f"(gate {ep['expert_stream_gate']}x); interconnect "
          f"{ep['full_scale']['interconnect_bytes_per_token']}B/tok ==")
    print(f"== faults: injected {faults['injected']} -> observed "
          f"{faults['observed']} (exact={faults['accounting_exact']}); "
          f"healthy-slot parity={faults['healthy_parity_bitwise']}; "
          f"same-seed replay={faults['replay_digest_equal']}; restore "
          f"parity {faults['restore']} ==")
    print(f"== parity {parity} ==")
    OUT_PATH.write_text(json.dumps(summary, indent=1))
    print(f"wrote {OUT_PATH}")
    if args.json:
        print(json.dumps(summary, indent=1))
    if not all(parity.values()):
        raise SystemExit("serve_bench parity check FAILED: " + repr(parity))
    if not int8["parity_ok"]:
        raise SystemExit(
            f"serve_bench int8 parity-tolerance FAILED: "
            f"top-1 match full={int8['top1_match_full']} "
            f"compressed={int8['top1_match_compressed']} "
            f"< tolerance {args.int8_tolerance}")
    if not int8["expert_stream_ok"]:
        raise SystemExit(
            f"serve_bench int8 expert-stream gate FAILED: full-scale "
            f"reductions {fs['int8_full']['expert_stream_reduction_vs_bf16_half']}x / "
            f"{fs['int8_half']['expert_stream_reduction_vs_bf16_half']}x "
            f"below {EXPERT_STREAM_GATE}x vs the bf16 M=N/2 row")
    if not (spec["parity_greedy_bitwise"] and spec["parity_t07_bitwise"]):
        raise SystemExit(
            f"serve_bench spec parity FAILED: the speculative engine must be "
            f"token-for-token identical to the fused full-model engine "
            f"(greedy={spec['parity_greedy_bitwise']}, "
            f"t0.7={spec['parity_t07_bitwise']})")
    if not spec["acceptance_ok"]:
        raise SystemExit(
            f"serve_bench spec acceptance floors FAILED: "
            + repr({k: r['acceptance_rate'] for k, r in spec_rows.items()})
            + f" (self floor {SPEC_ACCEPT_FLOOR_SELF}, merged floor "
              f"{spec['acceptance_floor_merged']})")
    if not spec["speedup_ok"]:
        raise SystemExit(
            f"serve_bench spec modeled-speedup gate FAILED: "
            f"{spec['modeled_speedup_at_reference']}x at {SPEC_GATE_SLOTS} "
            f"slots < {SPEC_SPEEDUP_GATE}x")
    if not (paged["parity_bf16_bitwise"]
            and share["parity_duplicates_bitwise"]):
        raise SystemExit(
            f"serve_bench paged-KV parity FAILED: the bf16 paged engine must "
            f"be token-for-token identical to the dense engine "
            f"(trace={paged['parity_bf16_bitwise']}, "
            f"duplicates={share['parity_duplicates_bitwise']})")
    if kv_top1 < KV_INT8_TOLERANCE:
        raise SystemExit(
            f"serve_bench int8-KV tolerance FAILED: teacher-forced top-1 "
            f"{kv_top1} < {KV_INT8_TOLERANCE}")
    if not paged["kv_stream_ok"]:
        raise SystemExit(
            f"serve_bench paged-KV stream gate FAILED: full-scale reduction "
            f"{paged['modeled_full_scale_kv']['kv_stream_reduction']}x "
            f"< {KV_STREAM_GATE}x vs dense bf16")
    if not ep["parity_ok"]:
        raise SystemExit(
            f"serve_bench EP parity FAILED: the {EP_MESH} mesh engine must "
            f"be token-for-token identical to the single-device engine: "
            + repr({m: v['parity_bitwise'] for m, v in ep['modes'].items()}))
    if not ep["expert_stream_ok"]:
        raise SystemExit(
            f"serve_bench EP expert-stream gate FAILED: modeled per-device "
            f"reduction {ep['full_scale']['expert_stream_reduction']}x "
            f"< {ep['expert_stream_gate']}x at EP={EP_FULL_SCALE_EP}")
    happy_degraded = [
        (label, c, rows_rec.get(c))
        for label, rows_rec in (("full/before", rows["full"]["before"]),
                                ("full/after", rows["full"]["after"]))
        for c in ("shed", "quarantined", "transient_retries")
        if rows_rec.get(c)]
    if happy_degraded:
        raise SystemExit(
            f"serve_bench happy-path resilience counters FAILED (must be "
            f"zero without injected faults): {happy_degraded}")
    if not faults["ok"]:
        raise SystemExit(
            f"serve_bench fault-injection gate FAILED: "
            + json.dumps({k: v for k, v in faults.items()
                          if k != 'fault_trace_digest'}, indent=1))


if __name__ == "__main__":
    main()
