"""Static-analysis layer (DESIGN.md §9): the AST linter fires each rule on
a seeded fixture and stays at zero findings on the repo tree; the kernel
contract checker validates every registered kernel against every config
without executing one, and rejects crafted contract violations; the trace
guard counts retraces and implicit transfers (and raises in strict mode).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (TraceGuard, TraceGuardError,
                            check_kernel_contracts, run_lint)
from repro.analysis.kernel_contracts import (_Capture, _check_capture,
                                             VMEM_WAIVERS)
from repro.analysis.lint import Analyzer, load_modules

# one seeded violation per rule, plus a suppressed one (the CLI fixture the
# acceptance criteria name)
FIXTURE_BAD = textwrap.dedent("""
    import jax
    import jax.numpy as jnp
    import numpy as np
    import pdb
    from jax.experimental import pallas as pl


    def hot(x):
        y = jnp.sum(x)
        v = y.item()                      # RA001
        f = float(y)                      # RA002
        if y > 0:                         # RA003
            y = y + 1
        z = np.square(y)                  # RA004
        jax.debug.print("y={}", y)        # RA005
        return y + f + v + z


    step = jax.jit(hot)


    @jax.jit
    def branchy(x, flag):
        if flag:                          # RA006
            return x + 1
        return x


    def rogue(x):
        return pl.pallas_call(lambda r, o: None, out_shape=None)(x)  # RA007


    def ok_suppressed(x):
        y = jnp.sum(x)
        return float(y)  # lint: ignore[RA002] host metric readout


    ok = jax.jit(ok_suppressed)
""")
FIXTURE_IMPORT = "from repro.kernels import grouped_mlp  # RA008\n"

ALL_RULES = {f"RA00{i}" for i in range(1, 9)}


@pytest.fixture(scope="module")
def fixture_root(tmp_path_factory):
    root = tmp_path_factory.mktemp("lintfix")
    pkg = root / "repro"
    pkg.mkdir()
    (pkg / "bad.py").write_text(FIXTURE_BAD)
    (pkg / "bad_import.py").write_text(FIXTURE_IMPORT)
    return str(root)


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------

def test_every_rule_fires_on_fixture(fixture_root):
    report = run_lint(root=fixture_root)
    assert {f.rule for f in report.findings} == ALL_RULES
    # the one suppression is recorded, with its reason, not silently eaten
    assert [f.rule for f in report.suppressed] == ["RA002"]
    assert report.suppressed[0].reason == "host metric readout"
    assert not report.ok


def test_findings_carry_location_and_format(fixture_root):
    report = run_lint(root=fixture_root)
    f = next(f for f in report.findings if f.rule == "RA001")
    assert f.path.endswith("bad.py") and f.line > 0
    assert f"{f.path}:{f.line}" in f.format() and "RA001" in f.format()


def test_rule_allowlist(fixture_root):
    report = run_lint(root=fixture_root, rules=["RA007"])
    assert {f.rule for f in report.findings} == {"RA007"}


def test_repo_tree_is_clean():
    """The zero-findings baseline the CI lint lane enforces."""
    report = run_lint()
    assert report.findings == [], "\n".join(
        f.format() for f in report.findings)


def test_reachability_covers_hot_paths():
    """The linter only means something if the jit call graph actually
    reaches the model/kernel/serving code — pin the load-bearing entries
    so a resolution regression cannot silently lint nothing."""
    a = Analyzer(load_modules())
    must_reach = [
        ("repro.models.moe", "moe_apply"),
        ("repro.models.moe", "route"),
        ("repro.models.model", "decode_step_slots"),
        ("repro.models.transformer", "stack_apply"),
        ("repro.kernels.grouped_mlp", "_kernel"),
        ("repro.launch.steps",
         "make_slot_decode_multi.slot_decode_multi.step"),
        ("repro.serving.engine", "Engine.bench_decode.block"),
        # speculative decoding (DESIGN.md §10): the draft->verify->accept
        # round and its model-side verify forward
        ("repro.launch.steps", "sample_tokens"),
        ("repro.serving.spec", "build_slot_decode_spec.slot_decode_spec"),
        ("repro.serving.spec", "build_slot_admit_spec.slot_admit_spec"),
        ("repro.serving.spec", "accept_drafts"),
        ("repro.models.model", "verify_step_slots"),
        ("repro.models.transformer", "stack_verify_slots"),
        ("repro.models.layers", "attn_verify_slots"),
        ("repro.serving.engine", "Engine.bench_spec_decode.round_"),
    ]
    for entry in must_reach:
        assert entry in a.reachable, entry


def test_cli_exit_codes(fixture_root):
    env_src = {"PYTHONPATH": "src"}
    import os
    env = dict(os.environ, **env_src)
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-contracts",
         "--root", fixture_root],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert bad.returncode == 1
    assert "RA001" in bad.stdout and "suppressed" in bad.stdout
    good = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--no-contracts"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
    assert good.returncode == 0, good.stdout + good.stderr


def test_taint_does_not_flag_static_config_math(tmp_path):
    """moe._capacity-style int() on closed-over config must NOT be
    flagged: parameters and shape attributes are trace-static."""
    pkg = tmp_path / "repro"
    pkg.mkdir()
    (pkg / "ok.py").write_text(textwrap.dedent("""
        import jax
        import jax.numpy as jnp


        def helper(x, cf):
            cap = int(x.shape[0] * cf)      # static: shape * config float
            if x.ndim == 2:                 # static: ndim
                cap += 1
            if x is None:                   # static: identity
                return None
            return jnp.zeros((cap,))


        fn = jax.jit(helper)
    """))
    report = run_lint(root=str(tmp_path))
    assert report.findings == [], [f.format() for f in report.findings]


# ---------------------------------------------------------------------------
# kernel contracts
# ---------------------------------------------------------------------------

def test_contracts_pass_on_every_registered_kernel():
    report = check_kernel_contracts()
    assert report.findings == [], "\n".join(
        f.format() for f in report.findings)
    kernels = {k for k, _ in report.checked}
    assert kernels == {"swiglu_mlp", "grouped_swiglu", "grouped_swiglu_q",
                       "gather_swiglu", "gather_swiglu_q", "flash_attention",
                       "paged_attention", "paged_attention_q"}
    # MoE kernels validated against both MoE archs, dense/flash more widely
    moe_archs = {a for k, a in report.checked if k == "gather_swiglu"}
    assert moe_archs == {"kimi_k2_1t_a32b", "qwen3_moe_30b_a3b"}
    # every waiver in the table actually fired (stale waivers rot)
    fired = {(f.kernel, f.arch) for f in report.waived}
    assert fired == set(VMEM_WAIVERS)


def test_contracts_never_execute_a_kernel(monkeypatch):
    """Abstract-eval only: booby-trap every MoE kernel body so any
    invocation crashes, then check a config end to end. functools.wraps
    keeps the real body visible to the AST dtype check (inspect.getsource
    unwraps) while a call — traced or concrete — raises."""
    import functools

    def trap(real):
        @functools.wraps(real)
        def boom(*a, **k):
            raise AssertionError("kernel executed")
        return boom

    import repro.kernels.grouped_mlp as gm
    import repro.kernels.decode_moe as dm
    for mod, name in ((gm, "_kernel"), (gm, "_kernel_q"),
                      (dm, "_kernel"), (dm, "_kernel_q")):
        monkeypatch.setattr(mod, name, trap(getattr(mod, name)))
    report = check_kernel_contracts(arch_ids=["qwen3_moe_30b_a3b"])
    assert report.findings == [], [f.format() for f in report.findings]
    assert report.checked


def test_contracts_rerun_in_same_process_stays_clean():
    """eval_shape caches on function identity; a cache hit would skip
    tracing and the recorder would capture nothing — regression guard for
    back-to-back checker runs (CI lint lane + tests in one process)."""
    for _ in range(2):
        report = check_kernel_contracts(arch_ids=["qwen3_moe_30b_a3b"])
        assert report.findings == []
        assert report.checked, "second run captured nothing (cache hit)"


def _capture(**kw):
    d, f = 64, 128
    base = dict(
        kernel_fn=None,
        grid=(2, 2),
        in_specs=(_spec((32, d), lambda i, j: (i, 0)),),
        out_spec=_spec((32, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((64, d), jnp.bfloat16),
        scratch=(),
        num_prefetch=0,
        operands=(jax.ShapeDtypeStruct((64, d), jnp.bfloat16),),
    )
    base.update(kw)
    return _Capture(**base)


def _spec(block, imap, memory_space=None):
    class S:
        block_shape = block
        index_map = staticmethod(imap)
    if memory_space is not None:
        S.memory_space = memory_space
    return S()


def _findings(cap, quantized=False):
    return list(_check_capture(cap, "k", "a", {"quantized": quantized}))


def test_contract_checker_rejects_bad_divisibility():
    cap = _capture(in_specs=(_spec((48, 64), lambda i, j: (i, 0)),))
    assert any(f.check == "divisibility" for f in _findings(cap))


def test_contract_checker_rejects_oob_index_map():
    # grid (2,2) but index map reaches block row i+1 -> row 2 of 2 blocks
    cap = _capture(in_specs=(_spec((32, 64), lambda i, j: (i + 1, 0)),))
    assert any(f.check == "bounds" for f in _findings(cap))


def test_contract_checker_rejects_undercovered_output():
    cap = _capture(out_spec=_spec((32, 64), lambda i, j: (0, 0)))
    assert any(f.check == "coverage" for f in _findings(cap))


def test_contract_checker_rejects_vmem_blowout():
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)    # 64 MiB
    cap = _capture(
        in_specs=(_spec((4096, 4096), lambda i, j: (0, 0)),),
        operands=(big,))
    assert any(f.check == "vmem" for f in _findings(cap))


def test_contract_checker_rejects_dtype_breaches():
    # output dtype drifts from input dtype
    cap = _capture(out_shape=jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert any(f.check == "dtype" for f in _findings(cap))
    # quantized contract: needs 3 int8 tables
    cap = _capture()
    assert any("int8" in f.msg for f in _findings(cap, quantized=True))


def test_contract_checker_oob_clip_tables():
    """§7 contract: scalar-prefetch tables at their extreme legal value
    E-1 stay in bounds; a spec that offsets the table value breaks."""
    E, d = 4, 64
    table = jax.ShapeDtypeStruct((2,), jnp.int32)
    w = jax.ShapeDtypeStruct((E, d, d), jnp.bfloat16)
    ok = _capture(
        grid=(2,), num_prefetch=1,
        in_specs=(_spec((1, d, d), lambda i, ix: (ix[i], 0, 0)),),
        operands=(table, w),
        out_spec=_spec((32, d), lambda i, ix: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((64, d), jnp.bfloat16))
    assert not any(f.check == "bounds" for f in _findings(ok))
    bad = _capture(
        grid=(2,), num_prefetch=1,
        in_specs=(_spec((1, d, d), lambda i, ix: (ix[i] + 1, 0, 0)),),
        operands=(table, w),
        out_spec=_spec((32, d), lambda i, ix: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((64, d), jnp.bfloat16))
    assert any(f.check == "bounds" for f in _findings(bad))


# ---------------------------------------------------------------------------
# trace guard
# ---------------------------------------------------------------------------

def test_trace_guard_counts_traces_not_dispatches():
    g = TraceGuard("count")
    fn = g.wrap_jit("f", lambda x: x + 1, expected_traces=1)
    x = jnp.arange(4)
    for _ in range(5):
        fn(x)
    assert g.traces["f"] == 1 and g.counters["retraces"] == 0


def test_trace_guard_flags_retrace():
    g = TraceGuard("count")
    fn = g.wrap_jit("f", lambda x: x + 1, expected_traces=1)
    fn(jnp.arange(4))
    fn(jnp.arange(8))                       # new shape -> retrace
    assert g.traces["f"] == 2
    assert g.counters["retraces"] == 1


def test_trace_guard_strict_raises_on_retrace():
    g = TraceGuard("strict")
    fn = g.wrap_jit("f", lambda x: x * 2, expected_traces=1)
    fn(jnp.arange(4))
    with pytest.raises(TraceGuardError, match="traced 2 times"):
        fn(jnp.arange(8))


def test_trace_guard_flags_implicit_transfer():
    g = TraceGuard("count")
    jitted = g.wrap_jit("f", lambda x: x + 1, expected_traces=1)
    g.run("f", jitted, jnp.arange(4))       # warmup: unguarded
    # np argument -> implicit host-to-device transfer under the armed guard;
    # count mode records it and re-executes unguarded (same result)
    out = g.run("f", jitted, np.arange(4))
    np.testing.assert_array_equal(np.asarray(out), np.arange(4) + 1)
    assert g.counters["implicit_transfers"] == 1


def test_trace_guard_strict_raises_on_transfer():
    g = TraceGuard("strict")
    jitted = g.wrap_jit("f", lambda x: x + 1, expected_traces=2)
    g.run("f", jitted, jnp.arange(4))
    with pytest.raises(TraceGuardError, match="implicit"):
        g.run("f", jitted, np.arange(4))


def test_trace_guard_off_mode_is_plain_jit():
    g = TraceGuard("off")
    jitted = g.wrap_jit("f", lambda x: x + 1, expected_traces=1)
    g.run("f", jitted, jnp.arange(4))
    out = g.run("f", jitted, np.arange(4))  # never guarded
    np.testing.assert_array_equal(np.asarray(out), np.arange(4) + 1)
    assert g.counters["implicit_transfers"] == 0


def test_trace_guard_shares_engine_counters():
    shared = {"device_calls": 7}
    g = TraceGuard("count", counters=shared)
    assert shared["retraces"] == 0 and shared["implicit_transfers"] == 0
    assert shared["device_calls"] == 7      # untouched


def test_trace_guard_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown trace-guard mode"):
        TraceGuard("loose")
