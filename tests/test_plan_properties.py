"""Property-based tests for the CompressionPlan builders.

Runs under real hypothesis when installed, else the deterministic
``tests/_hypothesis_compat.py`` shim — same properties either way:

* ``for_target_ratio`` MEETS the requested ratio and never overshoots by
  more than one expert's bytes (the planner's decrement granularity);
* the planner is MONOTONE: asking for more compression never keeps more
  experts alive, globally or per layer;
* plan validation REJECTS out-of-range budgets, holes in the suffix, and
  unknown methods — for arbitrary bad inputs, not just the hand-picked
  cases in test_plan.py.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro import configs
from repro.core import plan as PLAN

CFG = configs.get("qwen3-moe-30b-a3b").reduced().replace(n_layers=4)
N = CFG.moe.n_experts
L = CFG.n_layers

# reachable target band for this config: ratios are drawn in tenths over
# (1.0, max_ratio at split=1) and clamped to the drawn split's own ceiling
# so every example is plannable by construction
_MAX_RATIO = PLAN.plan_live_ratio(
    CFG, PLAN.uniform(CFG, merged_experts=1, split=1))
_TENTHS = st.integers(min_value=11, max_value=int(_MAX_RATIO * 10) - 1)


def _reachable(target: float, split: int) -> float:
    ceil = PLAN.plan_live_ratio(
        CFG, PLAN.uniform(CFG, merged_experts=1, split=split))
    return min(target, ceil - 1e-9)


@settings(max_examples=25, deadline=None)
@given(_TENTHS, st.integers(min_value=1, max_value=L - 1))
def test_for_target_ratio_lands_within_tolerance(tenths, split):
    target = _reachable(tenths / 10.0, split)
    plan = PLAN.for_target_ratio(CFG, target_ratio=target, split=split)
    got = PLAN.plan_live_ratio(CFG, plan)
    assert got >= target                      # met ...
    # ... and not overshot by more than ONE expert's bytes (the greedy
    # planner's decrement granularity)
    total = CFG.param_count() * CFG.param_dtype.itemsize
    assert (total / target) - (total / got) <= PLAN.expert_bytes(CFG) + 1


@settings(max_examples=25, deadline=None)
@given(_TENTHS, _TENTHS, st.integers(min_value=1, max_value=L - 1))
def test_for_target_ratio_monotone_in_target(a, b, split):
    lo = _reachable(min(a, b) / 10.0, split)
    hi = _reachable(max(a, b) / 10.0, split)
    p_lo = PLAN.for_target_ratio(CFG, target_ratio=lo, split=split)
    p_hi = PLAN.for_target_ratio(CFG, target_ratio=hi, split=split)
    # more compression => no layer keeps MORE experts (the greedy order is
    # fixed, so the harder plan's allocation is a pointwise refinement)
    for m_lo, m_hi in zip(p_lo.merged_per_layer, p_hi.merged_per_layer):
        assert m_hi <= m_lo
    assert sum(p_hi.merged_per_layer) <= sum(p_lo.merged_per_layer)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=-5, max_value=3 * N),
       st.integers(min_value=0, max_value=L - 1))
def test_validation_rejects_out_of_range_budgets(m, split):
    specs = tuple(PLAN.LayerSpec(l, "mergemoe", m) for l in range(split, L))
    plan = PLAN.CompressionPlan(specs)
    if 1 <= m <= N:
        assert plan.validate(CFG) is plan
    else:
        with pytest.raises(ValueError, match="merged_experts"):
            plan.validate(CFG)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=L - 1),
                min_size=1, max_size=L))
def test_validation_accepts_exactly_contiguous_suffixes(layers):
    layer_set = sorted(set(layers))
    specs = tuple(PLAN.LayerSpec(l, "mergemoe", 2) for l in layer_set)
    plan = PLAN.CompressionPlan(specs)
    if layer_set == list(range(layer_set[0], L)):
        assert plan.validate(CFG) is plan
    else:                                     # hole, or suffix not reaching L
        with pytest.raises(ValueError, match="suffix"):
            plan.validate(CFG)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=N),
       st.sampled_from(PLAN.available_methods()),
       st.integers(min_value=0, max_value=L - 1))
def test_plan_json_roundtrip_property(m, method, split):
    plan = PLAN.uniform(CFG, method=method, merged_experts=m, split=split)
    again = PLAN.CompressionPlan.from_json(plan.to_json())
    assert again == plan
    # mesh provenance survives the roundtrip too
    annotated = plan.with_mesh({"data": 4, "model": 2})
    back = PLAN.CompressionPlan.from_json(annotated.to_json())
    assert back.mesh == (("data", 4), ("model", 2))
    assert back.specs == plan.specs
