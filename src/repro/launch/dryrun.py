import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).
os.environ.setdefault("REPRO_TPU_SEMANTICS", "1")   # lower bf16 dots, never executed

"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input-shape x mesh) cell without real hardware.

Per cell this driver:
  1. lowers + compiles the PRODUCTION form (scan-over-layers, full depth) on
     the requested mesh -> memory_analysis (fits?), collective schedule
     (while-trip-multiplied), compile wall time;
  2. lowers unrolled 1-layer / 2-layer PROBES -> exact per-layer FLOPs/bytes,
     extrapolated to full depth (XLA cost_analysis counts loop bodies once,
     so the scanned module alone under-reports — see hlo_analysis.py);
  3. emits a JSON record consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis as H
from repro.launch import input_specs as I
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import model as MD
from repro.optim import make_optimizer, default_optimizer_for

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _probe_layers(cfg):
    """(L1, L2, n_units): unrolled probe depths + number of repeating units."""
    if cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        return e, 2 * e, cfg.n_layers // e
    return 1, 2, cfg.n_layers


def _probe_cfg(cfg, n_layers):
    kw = dict(n_layers=n_layers, scan_layers=False, remat="none")
    if cfg.moe_merged:
        kw["moe_split"] = 0
    return cfg.replace(**kw)


def _build(cfg, kind, gb, seq, mesh, opt_name):
    """Returns (jitted_fn, arg_specs tuple) ready to .lower(*arg_specs)."""
    p_specs = I.params_specs(cfg)
    p_sh = SH.named(SH.params_pspecs(p_specs, mesh), mesh)
    if kind == "train":
        opt = make_optimizer(opt_name)
        o_specs = jax.eval_shape(opt.init, p_specs)
        o_sh = SH.named(SH.opt_pspecs(o_specs, mesh), mesh)
        b_specs = I.train_batch_specs(cfg, gb, seq)
        b_sh = SH.named(SH.batch_pspecs(b_specs, mesh), mesh)
        s_spec = jax.ShapeDtypeStruct((), jnp.int32)
        s_sh = NamedSharding(mesh, P())
        fn = ST.make_train_step(cfg, opt)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh, s_sh),
                      out_shardings=(p_sh, o_sh, s_sh, None),
                      donate_argnums=(0, 1))
        return jfn, (p_specs, o_specs, b_specs, s_spec)
    if kind == "prefill":
        b_specs = I.train_batch_specs(cfg, gb, seq)
        b_sh = SH.named(SH.batch_pspecs(b_specs, mesh), mesh)
        fn = ST.make_serve_prefill(cfg)
        cache_specs = jax.eval_shape(
            lambda p, b: fn(p, b)[1], p_specs, b_specs)
        c_sh = SH.named(SH.cache_pspecs(cache_specs, mesh), mesh)
        l_sh = NamedSharding(mesh, SH.logits_pspec(mesh, (gb, cfg.vocab_size)))
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                      out_shardings=(l_sh, c_sh))
        return jfn, (p_specs, b_specs)
    if kind == "decode":
        cache_specs, tok_spec = I.decode_specs(cfg, gb, seq)
        c_sh = SH.named(SH.cache_pspecs(cache_specs, mesh), mesh)
        t_sh = SH.named(SH.batch_pspecs(tok_spec, mesh), mesh)
        l_sh = NamedSharding(mesh, SH.logits_pspec(mesh, (gb, cfg.vocab_size)))
        fn = ST.make_serve_step(cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                      out_shardings=(l_sh, c_sh), donate_argnums=(1,))
        return jfn, (p_specs, cache_specs, tok_spec)
    raise ValueError(kind)


def _lower_compile(cfg, kind, gb, seq, mesh, opt_name, dump_dir=None):
    jfn, arg_specs = _build(cfg, kind, gb, seq, mesh, opt_name)
    t0 = time.perf_counter()
    lowered = jfn.lower(*arg_specs)
    t_lower = time.perf_counter() - t0
    opts = None
    if dump_dir is not None:
        opts = {"xla_dump_to": str(dump_dir),
                "xla_dump_hlo_pass_re": "spmd-partitioning"}
    t0 = time.perf_counter()
    compiled = lowered.compile(compiler_options=opts) if opts else lowered.compile()
    t_compile = time.perf_counter() - t0
    return lowered, compiled, t_lower, t_compile


def _read_spmd_dump(dump_dir) -> str:
    """Pick the post-SPMD, pre-legalization HLO snapshot — TRUE dtypes (the
    CPU backend later rewrites bf16 dots/collectives to f32, which would
    double every byte count)."""
    cands = sorted(Path(dump_dir).glob("*after_spmd-partitioning*.txt"))
    if not cands:
        raise FileNotFoundError(f"no post-SPMD dump in {dump_dir}")
    return max(cands, key=lambda p: p.stat().st_size).read_text()


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cfg_override=None, tag: str = "", opt_override=None,
             skip_probes: bool = True) -> dict:
    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    sh = configs.SHAPES[shape_name]
    kind, gb, seq = sh["kind"], sh["global_batch"], sh["seq_len"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_devices(mesh)
    opt_name = opt_override or default_optimizer_for(cfg.param_count())

    from repro.models.numerics import set_activation_mesh
    profile = SH.profile_for(cfg, mesh, gb)
    SH.set_profile(profile)
    if profile == "dp_only":
        set_activation_mesh(mesh, dp=tuple(mesh.axis_names), m=None)
    else:
        set_activation_mesh(mesh)
    rec_profile = profile

    rec = {"arch": arch, "shape": shape_name, "kind": kind,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "global_batch": gb, "seq_len": seq, "optimizer": opt_name,
           "profile": rec_profile, "tag": tag, "ok": False}

    try:
        # ---- production form: compile + memory + full HLO analysis
        import shutil
        import tempfile
        dump_dir = Path(tempfile.mkdtemp(prefix="spmd_dump_"))
        lowered, compiled, t_lo, t_co = _lower_compile(
            cfg, kind, gb, seq, mesh, opt_name, dump_dir=dump_dir)
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = _read_spmd_dump(dump_dir)   # true-dtype post-SPMD module
        shutil.rmtree(dump_dir, ignore_errors=True)
        an = H.analyze_module(hlo)        # FLOPs + HBM traffic (true dtypes)
        # collectives from the FINAL schedule (post AR-folding/RS-creation),
        # byte sizes dtype-corrected against the dump
        coll = H.analyze_collectives(
            compiled.as_text(), H._collective_dtype_reference(hlo))
        an.coll_bytes = coll.coll_bytes
        an.coll_by_kind = coll.coll_by_kind
        an.coll_count = coll.coll_count
        rec.update({
            "t_lower_s": round(t_lo, 2), "t_compile_s": round(t_co, 2),
            "mem_per_dev": {
                "arguments": int(ma.argument_size_in_bytes),
                "output": int(ma.output_size_in_bytes),
                "temp": int(ma.temp_size_in_bytes),
                "peak": int(ma.peak_memory_in_bytes),
            },
            "cost_analysis_raw": {k: float(ca.get(k, 0.0))
                                  for k in ("flops", "bytes accessed")},
            "per_dev": {
                "flops": an.dot_flops,
                "hbm_bytes": an.traffic_bytes,
                "hbm_bytes_flash": an.traffic_bytes_flash,
                "sdpa_bytes": an.sdpa_traffic_bytes,
                "coll_bytes": an.coll_bytes,
                "dot_count": an.dot_count,
            },
            "collectives_per_dev": {
                "total_bytes": an.coll_bytes,
                "by_kind_bytes": an.coll_by_kind,
                "by_kind_count": an.coll_count,
            },
        })
        rec["roofline"] = H.roofline_terms(an.dot_flops, an.traffic_bytes,
                                           an.coll_bytes)
        # useful-FLOPs accounting: 6ND (train) / 2ND (inference)
        n_active = cfg.param_count(active_only=True)
        tokens = gb * seq if kind != "decode" else gb
        mult = {"train": 6, "prefill": 2, "decode": 2}[kind]
        model_flops = mult * n_active * tokens
        rec["model_flops_global"] = float(model_flops)
        rec["hlo_flops_global"] = an.dot_flops * chips
        rec["useful_flops_ratio"] = (
            float(model_flops) / max(an.dot_flops * chips, 1.0))
        rec["ok"] = True
        del lowered, compiled
    finally:
        set_activation_mesh(None)
        SH.set_profile("2d")
    return rec


def run_serve_cell(arch: str, mesh_spec: str, *, n_slots: int = 128,
                   s_max: int = 32_768, combine_wire_dtype: str = "fp32",
                   cfg_override=None, tag: str = "") -> dict:
    """Serve-mode (decode-shaped) dry-run on an ABSTRACT mesh.

    Unlike the train/prefill/decode cells above, this does not compile:
    1T-class serving programs are proven coherent by ``jax.eval_shape`` of
    the expert-parallel slot-decode program (``ST.make_slot_decode_mesh``)
    over an ``AbstractMesh`` — the same shard_map the real engine jits,
    with expert tables partitioned on "model" and slots/KV on "data" —
    and the performance surface comes from the ANALYTIC traffic model
    (``H.decode_traffic_model`` at the mesh's EP/DP degrees), including
    the all-to-all interconnect bytes that only exist on a mesh
    (DESIGN.md §13). Works for dense configs too (no MoE ⇒ no a2a term ⇒
    the record shows interconnect 0 by construction, not by omission)."""
    import dataclasses

    from repro.launch.mesh import make_abstract_mesh, parse_mesh_spec

    cfg = cfg_override if cfg_override is not None else configs.get(arch)
    shape, axes = parse_mesh_spec(mesh_spec)
    amesh = make_abstract_mesh(shape, axes)
    ep = int(dict(zip(axes, shape)).get("model", 1))
    dp = int(dict(zip(axes, shape)).get("data", 1))
    if n_slots % max(dp, 1):
        raise ValueError(f"n_slots={n_slots} must divide over data={dp}")
    if cfg.moe is not None:
        # the engine's serving dispatch: EP engages on the gather path
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, dispatch="gather",
            gather_max_tokens=max(cfg.moe.gather_max_tokens, n_slots)))

    rec = {"arch": arch, "kind": "serve", "mesh": mesh_spec,
           "chips": int(np.prod(shape)), "ep_degree": ep, "dp_degree": dp,
           "n_slots": n_slots, "s_max": s_max,
           "combine_wire_dtype": combine_wire_dtype, "tag": tag,
           "ok": False}

    p_specs = I.params_specs(cfg)
    if ep > 1 and cfg.moe is not None:
        SH.validate_ep_params(p_specs, amesh)
    cache_specs = jax.eval_shape(
        lambda: MD.init_slot_cache(cfg, n_slots, s_max))

    from repro.models.numerics import set_activation_mesh
    set_activation_mesh(None)   # shard_map body: no sharding constraints
    t0 = time.perf_counter()
    fn = ST.make_slot_decode_mesh(cfg, amesh, p_specs, cache_specs,
                                  combine_wire_dtype=combine_wire_dtype)
    tok = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    flag = jax.ShapeDtypeStruct((n_slots,), jnp.bool_)
    logits, aux, out_cache = jax.eval_shape(
        fn, p_specs, cache_specs, tok, flag, flag)
    rec["t_trace_s"] = round(time.perf_counter() - t0, 2)
    assert logits.shape == (n_slots, cfg.vocab_size), logits.shape
    rec["logits_shape"] = list(logits.shape)

    # per-device parameter bytes under the serving partition (expert tables
    # /ep on "model", everything else replicated — the honest "fits?" term)
    pspecs = SH.serve_param_pspecs(p_specs, amesh)
    sizes = dict(zip(axes, shape))
    param_b = 0.0
    for leaf, spec in zip(jax.tree.leaves(p_specs), jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P))):
        div = 1
        for entry in spec:
            for ax in ((entry,) if isinstance(entry, str) else entry or ()):
                div *= sizes.get(ax, 1)
        param_b += leaf.size * leaf.dtype.itemsize / div
    kv_b = sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(cache_specs)) / max(dp, 1)
    rec["mem_per_dev"] = {"params": int(param_b), "kv_cache": int(kv_b)}

    # interconnect-aware modeled decode traffic at this mesh (mid-cache)
    traffic = H.decode_traffic_model(
        cfg, n_slots=n_slots, pos=s_max // 2, ep_degree=ep, dp_degree=dp,
        combine_wire_dtype=combine_wire_dtype)
    rec["modeled_traffic"] = traffic
    rec["roofline"] = H.roofline_terms(
        traffic["flops_per_token"], traffic["bytes_per_token"],
        traffic["interconnect_bytes_per_token"])
    rec["ok"] = True
    return rec


def all_cells():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in configs.SHAPES:
            yield arch, shape, configs.shape_applicable(cfg, shape)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-probes", action="store_true",
                    help="compile proof + memory only (multi-pod pass)")
    ap.add_argument("--compressed", default="",
                    help="M[:split] — dry-run the MergeMoE-compressed "
                         "variant (M merged experts in layers [split, L))")
    ap.add_argument("--serve", action="store_true",
                    help="serve-mode (decode-shaped) dry-run: eval_shape "
                         "the EP slot-decode program on an AbstractMesh "
                         "given by --mesh, emit modeled traffic")
    ap.add_argument("--mesh", default="data=16,model=16",
                    help="serve-mode mesh spec (parse_mesh_spec form)")
    ap.add_argument("--slots", type=int, default=128)
    ap.add_argument("--s-max", type=int, default=32_768)
    ap.add_argument("--wire", default="fp32", choices=("fp32", "int8"))
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.serve:
        cfg_override, comp_tag = None, ""
        if args.compressed:
            parts = args.compressed.split(":")
            merged = int(parts[0])
            split = int(parts[1]) if len(parts) > 1 else 0
            cfg_override = configs.get(args.arch).compressed(merged, split)
            comp_tag = f"__compressed{merged}"
        mesh_tag = args.mesh.replace("=", "").replace(",", "_")
        wire_tag = "" if args.wire == "fp32" else f"_{args.wire}"
        name = (f"{configs.canonical(args.arch)}__serve_{mesh_tag}"
                f"{wire_tag}{comp_tag}")
        path = out_dir / f"{name}.json"
        print(f"[run ] {name}", flush=True)
        t0 = time.perf_counter()
        try:
            rec = run_serve_cell(
                args.arch, args.mesh, n_slots=args.slots, s_max=args.s_max,
                combine_wire_dtype=args.wire, cfg_override=cfg_override,
                tag=comp_tag.strip("_"))
            rec["t_total_s"] = round(time.perf_counter() - t0, 1)
            path.write_text(json.dumps(rec, indent=1))
            t = rec["modeled_traffic"]
            print(f"[ ok ] {name}: params/dev="
                  f"{rec['mem_per_dev']['params']/2**30:.2f}GiB "
                  f"expert_red={t['expert_stream_reduction']:.1f}x "
                  f"ici/tok={t['interconnect_bytes_per_token']:.3e}B "
                  f"({rec['t_total_s']}s)", flush=True)
        except Exception as e:
            rec = {"arch": args.arch, "kind": "serve", "mesh": args.mesh,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[FAIL] {name}: {type(e).__name__}: {str(e)[:200]}",
                  flush=True)
        return

    cells = []
    if args.all:
        for arch, shape, applicable in all_cells():
            cells.append((arch, shape, applicable))
    else:
        cfg = configs.get(args.arch)
        cells.append((args.arch, args.shape,
                      configs.shape_applicable(cfg, args.shape)))

    cfg_override, comp_tag = None, ""
    if args.compressed:
        parts = args.compressed.split(":")
        merged = int(parts[0])
        split = int(parts[1]) if len(parts) > 1 else 0
        cfg_override = configs.get(args.arch).compressed(merged, split)
        comp_tag = f"__compressed{merged}"

    for arch, shape, applicable in cells:
        mesh_tag = "multipod" if args.multi_pod else "pod"
        name = f"{configs.canonical(arch)}__{shape}__{mesh_tag}{comp_tag}"
        path = out_dir / f"{name}.json"
        if not applicable:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "skipped": "long_500k needs sub-quadratic attention; "
                              "this arch is pure full-attention (DESIGN.md §5)"}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[skip] {name}")
            continue
        print(f"[run ] {name}", flush=True)
        t0 = time.perf_counter()
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           cfg_override=cfg_override, tag=comp_tag.strip("_"),
                           skip_probes=args.skip_probes)
            rec["t_total_s"] = round(time.perf_counter() - t0, 1)
            path.write_text(json.dumps(rec, indent=1))
            r = rec.get("roofline", {})
            print(f"[ ok ] {name}: peak/dev="
                  f"{rec['mem_per_dev']['peak']/2**30:.2f}GiB "
                  f"dominant={r.get('dominant','-')} "
                  f"({rec['t_total_s']}s)", flush=True)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[FAIL] {name}: {type(e).__name__}: {str(e)[:200]}",
                  flush=True)


if __name__ == "__main__":
    main()
