"""Layer stacks: decoder-only (dense/MoE/VLM), hybrid (Mamba2 + shared attn),
and encoder-decoder (whisper-style). All homogeneous stacks run under
``jax.lax.scan`` over stacked layer params so HLO size / compile time stay
bounded at 512 simulated devices; ``cfg.remat`` optionally rematerializes each
block.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.numerics import constrain, bf16_cotangent
from repro.models import layers as L
from repro.models import moe as M
from repro.models import mamba as S

F32 = jnp.float32


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _stack_init(init_fn, n: int, key):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# decoder-only block (dense MLP or MoE)
# ---------------------------------------------------------------------------

def block_init(cfg: ModelConfig, key, n_real: int | None = None) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(cfg, k1),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if cfg.moe is not None:
        p["moe"] = M.moe_init(cfg, k2, n_real=n_real)
    else:
        p["mlp"] = L.mlp_init(cfg.d_model, cfg.d_ff, cfg.param_dtype, k2)
    return p


def block_apply(cfg: ModelConfig, p: dict, x, *, inv_freq, positions=None,
                causal=True, capture=False):
    """Returns (y, aux_loss, capture_tuple_or_None).

    Sub-block outputs are constrained to the sequence-parallel layout BEFORE
    the residual add so the row-parallel projections' partial sums lower to
    reduce-scatter (not all-reduce + slice) — Megatron-SP."""
    a = L.attn_apply(cfg, p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                     inv_freq=inv_freq, positions=positions, causal=causal)
    h = x + constrain(a, "DP", "M", None)
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if cfg.moe is not None:
        out = M.moe_apply(cfg, p["moe"], hn, capture=capture)
        cap = (out.expert_inputs, out.usage_counts) if capture else None
        return h + constrain(out.y, "DP", "M", None), out.aux_loss, cap
    return h + constrain(L.mlp_apply(p["mlp"], hn), "DP", "M", None), \
        jnp.zeros((), F32), None


def stack_init(cfg: ModelConfig, key, n_layers: int | None = None,
               n_real: int | None = None) -> dict:
    n = cfg.n_layers if n_layers is None else n_layers
    return _stack_init(lambda k: block_init(cfg, k, n_real=n_real), n, key)


def stack_apply(cfg: ModelConfig, stacked: dict, x, *, inv_freq,
                capture=False):
    """Scan the decoder-only stack. Returns (y, total_aux, captures)."""
    def body(carry, layer_p):
        h, aux = carry
        y, a, cap = block_apply(cfg, layer_p, h, inv_freq=inv_freq,
                                capture=capture)
        y = bf16_cotangent(constrain(y, "DP", "M", None))  # Megatron-SP residual
        return (y, aux + a), cap

    body = _maybe_remat(cfg, body)
    if cfg.scan_layers:
        (y, aux), caps = jax.lax.scan(body, (x, jnp.zeros((), F32)), stacked)
    else:
        caps_list, carry = [], (x, jnp.zeros((), F32))
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda a: a[i], stacked)
            carry, cap = body(carry, layer_p)
            caps_list.append(cap)
        y, aux = carry
        caps = (jax.tree.map(lambda *xs: jnp.stack(xs), *caps_list)
                if capture and cfg.moe is not None else None)
    return y, aux, caps


def stack_decode(cfg: ModelConfig, stacked: dict, x, cache_k, cache_v, pos,
                 *, inv_freq):
    """One-token decode through the scanned stack.

    cache_k/v: [L, B, S_max, nkv, hd]. Returns (y, new_k, new_v)."""
    def body(h, xs):
        layer_p, ck, cv = xs
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, ck, cv = L.attn_decode(cfg, layer_p["attn"], hn, ck, cv, pos,
                                  inv_freq=inv_freq)
        h = h + a
        hn = L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            # decode throws the aux loss away every step — skip it and the
            # full-probs softmax it retains (moe_apply need_aux=False)
            out = M.moe_apply(cfg, layer_p["moe"], hn, need_aux=False)
            h = h + out.y
        else:
            h = h + L.mlp_apply(layer_p["mlp"], hn)
        return h, (ck, cv)

    y, (nk, nv) = jax.lax.scan(body, x, (stacked, cache_k, cache_v))
    return y, nk, nv


def stack_decode_slots(cfg: ModelConfig, stacked: dict, x, cache_k, cache_v,
                       pos, *, inv_freq):
    """One-token decode with per-slot positions (continuous batching).

    cache_k/v: [L, B, S_max, nkv, hd]; pos: [B] int32 per-slot lengths.
    The MoE sub-block goes through ``moe_apply`` unchanged, so under
    ``dispatch='ragged'`` every decode step runs the grouped kernel over the
    B slot tokens. Returns (y, new_k, new_v)."""
    def body(h, xs):
        layer_p, ck, cv = xs
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, ck, cv = L.attn_decode_slots(cfg, layer_p["attn"], hn, ck, cv, pos,
                                        inv_freq=inv_freq)
        h = h + a
        hn = L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            out = M.moe_apply(cfg, layer_p["moe"], hn, need_aux=False)
            h = h + out.y
        else:
            h = h + L.mlp_apply(layer_p["mlp"], hn)
        return h, (ck, cv)

    y, (nk, nv) = jax.lax.scan(body, x, (stacked, cache_k, cache_v))
    return y, nk, nv


def stack_verify_slots(cfg: ModelConfig, stacked: dict, x, cache_k, cache_v,
                       pos, *, inv_freq):
    """T-token forward with per-slot positions (speculative verify).

    Same layer body as :func:`stack_decode_slots` but over T positions per
    slot via ``attn_verify_slots``; x: [B, T, d]. With T > 1 the MoE
    sub-block sees B*T tokens, so it always takes the grouped/ragged path —
    the T == 1 gather specialization never applies to a verify forward.
    Returns (y [B, T, d], new_k, new_v)."""
    def body(h, xs):
        layer_p, ck, cv = xs
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, ck, cv = L.attn_verify_slots(cfg, layer_p["attn"], hn, ck, cv, pos,
                                        inv_freq=inv_freq)
        h = h + a
        hn = L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            out = M.moe_apply(cfg, layer_p["moe"], hn, need_aux=False)
            h = h + out.y
        else:
            h = h + L.mlp_apply(layer_p["mlp"], hn)
        return h, (ck, cv)

    y, (nk, nv) = jax.lax.scan(body, x, (stacked, cache_k, cache_v))
    return y, nk, nv


def _paged_body(cfg: ModelConfig, attn_fn, tab, pos, inv_freq, quant: bool):
    """Layer body shared by the paged decode/verify stacks: same
    ln1 -> attn -> residual -> ln2 -> moe/mlp structure as the dense slot
    stacks, with the per-layer KV pool (and scales, when int8) threaded
    through the scan carry-out."""
    def body(h, xs):
        if quant:
            layer_p, kp, vp, ks, vs = xs
        else:
            (layer_p, kp, vp), ks, vs = xs, None, None
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, kp, vp, ks, vs = attn_fn(cfg, layer_p["attn"], hn, kp, vp, ks, vs,
                                    tab, pos, inv_freq=inv_freq)
        h = h + a
        hn = L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            out = M.moe_apply(cfg, layer_p["moe"], hn, need_aux=False)
            h = h + out.y
        else:
            h = h + L.mlp_apply(layer_p["mlp"], hn)
        return h, (kp, vp, ks, vs) if quant else (kp, vp)
    return body


def stack_decode_paged(cfg: ModelConfig, stacked: dict, x, kp, vp, ks, vs,
                       tab, pos, *, inv_freq):
    """One-token decode through the scanned stack over paged KV pools.

    kp/vp: [L, n_blocks, bs, nkv, hd]; ks/vs: [L, n_blocks, bs, nkv] fp32
    or None (bf16 pools); tab: [B, mb] int32 (shared by all layers — one
    allocator owns the block ids); pos: [B] int32.
    Returns (y, kp, vp, ks, vs)."""
    quant = ks is not None
    body = _paged_body(cfg, L.attn_decode_paged, tab, pos, inv_freq, quant)
    if quant:
        y, (nk, nv, nks, nvs) = jax.lax.scan(body, x, (stacked, kp, vp,
                                                       ks, vs))
        return y, nk, nv, nks, nvs
    y, (nk, nv) = jax.lax.scan(body, x, (stacked, kp, vp))
    return y, nk, nv, None, None


def stack_verify_paged(cfg: ModelConfig, stacked: dict, x, kp, vp, ks, vs,
                       tab, pos, *, inv_freq):
    """T-token forward over paged KV pools (speculative verify AND paged
    admission — see ``layers.attn_verify_paged``). x: [B, T, d].
    Returns (y [B, T, d], kp, vp, ks, vs)."""
    quant = ks is not None
    body = _paged_body(cfg, L.attn_verify_paged, tab, pos, inv_freq, quant)
    if quant:
        y, (nk, nv, nks, nvs) = jax.lax.scan(body, x, (stacked, kp, vp,
                                                       ks, vs))
        return y, nk, nv, nks, nvs
    y, (nk, nv) = jax.lax.scan(body, x, (stacked, kp, vp))
    return y, nk, nv, None, None


def stack_prefill(cfg: ModelConfig, stacked: dict, x, *, inv_freq):
    """Full-sequence forward that also emits per-layer (k, v) decode caches.
    Returns (y, cache_k [L,B,S,nkv,hd], cache_v)."""
    def body(carry, layer_p):
        h = carry
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, k, v = L.attn_prefill(cfg, layer_p["attn"], hn, inv_freq=inv_freq)
        h = h + a
        hn = L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            # stack_prefill only feeds serving caches (training runs
            # stack_apply), so the aux loss is never consumed here
            h = h + constrain(M.moe_apply(cfg, layer_p["moe"], hn,
                                          need_aux=False).y,
                              "DP", "M", None)
        else:
            h = h + constrain(L.mlp_apply(layer_p["mlp"], hn),
                              "DP", "M", None)
        return bf16_cotangent(constrain(h, "DP", "M", None)), (k, v)

    body = _maybe_remat(cfg, body)
    y, (ks, vs) = jax.lax.scan(body, x, stacked)
    return y, ks, vs


# ---------------------------------------------------------------------------
# hybrid stack (zamba2): mamba blocks + ONE shared attn+MLP block every k
# ---------------------------------------------------------------------------

def hybrid_init(cfg: ModelConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mamba_ln": _stack_init(
            lambda k: L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            cfg.n_layers, k1),
        "mamba": _stack_init(lambda k: S.mamba_init(cfg, k), cfg.n_layers, k1),
        "shared_ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "shared_attn": L.attn_init(cfg, k2),
        "shared_ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "shared_mlp": L.mlp_init(cfg.d_model, cfg.d_ff, cfg.param_dtype, k3),
    }


def _n_segments(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


def hybrid_apply(cfg: ModelConfig, p: dict, x, *, inv_freq):
    every = cfg.hybrid_attn_every
    nseg = _n_segments(cfg)

    def mamba_body(h, xs):
        ln, mp = xs
        h = h + S.mamba_apply(cfg, mp, L.rmsnorm(ln, h, cfg.norm_eps))
        return bf16_cotangent(constrain(h, "DP", "M", None)), None

    mamba_body = _maybe_remat(cfg, mamba_body)
    seg_params = jax.tree.map(
        lambda a: a.reshape((nseg, every) + a.shape[1:]), (p["mamba_ln"], p["mamba"]))

    for s_i in range(nseg):
        xs = jax.tree.map(lambda a: a[s_i], seg_params)
        x, _ = jax.lax.scan(mamba_body, x, xs)
        # shared transformer block (weights shared across segments)
        h = x + L.attn_apply(cfg, p["shared_attn"],
                             L.rmsnorm(p["shared_ln1"], x, cfg.norm_eps),
                             inv_freq=inv_freq)
        x = h + L.mlp_apply(p["shared_mlp"],
                            L.rmsnorm(p["shared_ln2"], h, cfg.norm_eps))
    return x


def hybrid_prefill(cfg: ModelConfig, p: dict, x, *, inv_freq):
    """Full-sequence forward emitting the decode cache (per-layer SSM states +
    per-segment shared-attn KV)."""
    every = cfg.hybrid_attn_every
    nseg = _n_segments(cfg)

    def mamba_body(h, xs):
        ln, mp = xs
        out, st = S.mamba_apply(cfg, mp, L.rmsnorm(ln, h, cfg.norm_eps),
                                return_state=True)
        return constrain(h + out, "DP", "M", None), st

    seg_params = jax.tree.map(
        lambda a: a.reshape((nseg, every) + a.shape[1:]),
        (p["mamba_ln"], p["mamba"]))

    ssm_states, ks, vs = [], [], []
    for s_i in range(nseg):
        xs = jax.tree.map(lambda a: a[s_i], seg_params)
        x, sts = jax.lax.scan(mamba_body, x, xs)
        ssm_states.append(sts)
        hn = L.rmsnorm(p["shared_ln1"], x, cfg.norm_eps)
        a, k, v = L.attn_prefill(cfg, p["shared_attn"], hn, inv_freq=inv_freq)
        x = x + a
        x = x + L.mlp_apply(p["shared_mlp"],
                            L.rmsnorm(p["shared_ln2"], x, cfg.norm_eps))
        ks.append(k)
        vs.append(v)
    cache = {
        "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *ssm_states),
        "k": jnp.stack(ks),
        "v": jnp.stack(vs),
    }
    return x, cache


def hybrid_decode(cfg: ModelConfig, p: dict, x, cache, pos, *, inv_freq):
    """cache: {"ssm": SSMState stacked [L,...], "k"/"v": [nseg, B, S, nkv, hd]}"""
    every = cfg.hybrid_attn_every
    nseg = _n_segments(cfg)
    new_ssm, new_k, new_v = [], [], []
    for s_i in range(nseg):
        for j in range(every):
            li = s_i * every + j
            ln = jax.tree.map(lambda a: a[li], p["mamba_ln"])
            mp = jax.tree.map(lambda a: a[li], p["mamba"])
            st = jax.tree.map(lambda a: a[li], cache["ssm"])
            out, st = S.mamba_decode(cfg, mp, L.rmsnorm(ln, x, cfg.norm_eps), st)
            x = x + out
            new_ssm.append(st)
        hn = L.rmsnorm(p["shared_ln1"], x, cfg.norm_eps)
        a, ck, cv = L.attn_decode(cfg, p["shared_attn"], hn,
                                  cache["k"][s_i], cache["v"][s_i], pos,
                                  inv_freq=inv_freq)
        x = x + a
        x = x + L.mlp_apply(p["shared_mlp"],
                            L.rmsnorm(p["shared_ln2"], x, cfg.norm_eps))
        new_k.append(ck)
        new_v.append(cv)
    new_cache = {
        "ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm),
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
    }
    return x, new_cache


# ---------------------------------------------------------------------------
# encoder-decoder (whisper-style)
# ---------------------------------------------------------------------------

def encdec_init(cfg: ModelConfig, key) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    def enc_block(k):
        ka, kb = jax.random.split(k)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "attn": L.attn_init(cfg, ka),
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": L.mlp_init(cfg.d_model, cfg.d_ff, cfg.param_dtype, kb),
        }

    def dec_block(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {
            "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "self_attn": L.attn_init(cfg, ka),
            "ln_x": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "cross_attn": L.attn_init(cfg, kb),
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mlp": L.mlp_init(cfg.d_model, cfg.d_ff, cfg.param_dtype, kc),
        }

    return {
        "enc": _stack_init(enc_block, cfg.n_layers, k1),
        "enc_ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "dec": _stack_init(dec_block, cfg.n_layers, k2),
    }


def _sinusoid(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=F32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=F32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(cfg: ModelConfig, p: dict, frames: jax.Array) -> jax.Array:
    """frames: [B, n_audio_ctx, d] precomputed frame embeddings (conv stub)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(h, layer_p):
        a = L.attn_apply(cfg, layer_p["attn"],
                         L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps),
                         inv_freq=None, causal=False)
        h = h + a
        h = h + L.mlp_apply(layer_p["mlp"],
                            L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps))
        return bf16_cotangent(constrain(h, "DP", "M", None)), None

    body = _maybe_remat(cfg, body)
    x, _ = jax.lax.scan(body, x, p["enc"])
    return L.rmsnorm(p["enc_ln"], x, cfg.norm_eps)


def decode_stack_apply(cfg: ModelConfig, p: dict, x, enc_out, *, inv_freq):
    def body(h, layer_p):
        a = L.attn_apply(cfg, layer_p["self_attn"],
                         L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps),
                         inv_freq=inv_freq, causal=True)
        h = h + a
        c = L.attn_apply(cfg, layer_p["cross_attn"],
                         L.rmsnorm(layer_p["ln_x"], h, cfg.norm_eps),
                         inv_freq=None, kv=enc_out)
        h = h + c
        h = h + L.mlp_apply(layer_p["mlp"],
                            L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps))
        return bf16_cotangent(constrain(h, "DP", "M", None)), None

    body = _maybe_remat(cfg, body)
    y, _ = jax.lax.scan(body, x, p["dec"])
    return y


def decode_stack_step(cfg: ModelConfig, p: dict, x, enc_out, cache_k, cache_v,
                      pos, *, inv_freq):
    def body(h, xs):
        layer_p, ck, cv = xs
        hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
        a, ck, cv = L.attn_decode(cfg, layer_p["self_attn"], hn, ck, cv, pos,
                                  inv_freq=inv_freq)
        h = h + a
        c = L.attn_apply(cfg, layer_p["cross_attn"],
                         L.rmsnorm(layer_p["ln_x"], h, cfg.norm_eps),
                         inv_freq=None, kv=enc_out)
        h = h + c
        h = h + L.mlp_apply(layer_p["mlp"],
                            L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps))
        return h, (ck, cv)

    y, (nk, nv) = jax.lax.scan(body, x, (p["dec"], cache_k, cache_v))
    return y, nk, nv
