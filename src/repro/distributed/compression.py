"""Distributed compression substrate.

Three pieces:

* ``ef_compressed(opt, bits=8)`` — optimizer wrapper implementing
  ERROR-FEEDBACK quantization: the gradient is quantized to int8 (per-leaf
  max-abs scaling, stochastic rounding via a deterministic hash of the step),
  the quantization residual is accumulated into an ``ef`` state and added
  back next step. The inner optimizer only ever sees dequantized gradients —
  exactly what crosses the wire in the compressed-collective deployment.

* ``compressed_psum(x, axis)`` — a shard_map-compatible int8 all-reduce:
  quantize -> psum int32 -> dequantize. Moves 4x fewer bytes on the mapped
  axis; used for the ``pod`` axis where DCN bandwidth, not ICI, is the
  bottleneck (EXPERIMENTS.md §Perf, multi-pod iteration).

* ``shard_layer_solves(thunks, n_shards)`` — the MergeMoE solve-stage
  executor: per-layer expert-merge solve closures are statically sharded
  over the mesh's expert-parallel axis ranks and the results all-gathered
  back in layer order (DESIGN.md §6). Solves are independent fp64 host
  computations over replicated calibration inputs, so the gathered result is
  bit-identical to the sequential loop for ANY shard count — the property
  ``tests/test_dist_compress.py`` enforces end to end.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, _diffable, _is_float0

F32 = jnp.float32
I8_MAX = 127.0


def quantize(g, key):
    """Per-tensor max-abs int8 quantization with stochastic rounding."""
    g = g.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / I8_MAX
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, F32) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -I8_MAX, I8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(F32) * scale


def ef_compressed(opt: Optimizer, seed: int = 0) -> Optimizer:
    """Wrap ``opt`` with int8 error-feedback gradient compression."""

    def init(params):
        inner = opt.init(params)
        ef = jax.tree.map(
            lambda p: jnp.zeros(p.shape, F32) if _diffable(p)
            else jnp.zeros((), F32), params)
        return {"inner": inner, "ef": ef}

    def update(grads, state, params, step):
        base = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        def compress(path_idx, g, r, p):
            if _is_float0(g) or not _diffable(p):
                return g, r
            key = jax.random.fold_in(base, path_idx)
            corrected = g.astype(F32) + r
            q, scale = quantize(corrected, key)
            deq = dequantize(q, scale)
            return deq, corrected - deq

        leaves_g, tdef = jax.tree_util.tree_flatten(grads)
        leaves_r = tdef.flatten_up_to(state["ef"])
        leaves_p = tdef.flatten_up_to(params)
        out = [compress(i, g, r, p) for i, (g, r, p)
               in enumerate(zip(leaves_g, leaves_r, leaves_p))]
        new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
        new_ef = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
        updates, inner = opt.update(new_g, state["inner"], params, step)
        return updates, {"inner": inner, "ef": new_ef}

    return Optimizer(init, update, state_factored=opt.state_factored)


def shard_layer_solves(thunks: Sequence[Callable[[], Any]], n_shards: int
                       ) -> Tuple[List[Any], Dict]:
    """Run the per-layer expert-merge solve closures across ``n_shards``
    worker shards; shard i owns the layers with ``index % n_shards == i``
    (static round-robin, mirroring how the expert axis stripes expert tables
    at serving time). Returns (results in layer order, stats).

    Shards are host threads: the solves are NumPy/LAPACK fp64 (DESIGN.md §2),
    which release the GIL inside BLAS, and every shard reads the same
    replicated calibration reservoir. Because each closure is a deterministic
    function of its (replicated) inputs and results are gathered by index —
    never by completion order — the output is bit-identical to running the
    loop sequentially, whatever ``n_shards`` is. On a multi-host fleet the
    same contract holds with processes instead of threads plus one
    all-gather of the merged tables.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    results: List[Any] = [None] * len(thunks)
    t_shard = [0.0] * n_shards
    errors: List[BaseException] = []

    def worker(rank: int) -> None:
        t0 = time.perf_counter()
        try:
            for i in range(rank, len(thunks), n_shards):
                results[i] = thunks[i]()
        except BaseException as e:        # re-raised on the caller thread
            errors.append(e)
        t_shard[rank] = time.perf_counter() - t0

    if n_shards == 1:
        worker(0)
    else:
        threads = [threading.Thread(target=worker, args=(r,), daemon=True)
                   for r in range(n_shards)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    if errors:
        raise errors[0]
    return results, {"n_shards": n_shards,
                     "t_shard_s": [round(t, 3) for t in t_shard]}


def compressed_psum(x: jax.Array, axis: str, key) -> jax.Array:
    """int8-over-the-wire psum for use inside shard_map. Each participant
    quantizes its contribution; the int32 sum is exact; dequantization uses
    the max scale (all-reduced, 4 bytes)."""
    g = x.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / I8_MAX
    scale = jax.lax.pmax(scale, axis)                 # shared scale
    noise = jax.random.uniform(key, g.shape, F32) - 0.5
    q = jnp.clip(jnp.round(g / scale + noise), -I8_MAX, I8_MAX
                 ).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    return total.astype(F32) * scale
