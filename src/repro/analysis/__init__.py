"""Static analysis for the repro tree (DESIGN.md §9).

Three legs: the AST hot-path linter (:mod:`repro.analysis.lint` +
:mod:`repro.analysis.rules`), the Pallas kernel contract checker
(:mod:`repro.analysis.kernel_contracts`), and the runtime retrace/transfer
guard (:mod:`repro.analysis.trace_guard`). ``python -m repro.analysis``
runs the first two and exits non-zero on any finding.
"""
from repro.analysis.lint import Finding, LintReport, run_lint
from repro.analysis.kernel_contracts import (ContractFinding, ContractReport,
                                             check_kernel_contracts)
from repro.analysis.trace_guard import TraceGuard, TraceGuardError

__all__ = [
    "Finding", "LintReport", "run_lint",
    "ContractFinding", "ContractReport", "check_kernel_contracts",
    "TraceGuard", "TraceGuardError",
]
