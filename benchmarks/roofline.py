"""Roofline table builder — reads experiments/dryrun/*.json and renders the
per-(arch x shape x mesh) three-term analysis (EXPERIMENTS.md §Roofline).

Terms (per device, TPU v5e constants from the assignment):
  t_compute    = MXU dot FLOPs / 197e12
  t_memory     = post-fusion HBM traffic / 819e9
  t_collective = ring-weighted collective bytes / 50e9

``--flash`` recomputes t_memory with the Pallas flash-attention kernel's
traffic model (materialized [B,H,S,S] buffers replaced by q/k/v/o reads).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.hlo_analysis import roofline_terms

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: str = "pod", tag: str = ""):
    recs = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}{tag}.json")):
        r = json.loads(p.read_text())
        recs.append(r)
    return recs


def row(rec, flash: bool = False):
    if rec.get("skipped"):
        return {"arch": rec["arch"], "shape": rec["shape"], "skip": True}
    pd = rec["per_dev"]
    hbm = pd["hbm_bytes_flash"] if flash else pd["hbm_bytes"]
    terms = roofline_terms(pd["flops"], hbm, pd["coll_bytes"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "mesh": rec["mesh"],
        "flops": pd["flops"], "hbm": hbm, "coll": pd["coll_bytes"],
        "peak_gib": rec["mem_per_dev"]["peak"] / 2**30,
        "useful": rec.get("useful_flops_ratio", 0.0),
        **terms,
    }


def render(rows, title):
    out = [f"### {title}", ""]
    hdr = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant | "
           "roofline frac | 6ND/HLO | peak GiB/dev |")
    out += [hdr, "|" + "---|" * 9]
    for r in rows:
        if r.get("skip"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP (full attention, DESIGN.md §5) | — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful']:.2f} | {r['peak_gib']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = [row(r, flash=args.flash) for r in load_records(args.mesh)]
    if args.markdown:
        print(render(rows, f"Roofline — {args.mesh} "
                           f"({'flash-adjusted' if args.flash else 'XLA sdpa'})"))
        return
    for r in rows:
        if r.get("skip"):
            print(f"{r['arch']:20s} {r['shape']:12s} SKIP")
        else:
            print(f"{r['arch']:20s} {r['shape']:12s} dom={r['dominant']:10s} "
                  f"frac={r['roofline_fraction']:.3f} "
                  f"tc={r['t_compute_s']:.3f} tm={r['t_memory_s']:.3f} "
                  f"tx={r['t_collective_s']:.3f} useful={r['useful']:.2f}")


if __name__ == "__main__":
    main()
