"""Fault-tolerant checkpointing: atomic, step-tagged, keep-N, async-capable,
and ELASTIC (restore onto a different mesh than the one that saved).

Layout:
    <dir>/step_00000420.tmp/...      (in-flight write)
    <dir>/step_00000420/             (atomic rename on completion)
        meta.json                    (tree structure, shapes, dtypes, extras)
        leaf_00000.npy ...           (one file per leaf, logical full array)
        COMMIT                       (terminal marker — restarts ignore any
                                      step directory without it)

Leaves are written as FULL logical arrays (device_get gathers shards), so a
relaunch may re-shard onto any mesh: ``load(..., shardings=...)`` device_puts
each leaf with the new NamedSharding. On a multi-host fleet the same format
generalizes to per-host index-range files; meta.json already records the
global shape per leaf.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_BF16 = "bfloat16"


def _flatten(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def tree_digest(tree) -> str:
    """sha256 over every leaf's path + raw bytes — the content identity the
    mesh bit-for-bit differential compares across device counts
    (DESIGN.md §6; used by tests/_dist_compress_child.py and
    benchmarks/compress_bench.py)."""
    import hashlib
    h = hashlib.sha256()
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        h.update(str(path).encode())
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


def save(directory, step: int, tree, extras: Optional[Dict] = None,
         keep: int = 3) -> Path:
    """Synchronous atomic save. Returns the committed directory."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    leaves, treedef = _flatten(tree)
    meta = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
        "leaves": [],
        "extras": extras or {},
        # content identity recorded at save time; load() recomputes it over
        # the restored tree and refuses corrupted artifacts (DESIGN.md §12)
        "tree_digest": tree_digest(tree),
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(leaf.dtype)
        if dtype == _BF16:                       # npy can't store bf16
            arr = arr.astype(np.float32)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        meta["leaves"].append({"dtype": dtype, "shape": list(arr.shape)})
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int) -> None:
    steps = sorted(d for d in directory.glob("step_????????")
                   if (d / "COMMIT").exists())
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(d, ignore_errors=True)
    for d in directory.glob("step_*.tmp"):       # orphaned partial writes
        if not (d / "COMMIT").exists():
            shutil.rmtree(d, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in directory.glob("step_????????")
             if (d / "COMMIT").exists()]
    return max(steps) if steps else None


def load(directory, step: Optional[int] = None, shardings=None,
         verify: bool = True) -> Tuple[Any, Dict]:
    """Restore (tree, extras). ``shardings``: optional pytree of NamedSharding
    (same structure) — enables elastic restore onto a NEW mesh.

    ``verify=True`` recomputes :func:`tree_digest` over the restored tree
    and raises :class:`repro.core.errors.ArtifactCorruptError` when it does
    not match the digest recorded in ``meta.json`` at save time (bit-flipped
    leaf files, truncated writes that still committed, tampering).
    Checkpoints written before digests existed skip the check. Pass
    ``verify=False`` to load a corrupted artifact for forensics."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    from jax.tree_util import PyTreeDef, default_registry
    treedef = PyTreeDef.deserialize_using_proto(
        default_registry, bytes.fromhex(meta["treedef"]))

    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else None)
    leaves = []
    for i, info in enumerate(meta["leaves"]):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        if info["dtype"] == _BF16:
            import jax.numpy as jnp
            arr = jnp.asarray(arr, jnp.bfloat16)
        if sh_leaves is not None:
            leaves.append(jax.device_put(arr, sh_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr) if not hasattr(arr, "devices")
                          else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    want = meta.get("tree_digest")
    if verify and want is not None:
        got = tree_digest(tree)
        if got != want:
            from repro.core.errors import ArtifactCorruptError
            raise ArtifactCorruptError(
                f"checkpoint {d} failed digest verification: meta.json "
                f"records {want[:16]}… but the restored tree hashes to "
                f"{got[:16]}… — the artifact bytes were corrupted after "
                f"save. Pass verify=False to load anyway (forensics only).")
    return tree, meta.get("extras", {})


# ---------------------------------------------------------------------------
# compressed artifacts: plan + report + config ride in meta.json extras
# ---------------------------------------------------------------------------

_EXPERT_TABLES = ("wg", "wu", "wd")


def _pack_stacked(stacked, live):
    """[L_c, M_max, ...] -> {layer_i: [live_i, ...]} (expert axis sliced)."""
    return {f"layer_{i:03d}": stacked[i, :live[i]]
            for i in range(stacked.shape[0])}


def _unpack_stacked(layers, M):
    """Inverse of :func:`_pack_stacked`: zero-pad each layer back to ``M``
    rows and restack (pad rows were zeros by construction — for int8 tables
    both the values and the scales pad with exact zeros, DESIGN.md §8)."""
    import jax.numpy as jnp
    out = []
    for i in range(len(layers)):
        a = layers[f"layer_{i:03d}"]
        pad = M - a.shape[0]
        if pad:
            a = jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
        out.append(a)
    return jnp.stack(out)


def _pack_ragged_suffix(cfg, params):
    """Store heterogeneous suffix expert tables UNPADDED: each stacked
    ``[L_c, M_max, ...]`` leaf becomes one per-layer leaf sliced to that
    layer's live count, so the artifact's bytes match the plan's budget
    rather than the in-memory max-M padding. Quantized suffixes
    (``moe["qexp"]``, DESIGN.md §8) pack all six int8/scale leaves the same
    way — the scale rows share the expert axis; bf16 checkpoints are
    untouched by the quantized branch."""
    if cfg.moe_merged_layers is None:
        return params
    live = cfg.live_experts_per_suffix_layer()
    moe = dict(params["stack_c"]["moe"])
    if "qexp" in moe:
        moe["qexp"] = {k: _pack_stacked(v, live)
                       for k, v in moe["qexp"].items()}
    else:
        for key in _EXPERT_TABLES:
            moe[key] = _pack_stacked(moe[key], live)
    return {**params, "stack_c": {**params["stack_c"], "moe": moe}}


def _unpack_ragged_suffix(cfg, tree):
    """Inverse of :func:`_pack_ragged_suffix`: zero-pad each layer back to
    ``cfg.moe_merged`` rows and restack (exactly reproducing the in-memory
    padded tables)."""
    if cfg.moe_merged_layers is None:
        return tree
    M = cfg.moe_merged
    moe = dict(tree["stack_c"]["moe"])
    if "qexp" in moe:
        moe["qexp"] = {k: _unpack_stacked(v, M)
                       for k, v in moe["qexp"].items()}
    else:
        for key in _EXPERT_TABLES:
            moe[key] = _unpack_stacked(moe[key], M)
    return {**tree, "stack_c": {**tree["stack_c"], "moe": moe}}


def save_compressed(directory, cfg, params, plan=None, report=None,
                    step: int = 0, keep: int = 0) -> Path:
    """Persist a MergeMoE-compressed model as a loadable artifact.

    The parameter tree is written through :func:`save`; the ``ModelConfig``,
    the executed :class:`~repro.core.plan.CompressionPlan` and the
    compression report travel in ``meta.json`` extras, so
    :func:`load_compressed` (and ``Engine.from_checkpoint``) can rebuild the
    model with zero out-of-band information. ``keep=0`` disables GC —
    artifacts are not a rolling train-checkpoint window."""
    if not cfg.moe_merged:
        raise ValueError(
            f"{cfg.name} is not compressed; save_compressed stores MergeMoE "
            "artifacts — use save() for training checkpoints")
    plan_dict = None
    if plan is not None:
        plan_dict = plan if isinstance(plan, dict) else plan.to_json_dict()
    # mesh provenance: which device mesh produced this artifact. Execution is
    # bit-for-bit across meshes (DESIGN.md §6), so this is a provenance
    # record, not a loading constraint — load_compressed ignores it. One
    # schema regardless of source: {"axes": {...}, ...} (the plan's record is
    # a flat axis dict and gets wrapped).
    mesh_meta = (report or {}).get("mesh")
    if mesh_meta is None and plan_dict is not None \
            and plan_dict.get("mesh") is not None:
        mesh_meta = {"axes": plan_dict["mesh"]}
    extras = {"compressed": {
        "format": 1,
        "config": cfg.to_json_dict(),
        "plan": plan_dict,
        "report": report or {},
        "mesh": mesh_meta,
    }}
    return save(directory, step, _pack_ragged_suffix(cfg, params),
                extras=extras, keep=keep)


def load_compressed(directory, step: Optional[int] = None,
                    verify: bool = True):
    """Restore (cfg, params, artifact) from a :func:`save_compressed`
    directory. ``artifact`` is the extras dict ({"config", "plan",
    "report"}); params come back padded/stacked, ready for the forward.

    No ``shardings`` passthrough: the on-disk tree of a heterogeneous
    artifact is the packed per-layer layout, which cannot pair with
    shardings built for the padded/stacked model tree — re-shard the
    returned params with ``jax.device_put`` instead."""
    from repro.models.config import config_from_dict
    tree, extras = load(directory, step, verify=verify)
    art = extras.get("compressed")
    if art is None:
        raise ValueError(
            f"{directory} holds a plain checkpoint, not a compressed "
            "artifact (no 'compressed' extras); use load()")
    cfg = config_from_dict(art["config"])
    return cfg, _unpack_ragged_suffix(cfg, tree), art


class CheckpointManager:
    """Keep-N manager with optional ASYNC saves (device_get on the caller
    thread — cheap snapshot — then file I/O on a worker thread, so the train
    loop never blocks on disk)."""

    def __init__(self, directory, keep: int = 3, async_save: bool = False):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extras: Optional[Dict] = None) -> None:
        self.wait()
        if not self.async_save:
            save(self.directory, step, tree, extras, self.keep)
            return
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        dtypes = jax.tree.map(lambda x: str(x.dtype), tree)

        def work():
            try:
                restored = jax.tree.map(
                    lambda a, dt: a if dt != _BF16 else a, snapshot, dtypes)
                save(self.directory, step, restored, extras, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, step: Optional[int] = None, shardings=None):
        self.wait()
        return load(self.directory, step, shardings)
