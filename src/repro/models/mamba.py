"""Mamba2 (State Space Duality) block — chunked prefill + O(1)-state decode.

Follows the SSD formulation (arXiv:2405.21060): within-chunk quadratic
(attention-like) term + cross-chunk linear recurrence carried by lax.scan.
All state math in fp32; projections in model dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.numerics import ein, dot as _ndot

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init

F32 = jnp.float32


class SSMState(NamedTuple):
    ssm: jax.Array    # [B, H, hd, d_state] fp32
    conv: jax.Array   # [B, conv_width-1, conv_channels]


def mamba_init(cfg: ModelConfig, key) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    conv_ch = di + 2 * gn
    dt = cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1]
    u = jax.random.uniform(k3, (nh,), F32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": _dense_init(k1, (d, 2 * di + 2 * gn + nh), dt),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_ch), F32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=F32)),
        "D": jnp.ones((nh,), F32),
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": _dense_init(k1, (di, d), dt),
    }


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xBC: [B, S, C]; w: [W, C]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=F32)
    for i in range(W):
        out = out + pad[:, i:i + xBC.shape[1], :].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out + b.astype(F32)).astype(xBC.dtype)


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < m <= i} x[..., m]."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD scan.

    x:  [b, s, h, p]   (dt-weighted inputs applied inside)
    dt: [b, s, h]      (post-softplus)
    A:  [h]            (negative reals)
    Bm, Cm: [b, s, g, n]; heads are grouped g -> h//g heads per group.
    Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    nc = s // chunk
    # reshape to chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, g, n)
    Cc = Cm.reshape(b, nc, chunk, g, n)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)                  # [b,nc,l,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc.astype(F32) * A                          # [b,nc,l,h], negative
    dA_cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum

    # ---- intra-chunk (quadratic) term
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, -1)))     # [b,nc,h,l,l]
    att = ein("bclhn,bcmhn,bchlm->bchlm", Ch.astype(F32), Bh.astype(F32), L)
    xdt = xc.astype(F32) * dtc[..., None].astype(F32)  # dt-weighted input
    y_intra = ein("bchlm,bcmhp->bclhp", att, xdt)

    # ---- per-chunk final states
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # [b,nc,l,h]
    states = ein("bclhn,bclh,bclhp->bchpn",
                        Bh.astype(F32), decay_to_end * dtc.astype(F32),
                        xc.astype(F32))                      # [b,nc,h,p,n]

    # ---- inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # [b,nc,h]
    s0 = (jnp.zeros((b, h, p, n), F32) if init_state is None
          else init_state.astype(F32))

    def step(carry, inp):
        st_c, dec_c = inp                     # [b,h,p,n], [b,h]
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev                       # emit state BEFORE this chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # [b,nc,h,p,n]

    # ---- inter-chunk contribution
    in_decay = jnp.exp(dA_cum)                               # decay from chunk start
    y_inter = ein("bclhn,bclh,bchpn->bclhp",
                         Ch.astype(F32), in_decay, prev_states)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def mamba_apply(cfg: ModelConfig, p: dict, x: jax.Array,
                init_state: SSMState | None = None,
                return_state: bool = False):
    """Full-sequence forward. x: [B, S, d]."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.d_state
    B_, S, _ = x.shape

    zxbcdt = ein("bsd,dk->bsk", x, p["in_proj"]).astype(x.dtype)
    z, xin, BC, dt_raw = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * gn], axis=-1)
    xBC = jnp.concatenate([xin, BC], axis=-1)
    if init_state is not None:
        full = jnp.concatenate([init_state.conv.astype(xBC.dtype), xBC], axis=1)
        xBC = _causal_conv(full, p["conv_w"], p["conv_b"])[:, s.conv_width - 1:]
    else:
        xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xin, Bm, Cm = jnp.split(xBC, [di, di + gn], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"])          # [B,S,nh]
    A = -jnp.exp(p["A_log"])                                         # [nh]
    xh = xin.reshape(B_, S, nh, s.head_dim)
    Bm = Bm.reshape(B_, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(B_, S, s.n_groups, s.d_state)

    # pad sequence to a chunk multiple
    pad = (-S) % s.chunk_size
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    y, fin = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk_size,
                          None if init_state is None else init_state.ssm)
    y = y[:, :S]
    y = y + xin.reshape(B_, S, nh, s.head_dim).astype(F32) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(x.dtype)

    # gated RMSNorm + out projection
    gated = y.astype(F32) * jax.nn.silu(z.astype(F32))
    var = jnp.mean(gated * gated, axis=-1, keepdims=True)
    gated = gated * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"].astype(F32)
    out = ein("bsk,kd->bsd", gated.astype(x.dtype), p["out_proj"]).astype(x.dtype)
    if return_state:
        # conv tail needs raw (pre-activation) xBC channels; recompute cheaply
        zxbcdt_tail = zxbcdt[:, -(s.conv_width - 1):]
        tail = jnp.concatenate(
            [zxbcdt_tail[..., di:2 * di], zxbcdt_tail[..., 2 * di:2 * di + 2 * gn]],
            axis=-1)
        return out, SSMState(ssm=fin, conv=tail)
    return out


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: SSMState):
    """Single-token decode. x: [B, 1, d]; state carries ssm + conv tails."""
    s = cfg.ssm
    d = cfg.d_model
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.d_state

    zxbcdt = ein("bsd,dk->bsk", x, p["in_proj"]).astype(x.dtype)
    z, xin_raw, BC_raw, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + 2 * gn], axis=-1)
    xBC_raw = jnp.concatenate([xin_raw, BC_raw], axis=-1)    # [B,1,C]

    # conv over (state.conv ++ new step)
    window = jnp.concatenate([state.conv.astype(xBC_raw.dtype), xBC_raw], axis=1)
    w, b = p["conv_w"], p["conv_b"]
    conv_out = ein("bwc,wc->bc", window.astype(F32), w.astype(F32))
    xBC = jax.nn.silu(conv_out + b.astype(F32)).astype(x.dtype)[:, None, :]
    new_conv = window[:, 1:]

    xin, Bm, Cm = jnp.split(xBC, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(F32) + p["dt_bias"])   # [B,nh]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                             # [B,nh]

    xh = xin.reshape(-1, nh, s.head_dim).astype(F32)                # [B,nh,hd]
    Bh = jnp.repeat(Bm.reshape(-1, s.n_groups, s.d_state),
                    nh // s.n_groups, axis=1).astype(F32)           # [B,nh,n]
    Ch = jnp.repeat(Cm.reshape(-1, s.n_groups, s.d_state),
                    nh // s.n_groups, axis=1).astype(F32)

    new_ssm = (state.ssm * a[..., None, None]
               + ein("bh,bhp,bhn->bhpn", dt, xh, Bh))
    y = ein("bhpn,bhn->bhp", new_ssm, Ch) + xh * p["D"][:, None]
    y = y.reshape(-1, 1, di)

    gated = y * jax.nn.silu(z.astype(F32))
    var = jnp.mean(gated * gated, axis=-1, keepdims=True)
    gated = gated * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"].astype(F32)
    out = ein("bsk,kd->bsd", gated.astype(x.dtype), p["out_proj"]).astype(x.dtype)
    return out, SSMState(ssm=new_ssm, conv=new_conv)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    s = cfg.ssm
    d = cfg.d_model
    di, nh, gn = s.d_inner(d), s.n_heads(d), s.n_groups * s.d_state
    return SSMState(
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), F32),
        conv=jnp.zeros((batch, s.conv_width - 1, di + 2 * gn), cfg.param_dtype),
    )
