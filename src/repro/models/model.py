"""Unified model API over all families.

Pure functions:
  init(cfg, rng)                          -> params pytree
  forward(cfg, params, batch, capture)    -> (logits, aux_loss, captures)
  loss(cfg, params, batch)                -> (scalar, metrics dict)
  init_cache(cfg, batch_size, s_max)      -> decode cache pytree
  prefill(cfg, params, batch)             -> (last-token logits, cache)
  decode_step(cfg, params, cache, token)  -> (logits, cache)

Batch keys by family:
  dense/moe/ssm/hybrid : tokens [B, S] int32
  vlm                  : tokens [B, S], patches [B, P, d_model]
  audio                : frames [B, n_audio_ctx, d_model], tokens [B, S]
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.numerics import ein, dot as _ndot, constrain, bf16_cotangent

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import mamba as S

F32 = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, rng) -> dict:
    k_emb, k_stack = jax.random.split(rng)
    params: Dict[str, Any] = {"embed": L.embed_init(cfg, k_emb)}
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.moe is not None and cfg.moe_merged:
            k_a, k_b = jax.random.split(k_stack)
            if cfg.moe_split > 0:
                params["stack"] = T.stack_init(cfg, k_a,
                                               n_layers=cfg.moe_split)
            params["stack_c"] = T.stack_init(
                cfg, k_b, n_layers=cfg.n_layers - cfg.moe_split,
                n_real=cfg.moe_merged)
            if cfg.moe_merged_layers is not None:
                # heterogeneous per-layer M: tables stay padded to the max,
                # but each layer's remap may only address its LIVE rows and
                # ``live`` arms the router-logit mask (DESIGN.md §5)
                live = jnp.asarray(cfg.moe_merged_layers, jnp.int32)
                E = cfg.moe.n_experts
                moe_c = dict(params["stack_c"]["moe"])
                moe_c["live"] = live
                moe_c["remap"] = (jnp.arange(E, dtype=jnp.int32)[None, :]
                                  % live[:, None])
                params["stack_c"] = dict(params["stack_c"], moe=moe_c)
        else:
            params["stack"] = T.stack_init(cfg, k_stack)
    elif cfg.family == "ssm":
        params["ssm_ln"] = jax.vmap(
            lambda k: L.rmsnorm_init(cfg.d_model, cfg.param_dtype))(
                jax.random.split(k_stack, cfg.n_layers))
        params["ssm"] = jax.vmap(lambda k: S.mamba_init(cfg, k))(
            jax.random.split(k_stack, cfg.n_layers))
    elif cfg.family == "hybrid":
        params["hybrid"] = T.hybrid_init(cfg, k_stack)
    elif cfg.family == "audio":
        params["encdec"] = T.encdec_init(cfg, k_stack)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    params["final_ln"] = L.rmsnorm_init(cfg.d_model, cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# SSM stack helpers
# ---------------------------------------------------------------------------

def _ssm_stack(cfg, params, x, return_states: bool):
    def body(h, xs):
        ln, mp = xs
        if return_states:
            out, st = S.mamba_apply(cfg, mp, L.rmsnorm(ln, h, cfg.norm_eps),
                                    return_state=True)
            return bf16_cotangent(constrain(h + out, "DP", "M", None)), st
        out = S.mamba_apply(cfg, mp, L.rmsnorm(ln, h, cfg.norm_eps))
        return bf16_cotangent(constrain(h + out, "DP", "M", None)), None

    body = T._maybe_remat(cfg, body)
    return jax.lax.scan(body, x, (params["ssm_ln"], params["ssm"]))


def _ssm_stack_decode(cfg, params, x, states: S.SSMState):
    def body(h, xs):
        ln, mp, st = xs
        out, st = S.mamba_decode(cfg, mp, L.rmsnorm(ln, h, cfg.norm_eps), st)
        return h + out, st

    return jax.lax.scan(body, x, (params["ssm_ln"], params["ssm"], states))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict,
            capture: bool = False):
    """Returns (logits [B, S_out, V], aux_loss scalar, captures or None)."""
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    aux = jnp.zeros((), F32)
    caps = None

    if cfg.family == "audio":
        enc_out = T.encode(cfg, params["encdec"], batch["frames"])
        x = constrain(L.embed_apply(params["embed"], batch["tokens"]),
                      "DP", None, None)
        x = T.decode_stack_apply(cfg, params["encdec"], x, enc_out,
                                 inv_freq=inv_freq)
    else:
        x = constrain(L.embed_apply(params["embed"], batch["tokens"]),
                      "DP", None, None)
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            x = constrain(x, "DP", None, None)
        if cfg.family in ("dense", "moe", "vlm"):
            caps_list = []
            if "stack" in params:
                x, aux, caps = T.stack_apply(cfg, params["stack"], x,
                                             inv_freq=inv_freq, capture=capture)
                caps_list.append(caps)
            if "stack_c" in params:
                x, aux2, caps2 = T.stack_apply(cfg, params["stack_c"], x,
                                               inv_freq=inv_freq,
                                               capture=capture)
                aux = aux + aux2
                caps_list.append(caps2)
            if capture and len(caps_list) > 1:
                caps = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *caps_list)
            elif capture:
                caps = caps_list[0]
        elif cfg.family == "ssm":
            x, _ = _ssm_stack(cfg, params, x, return_states=False)
        elif cfg.family == "hybrid":
            x = T.hybrid_apply(cfg, params["hybrid"], x, inv_freq=inv_freq)
        if cfg.family == "vlm" and "patches" in batch:
            x = x[:, batch["patches"].shape[1]:]   # predictions on text only

    x = bf16_cotangent(constrain(x, "DP", None, None))
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = constrain(L.lm_head(cfg, params["embed"], x), "DP", None, "M")
    return logits, aux, caps


def loss(cfg: ModelConfig, params: dict, batch: dict) -> Tuple[jax.Array, dict]:
    """Next-token cross-entropy (+ MoE aux)."""
    logits, aux, _ = forward(cfg, params, batch)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(F32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(targets, F32))
    mask = mask.astype(F32) if mask.shape == targets.shape else jnp.ones_like(targets, F32)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe is not None else 0.0
    total = ce + aux_coef * aux
    return total, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, s_max: int) -> dict:
    dt = cfg.param_dtype
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        shape = (cfg.n_layers, batch_size, s_max, cfg.n_kv_heads, cfg.hd)
        cache = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                 "pos": jnp.zeros((), jnp.int32)}
        if cfg.family == "audio":
            cache["enc"] = jnp.zeros((batch_size, cfg.n_audio_ctx, cfg.d_model), dt)
        return cache
    if cfg.family == "ssm":
        st = S.init_ssm_state(cfg, batch_size)
        return {"ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st),
            "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        st = S.init_ssm_state(cfg, batch_size)
        nseg = cfg.n_layers // cfg.hybrid_attn_every
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st),
            "k": jnp.zeros((nseg, batch_size, s_max, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((nseg, batch_size, s_max, cfg.n_kv_heads, cfg.hd), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def _pad_kv(cache: dict, s_max: int) -> dict:
    """Grow prefilled KV caches along the sequence axis to ``s_max`` so
    subsequent decode steps have slots to write into."""
    def pad(a):
        extra = s_max - a.shape[2]
        if extra <= 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[2] = (0, extra)
        return jnp.pad(a, widths)
    out = dict(cache)
    for key in ("k", "v"):
        if key in out:
            out[key] = pad(out[key])
    return out


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            s_max: int | None = None):
    """Process the prompt; returns (last-position logits [B, V], cache).

    ``s_max``: total cache capacity (prompt + generation budget). Defaults to
    the prompt length (no decode headroom)."""
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    tokens = batch["tokens"]
    S_len = tokens.shape[1]

    if cfg.family == "audio":
        enc_out = T.encode(cfg, params["encdec"], batch["frames"])
        x = L.embed_apply(params["embed"], tokens)
        # prefill the decoder self-attn cache by scanning with kv emission
        def body(h, layer_p):
            hn = L.rmsnorm(layer_p["ln1"], h, cfg.norm_eps)
            a, k, v = L.attn_prefill(cfg, layer_p["self_attn"], hn,
                                     inv_freq=inv_freq)
            h = h + a
            c = L.attn_apply(cfg, layer_p["cross_attn"],
                             L.rmsnorm(layer_p["ln_x"], h, cfg.norm_eps),
                             inv_freq=None, kv=enc_out)
            h = h + c
            h = h + L.mlp_apply(layer_p["mlp"],
                                L.rmsnorm(layer_p["ln2"], h, cfg.norm_eps))
            return h, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, params["encdec"]["dec"])
        cache = {"k": ks, "v": vs, "enc": enc_out,
                 "pos": jnp.asarray(S_len, jnp.int32)}
    elif cfg.family in ("dense", "moe", "vlm"):
        x = L.embed_apply(params["embed"], tokens)
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        ks_l, vs_l = [], []
        for key in ("stack", "stack_c"):
            if key in params:
                x, ks, vs = T.stack_prefill(cfg, params[key], x,
                                            inv_freq=inv_freq)
                ks_l.append(ks)
                vs_l.append(vs)
        ks = jnp.concatenate(ks_l, axis=0) if len(ks_l) > 1 else ks_l[0]
        vs = jnp.concatenate(vs_l, axis=0) if len(vs_l) > 1 else vs_l[0]
        cache = {"k": ks, "v": vs, "pos": jnp.asarray(x.shape[1], jnp.int32)}
    elif cfg.family == "ssm":
        x = L.embed_apply(params["embed"], tokens)
        x, states = _ssm_stack(cfg, params, x, return_states=True)
        cache = {"ssm": states, "pos": jnp.asarray(S_len, jnp.int32)}
    elif cfg.family == "hybrid":
        x = L.embed_apply(params["embed"], tokens)
        x, cache = T.hybrid_prefill(cfg, params["hybrid"], x, inv_freq=inv_freq)
        cache["pos"] = jnp.asarray(S_len, jnp.int32)
    else:
        raise ValueError(cfg.family)

    if s_max is not None:
        cache = _pad_kv(cache, s_max)
    x = L.rmsnorm(params["final_ln"], x[:, -1:], cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], x)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# slotted serving: continuous batching over a persistent slot cache
# ---------------------------------------------------------------------------

def init_slot_cache(cfg: ModelConfig, n_slots: int, s_max: int) -> dict:
    """Persistent KV cache for the continuous-batching engine.

    One row per serving slot; ``pos`` is a PER-SLOT length vector (unlike the
    scalar in :func:`init_cache`) so requests of different lengths coexist and
    slots survive request turnover."""
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"slotted serving is token-only (dense/moe), not {cfg.family}")
    dt = cfg.param_dtype
    shape = (cfg.n_layers, n_slots, s_max, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((n_slots,), jnp.int32)}


def init_paged_cache(cfg: ModelConfig, n_slots: int, s_max: int, *,
                     n_blocks: int, block_size: int,
                     kv_dtype: str = "bf16") -> dict:
    """Paged KV pool for the continuous-batching engine (DESIGN.md §11).

    Replaces the dense ``[L, n_slots, s_max, nkv, hd]`` slot cache with a
    flat pool of ``n_blocks`` fixed-size blocks plus a per-slot block table:
    ``kp``/``vp``: [L, n_blocks, block_size, nkv, hd] (int8 when
    ``kv_dtype == "int8"``, with per-(row, head) fp32 scales ``ks``/``vs``);
    ``tab``: [n_slots + 1, s_max // block_size] int32 block ids, sentinel
    ``n_blocks`` for unallocated entries AND the whole last row (admission
    pads point there so their scatters drop); ``pos``: [n_slots] int32.
    Block ownership lives host-side in ``serving.paging.PagedAllocator``;
    the pool zeros-init keeps never-written garbage finite. Works with the
    UNCHANGED ``decode_step_slots``/``verify_step_slots`` entries, which
    dispatch on ``"kp" in cache``."""
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged serving is token-only (dense/moe), not {cfg.family}")
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} not a multiple of "
                         f"block_size={block_size}")
    mb = s_max // block_size
    pshape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, cfg.hd)
    cache = {"pos": jnp.zeros((n_slots,), jnp.int32),
             "tab": jnp.full((n_slots + 1, mb), n_blocks, jnp.int32)}
    if kv_dtype == "int8":
        cache.update(kp=jnp.zeros(pshape, jnp.int8),
                     vp=jnp.zeros(pshape, jnp.int8),
                     ks=jnp.zeros(pshape[:-1], F32),
                     vs=jnp.zeros(pshape[:-1], F32))
    elif kv_dtype == "bf16":
        dt = cfg.param_dtype
        cache.update(kp=jnp.zeros(pshape, dt), vp=jnp.zeros(pshape, dt))
    else:
        raise ValueError(f"kv_dtype must be 'bf16' or 'int8', got "
                         f"{kv_dtype!r}")
    return cache


def _paged_forward(cfg: ModelConfig, params: dict, cache: dict, x, stack_fn,
                   tab, pos, inv_freq):
    """Run a paged stack function over both parameter stacks, splitting the
    pools' layer axis at ``cfg.moe_split``. Returns (x, cache-with-new-pools)
    — ``pos``/``tab`` updates are the caller's business."""
    quant = "ks" in cache
    kp, vp = cache["kp"], cache["vp"]
    ks, vs = (cache["ks"], cache["vs"]) if quant else (None, None)

    def sl(a, lo, hi):
        return None if a is None else a[lo:hi]

    if "stack_c" in params and "stack" in params:
        split = cfg.moe_split
        L_ = cfg.n_layers
        x, k1, v1, s1, t1 = stack_fn(cfg, params["stack"], x,
                                     kp[:split], vp[:split],
                                     sl(ks, 0, split), sl(vs, 0, split),
                                     tab, pos, inv_freq=inv_freq)
        x, k2, v2, s2, t2 = stack_fn(cfg, params["stack_c"], x,
                                     kp[split:], vp[split:],
                                     sl(ks, split, L_), sl(vs, split, L_),
                                     tab, pos, inv_freq=inv_freq)
        kp = jnp.concatenate([k1, k2], axis=0)
        vp = jnp.concatenate([v1, v2], axis=0)
        if quant:
            ks = jnp.concatenate([s1, s2], axis=0)
            vs = jnp.concatenate([t1, t2], axis=0)
    else:
        stack = params.get("stack", params.get("stack_c"))
        x, kp, vp, ks, vs = stack_fn(cfg, stack, x, kp, vp, ks, vs,
                                     tab, pos, inv_freq=inv_freq)
    new_cache = dict(cache, kp=kp, vp=vp)
    if quant:
        new_cache.update(ks=ks, vs=vs)
    return x, new_cache


def admit_slots_paged(cfg: ModelConfig, params: dict, cache: dict,
                      tokens: jax.Array, lengths: jax.Array,
                      slots: jax.Array, pos0: jax.Array):
    """Admit one bucketed request group into the paged cache.

    tokens: [Bp, Sb] SUFFIX tokens (prompt minus any shared-prefix rows)
    right-padded to a bucket length; lengths: [Bp] true suffix lengths
    (>= 1 — the allocator caps sharing below the full prompt); slots: [Bp]
    int32 target slots with pads = n_slots (the sentinel table row, so pad
    rows' KV scatters and pos write all drop); pos0: [Bp] int32 shared
    prefix row counts (all zero without sharing).

    This is a verify-shaped forward at absolute positions
    ``pos0[b] + arange(Sb)``: suffix queries attend the adopted prefix
    blocks through the slot's table, so with pos0 = 0 it reproduces the
    dense ``prefill_slots`` + ``insert_slots`` admission bitwise (bf16
    pools), and with pos0 > 0 it skips re-prefilling the shared rows
    entirely. Returns (logits [Bp, V] at each row's last real suffix
    position, new cache with ``pos[slots] = pos0 + lengths``).
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"paged admission is token-only (dense/moe), not {cfg.family}")
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], tokens)
    tab_b = cache["tab"][slots]                     # [Bp, mb]
    x, new_cache = _paged_forward(cfg, params, cache, x,
                                  T.stack_verify_paged, tab_b, pos0,
                                  inv_freq)
    new_cache["pos"] = cache["pos"].at[slots].set(pos0 + lengths)
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    last = x[jnp.arange(x.shape[0]), lengths - 1]   # [Bp, d]
    logits = L.lm_head(cfg, params["embed"], last[:, None])[:, 0]
    return logits, new_cache


def prefill_slots(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  lengths: jax.Array):
    """Prefill right-padded prompts for slot insertion.

    tokens: [B, S_bucket] int32 prompts padded to a shared bucket length;
    lengths: [B] int32 true prompt lengths. Returns (logits [B, V] taken at
    each row's LAST REAL position, k [L, B, S_bucket, nkv, hd], v).

    Padding rows beyond ``lengths[b]`` produce garbage KV, which is harmless:
    causality keeps them out of every real position's context, and the decode
    mask (``<= pos``) hides them until they are overwritten in place.
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"slot prefill is token-only (dense/moe), not {cfg.family}")
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], tokens)
    ks_l, vs_l = [], []
    for key in ("stack", "stack_c"):
        if key in params:
            x, ks, vs = T.stack_prefill(cfg, params[key], x, inv_freq=inv_freq)
            ks_l.append(ks)
            vs_l.append(vs)
    ks = jnp.concatenate(ks_l, axis=0) if len(ks_l) > 1 else ks_l[0]
    vs = jnp.concatenate(vs_l, axis=0) if len(vs_l) > 1 else vs_l[0]
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    last = x[jnp.arange(x.shape[0]), lengths - 1]          # [B, d]
    logits = L.lm_head(cfg, params["embed"], last[:, None])[:, 0]
    return logits, ks, vs


def insert_slot(cache: dict, slot: jax.Array, k_new: jax.Array,
                v_new: jax.Array, length: jax.Array) -> dict:
    """Write one prefilled request into slot ``slot`` of the engine cache.

    k_new/v_new: [L, 1, S_bucket, nkv, hd] from :func:`prefill_slots`;
    ``slot``/``length`` are traced int32 scalars so admission never
    recompiles per slot. Rows [S_bucket, s_max) keep whatever the previous
    occupant left — masked until overwritten."""
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0, 0))
    pos = cache["pos"].at[slot].set(length)
    return {"k": k, "v": v, "pos": pos}


def insert_slots(cache: dict, slots: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, lengths: jax.Array) -> dict:
    """Batched :func:`insert_slot`: write a whole admission group at once.

    k_new/v_new: [L, B, S_bucket, nkv, hd] from one batched
    :func:`prefill_slots`; slots: [B] int32 (distinct); lengths: [B] int32.
    One scatter per tensor instead of B ``dynamic_update_slice`` dispatches."""
    Sb = k_new.shape[2]
    k = cache["k"].at[:, slots, :Sb].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, slots, :Sb].set(v_new.astype(cache["v"].dtype))
    pos = cache["pos"].at[slots].set(lengths)
    return {"k": k, "v": v, "pos": pos}


def decode_step_slots(cfg: ModelConfig, params: dict, cache: dict,
                      token: jax.Array, active: jax.Array):
    """One decode step across all serving slots.

    token: [B] int32 (last sampled token per slot, anything for idle slots);
    active: [B] bool. Idle slots compute alongside (their flops are the price
    of static shapes) but their ``pos`` does not advance, so they never
    corrupt state another request will read. Returns (logits [B, V], cache).
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"slotted decode is token-only (dense/moe), not {cfg.family}")
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], token[:, None])
    pos = cache["pos"]

    if "kp" in cache:                                  # paged pool (§11)
        x, new_cache = _paged_forward(cfg, params, cache, x,
                                      T.stack_decode_paged,
                                      cache["tab"][:pos.shape[0]], pos,
                                      inv_freq)
        new_cache["pos"] = jnp.where(active, pos + 1, pos)
    elif "stack_c" in params and "stack" in params:
        split = cfg.moe_split
        x, nk1, nv1 = T.stack_decode_slots(cfg, params["stack"], x,
                                           cache["k"][:split],
                                           cache["v"][:split],
                                           pos, inv_freq=inv_freq)
        x, nk2, nv2 = T.stack_decode_slots(cfg, params["stack_c"], x,
                                           cache["k"][split:],
                                           cache["v"][split:],
                                           pos, inv_freq=inv_freq)
        nk = jnp.concatenate([nk1, nk2], axis=0)
        nv = jnp.concatenate([nv1, nv2], axis=0)
        new_cache = {"k": nk, "v": nv,
                     "pos": jnp.where(active, pos + 1, pos)}
    else:
        stack = params.get("stack", params.get("stack_c"))
        x, nk, nv = T.stack_decode_slots(cfg, stack, x,
                                         cache["k"], cache["v"], pos,
                                         inv_freq=inv_freq)
        new_cache = {"k": nk, "v": nv,
                     "pos": jnp.where(active, pos + 1, pos)}
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], x)[:, 0]
    return logits, new_cache


def verify_step_slots(cfg: ModelConfig, params: dict, cache: dict,
                      tokens: jax.Array):
    """Multi-position forward across all serving slots (speculative verify).

    tokens: [B, T] int32 — slot b's last committed token followed by its
    draft proposals. One forward scores all T positions of every slot at
    once (prefill-shaped: with T > 1 the MoE layers always take the
    grouped/ragged path, never the T == 1 gather specialization), writing
    KV into rows pos[b] .. pos[b]+T-1 of the slot cache. ``pos`` is NOT
    advanced here: how many of the T positions become committed is the
    acceptance rule's decision (``repro.serving.spec``), which rewinds or
    advances ``pos`` for both caches after sampling. Returns
    (logits [B, T, V], cache).
    """
    if cfg.family not in ("dense", "moe"):
        raise NotImplementedError(
            f"slotted verify is token-only (dense/moe), not {cfg.family}")
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], tokens)
    pos = cache["pos"]

    if "kp" in cache:                                  # paged pool (§11)
        x, new_cache = _paged_forward(cfg, params, cache, x,
                                      T.stack_verify_paged,
                                      cache["tab"][:pos.shape[0]], pos,
                                      inv_freq)
    elif "stack_c" in params and "stack" in params:
        split = cfg.moe_split
        x, nk1, nv1 = T.stack_verify_slots(cfg, params["stack"], x,
                                           cache["k"][:split],
                                           cache["v"][:split],
                                           pos, inv_freq=inv_freq)
        x, nk2, nv2 = T.stack_verify_slots(cfg, params["stack_c"], x,
                                           cache["k"][split:],
                                           cache["v"][split:],
                                           pos, inv_freq=inv_freq)
        nk = jnp.concatenate([nk1, nk2], axis=0)
        nv = jnp.concatenate([nv1, nv2], axis=0)
        new_cache = {"k": nk, "v": nv, "pos": pos}
    else:
        stack = params.get("stack", params.get("stack_c"))
        x, nk, nv = T.stack_verify_slots(cfg, stack, x,
                                         cache["k"], cache["v"], pos,
                                         inv_freq=inv_freq)
        new_cache = {"k": nk, "v": nv, "pos": pos}
    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], x)
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict, token: jax.Array):
    """One decode step. token: [B] int32. Returns (logits [B, V], cache)."""
    inv_freq = None if cfg.is_attention_free else L.rope_freqs(cfg.hd, cfg.rope_theta)
    x = L.embed_apply(params["embed"], token[:, None])
    pos = cache["pos"]

    if cfg.family in ("dense", "moe", "vlm"):
        if "stack_c" in params and "stack" in params:
            split = cfg.moe_split
            x, nk1, nv1 = T.stack_decode(cfg, params["stack"], x,
                                         cache["k"][:split], cache["v"][:split],
                                         pos, inv_freq=inv_freq)
            x, nk2, nv2 = T.stack_decode(cfg, params["stack_c"], x,
                                         cache["k"][split:], cache["v"][split:],
                                         pos, inv_freq=inv_freq)
            nk = jnp.concatenate([nk1, nk2], axis=0)
            nv = jnp.concatenate([nv1, nv2], axis=0)
        else:
            stack = params.get("stack", params.get("stack_c"))
            x, nk, nv = T.stack_decode(cfg, stack, x,
                                       cache["k"], cache["v"], pos,
                                       inv_freq=inv_freq)
        new_cache = {"k": nk, "v": nv, "pos": pos + 1}
    elif cfg.family == "audio":
        x, nk, nv = T.decode_stack_step(cfg, params["encdec"], x, cache["enc"],
                                        cache["k"], cache["v"], pos,
                                        inv_freq=inv_freq)
        new_cache = {"k": nk, "v": nv, "enc": cache["enc"], "pos": pos + 1}
    elif cfg.family == "ssm":
        x, states = _ssm_stack_decode(cfg, params, x, cache["ssm"])
        new_cache = {"ssm": states, "pos": pos + 1}
    elif cfg.family == "hybrid":
        x, nc = T.hybrid_decode(cfg, params["hybrid"], x, cache, pos,
                                inv_freq=inv_freq)
        nc["pos"] = pos + 1
        new_cache = nc
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(params["final_ln"], x, cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], x)[:, 0]
    return logits, new_cache
