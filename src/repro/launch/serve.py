"""Serving driver: continuous batching over the slotted ragged-MoE path.

The production entry point is :class:`repro.serving.Engine` — request-level
admission/eviction over a persistent slot cache, decode through the ragged
dispatch + grouped SwiGLU kernel (see ``repro/serving/engine.py`` for the
scheduler semantics).

:class:`FixedBatchServer` (the former continuous-batching-lite ``Server``) is
kept as the decode-parity reference: it groups requests into fixed-size
batches with one scalar cache position, which is exactly the token-for-token
baseline the engine is tested against (tests/test_serving_engine.py).

    PYTHONPATH=src python -m repro.launch.serve --requests 16 --n-slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.models.numerics import set_activation_mesh
from repro.serving import Engine, EngineConfig, Request, poisson_trace


@dataclasses.dataclass
class ServeConfig:
    arch: str = "qwen3-moe-30b-a3b"
    reduced: bool = True
    batch_size: int = 4
    prompt_len: int = 32
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0


class FixedBatchServer:
    """Fixed-batch reference loop (the seed repo's ``Server``).

    All requests in a batch share one prompt length and one scalar cache
    position; a batch must fully finish before the next one starts. Kept as
    the numerical baseline for the continuous-batching parity tests and for
    the quickstart example — new serving code should use
    :class:`repro.serving.Engine`.
    """

    def __init__(self, sc: ServeConfig, cfg=None, params=None):
        self.sc = sc
        self.cfg = cfg if cfg is not None else (
            configs.get(sc.arch).reduced() if sc.reduced
            else configs.get(sc.arch))
        mesh = make_host_mesh()
        set_activation_mesh(mesh)
        self.params = params if params is not None else MD.init(
            self.cfg, jax.random.PRNGKey(sc.seed))
        s_max = sc.prompt_len + sc.max_new_tokens
        self._prefill = jax.jit(ST.make_serve_prefill(self.cfg, s_max=s_max))
        self._step = jax.jit(ST.make_serve_step(self.cfg))

    def generate(self, prompts: np.ndarray,
                 extra_batch: Optional[dict] = None) -> np.ndarray:
        """prompts: [B, prompt_len] int32 -> [B, max_new_tokens] int32."""
        sc = self.sc
        batch = {"tokens": jnp.asarray(prompts)}
        if extra_batch:
            batch.update(extra_batch)
        if self.cfg.family == "audio" and "frames" not in batch:
            batch["frames"] = jnp.zeros(
                (prompts.shape[0], self.cfg.n_audio_ctx, self.cfg.d_model),
                self.cfg.param_dtype)
        logits, cache = self._prefill(self.params, batch)
        outs = []
        key = jax.random.PRNGKey(sc.seed)
        for t in range(sc.max_new_tokens):
            if sc.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / sc.temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            outs.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache,
                                       tok.astype(jnp.int32))
        return np.stack(outs, axis=1)


# Back-compat alias (quickstart / system tests predate the engine).
Server = FixedBatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate, requests per decode step")
    args = ap.parse_args()

    ec = EngineConfig(arch=args.arch, n_slots=args.n_slots, s_max=args.s_max,
                      prefill_buckets=(args.prompt_len,))
    eng = Engine(ec)
    rng = np.random.default_rng(0)
    arrivals = poisson_trace(args.requests, rate=args.rate, seed=1)
    for i in range(args.requests):
        eng.submit(rng.integers(0, eng.cfg.vocab_size, size=args.prompt_len,
                                dtype=np.int32),
                   max_new_tokens=args.max_new_tokens,
                   arrival_time=float(arrivals[i]))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {eng.ec.n_slots} slots, "
          f"dispatch={eng.cfg.moe.dispatch if eng.cfg.moe else 'dense-mlp'})")
    for r in done[:4]:
        print(f"  req {r.uid}: arrived@{r.arrival_time:.1f} "
              f"admitted@{r.t_admitted:.0f} done@{r.t_finished:.0f} "
              f"[{r.finish_reason}] first tokens {r.out_tokens[:6]}")


if __name__ == "__main__":
    main()
