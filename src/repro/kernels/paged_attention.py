"""Paged-attention decode Pallas kernel (TPU target, interpret-validated).

Decode attention over the paged KV pool (DESIGN.md §11): keys/values live in
fixed-size blocks of a flat ``[n_blocks, bs, nkv, hd]`` pool, and each slot's
block ids arrive in a scalar-prefetched table so the K/V BlockSpec index maps
gather exactly the blocks slot ``b`` owns — the kernel never materializes the
``[B, s_max]`` contiguous view the jnp oracle builds. Grid is
``(B, max_blocks)``: one slot per outer step, one of its blocks per inner
step, with the flash-attention online-softmax state (running max /
normalizer / fp32 accumulator, per kv-head-group) in VMEM scratch.

GQA is handled by reshaping the ``nq = nkv·n_rep`` query heads to
``[nkv, n_rep, hd]`` so each kv head's block is loaded once per slot and
shared by its ``n_rep`` query heads — the HBM story the paged layout exists
for: per decoded token the kernel streams each owned block once, int8 blocks
(the ``_q`` variant, with per-(row, head) fp32 scales dequantized in VMEM) at
half the bf16 width.

Rows past ``lens[b]`` are masked with the running-max trick, so the sentinel
blocks the wrapper clips into range (unallocated / pad table entries point at
``n_blocks``) contribute exactly nothing regardless of what block 0 holds.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _update(s, j, b, lens_ref, m_ref, l_ref, acc_ref, vt, *, bs: int):
    """One online-softmax block update: s [nkv, n_rep, bs] raw logits,
    vt [nkv, bs, hd] fp32 values."""
    rows = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
    s = jnp.where((rows < lens_ref[b])[None, None, :], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("grs,gsd->grd", p, vt))
    m_ref[...] = m_new


def _kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, bs: int, nk: int,
            n_rep: int):
    del tab_ref                         # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nkv, hd = k_ref.shape[2], k_ref.shape[3]
    q = q_ref[0].reshape(nkv, n_rep, hd).astype(F32)
    kt = k_ref[0].astype(F32).transpose(1, 0, 2)          # [nkv, bs, hd]
    vt = v_ref[0].astype(F32).transpose(1, 0, 2)
    s = jnp.einsum("grd,gsd->grs", q, kt) * scale
    _update(s, j, b, lens_ref, m_ref, l_ref, acc_ref, vt, bs=bs)

    @pl.when(j == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0] = (acc_ref[...] / l[..., None]).reshape(
            nkv * n_rep, hd).astype(o_ref.dtype)


def _kernel_q(tab_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
              m_ref, l_ref, acc_ref, *, scale: float, bs: int, nk: int,
              n_rep: int):
    """Int8 variant: K/V blocks are int8 with per-(row, head) fp32 scales;
    dequantization is a single fp32 multiply in VMEM (the §8 fused-dequant
    stance applied to the KV stream)."""
    del tab_ref
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nkv, hd = k_ref.shape[2], k_ref.shape[3]
    q = q_ref[0].reshape(nkv, n_rep, hd).astype(F32)
    k = k_ref[0].astype(F32) * ks_ref[0][..., None]       # [bs, nkv, hd]
    v = v_ref[0].astype(F32) * vs_ref[0][..., None]
    kt = k.transpose(1, 0, 2)
    vt = v.transpose(1, 0, 2)
    s = jnp.einsum("grd,gsd->grs", q, kt) * scale
    _update(s, j, b, lens_ref, m_ref, l_ref, acc_ref, vt, bs=bs)

    @pl.when(j == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[..., None]).reshape(
            nkv * n_rep, hd).astype(o_ref.dtype)


def _specs(B, nq, hd, bs, nkv, quantized: bool):
    kv = pl.BlockSpec((1, bs, nkv, hd), lambda b, j, tb, ln: (tb[b, j],
                                                              0, 0, 0))
    ins = [pl.BlockSpec((1, nq, hd), lambda b, j, tb, ln: (b, 0, 0)), kv, kv]
    if quantized:
        sc = pl.BlockSpec((1, bs, nkv), lambda b, j, tb, ln: (tb[b, j], 0, 0))
        ins += [sc, sc]
    return ins, pl.BlockSpec((1, nq, hd), lambda b, j, tb, ln: (b, 0, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, kp, vp, tab, lens, interpret: bool = False):
    """q: [B, nq, hd] (current row already written to the pool by the
    caller); kp/vp: [n_blocks, bs, nkv, hd]; tab: [B, max_blocks] int32
    block ids (entries >= n_blocks are sentinels for unallocated table
    slots — clipped here, masked by ``lens``); lens: [B] int32 valid rows
    (``pos + 1``). Returns [B, nq, hd]."""
    B, nq, hd = q.shape
    nb, bs, nkv, _ = kp.shape
    mb = tab.shape[1]
    n_rep = nq // nkv
    tab = jnp.clip(tab.astype(jnp.int32), 0, nb - 1)
    lens = lens.astype(jnp.int32)
    ins, outs = _specs(B, nq, hd, bs, nkv, quantized=False)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(B, mb), in_specs=ins, out_specs=outs,
        scratch_shapes=[pltpu.VMEM((nkv, n_rep), F32),
                        pltpu.VMEM((nkv, n_rep), F32),
                        pltpu.VMEM((nkv, n_rep, hd), F32)])
    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(hd), bs=bs, nk=mb,
                          n_rep=n_rep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nq, hd), q.dtype),
        interpret=interpret,
    )(tab, lens, q, kp, vp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_q(q, kp, vp, ks, vs, tab, lens, interpret: bool = False):
    """Int8 pool variant of :func:`paged_attention`: kp/vp int8
    [n_blocks, bs, nkv, hd] with ks/vs fp32 [n_blocks, bs, nkv] per-(row,
    head) scales (``core.quant.quantize_kv`` format)."""
    B, nq, hd = q.shape
    nb, bs, nkv, _ = kp.shape
    mb = tab.shape[1]
    n_rep = nq // nkv
    tab = jnp.clip(tab.astype(jnp.int32), 0, nb - 1)
    lens = lens.astype(jnp.int32)
    ins, outs = _specs(B, nq, hd, bs, nkv, quantized=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(B, mb), in_specs=ins, out_specs=outs,
        scratch_shapes=[pltpu.VMEM((nkv, n_rep), F32),
                        pltpu.VMEM((nkv, n_rep), F32),
                        pltpu.VMEM((nkv, n_rep, hd), F32)])
    return pl.pallas_call(
        functools.partial(_kernel_q, scale=1.0 / math.sqrt(hd), bs=bs, nk=mb,
                          n_rep=n_rep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nq, hd), q.dtype),
        interpret=interpret,
    )(tab, lens, q, kp, vp, ks, vs)
