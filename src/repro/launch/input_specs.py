"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as MD
from repro import configs

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int) -> Dict:
    specs = {"tokens": SDS((global_batch, seq_len), jnp.int32)}
    if cfg.family == "vlm":
        specs["patches"] = SDS(
            (global_batch, cfg.vlm_num_patches, cfg.d_model), cfg.param_dtype)
    if cfg.family == "audio":
        specs["frames"] = SDS(
            (global_batch, cfg.n_audio_ctx, cfg.d_model), cfg.param_dtype)
    return specs


def decode_specs(cfg: ModelConfig, global_batch: int, s_max: int) -> Tuple:
    """(cache_specs, token_spec) for serve_step lowering."""
    cache = jax.eval_shape(lambda: MD.init_cache(cfg, global_batch, s_max))
    token = SDS((global_batch,), jnp.int32)
    return cache, token


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: MD.init(cfg, jax.random.PRNGKey(0)))


def input_specs(arch: str, shape_name: str) -> Dict:
    """Assignment entry point: per (arch, shape) cell returns everything the
    corresponding step function needs, as ShapeDtypeStructs."""
    cfg = configs.get(arch)
    sh = configs.SHAPES[shape_name]
    if not configs.shape_applicable(cfg, shape_name):
        raise ValueError(
            f"{arch} x {shape_name}: skipped (full-attention arch on a "
            "sub-quadratic-only shape; DESIGN.md §5)")
    gb, seq = sh["global_batch"], sh["seq_len"]
    if sh["kind"] in ("train", "prefill"):
        return {"kind": sh["kind"], "cfg": cfg,
                "batch": train_batch_specs(cfg, gb, seq)}
    cache, token = decode_specs(cfg, gb, seq)
    return {"kind": "decode", "cfg": cfg, "cache": cache, "token": token}
