"""Optimizers: convergence, clipping, factored states, int-param handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, adafactor, sgd, apply_updates, global_norm,
                         cosine_schedule, default_optimizer_for)


def _quadratic_target():
    target = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]]),
              "b": jnp.asarray([0.3, -0.7])}

    def loss(p):
        return sum(jnp.sum((p[k] - target[k]) ** 2) for k in p)
    return target, loss


@pytest.mark.parametrize("make,steps,lr,tol", [
    (adamw, 400, 3e-2, 1e-2), (adafactor, 800, 5e-2, 6e-2),
    (sgd, 200, 2e-1, 1e-2)])
def test_converges_on_quadratic(make, steps, lr, tol):
    target, loss = _quadratic_target()
    params = {"w": jnp.zeros((2, 2)), "b": jnp.zeros(2)}
    opt = make(lr=lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params, i)
        return apply_updates(params, u), state

    for i in range(steps):
        params, state = step(params, state, jnp.asarray(i))
    assert float(loss(params)) < tol


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros(32)}
    st = adafactor().init(params)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (32,)
    assert st["b"]["v"].shape == (32,)
    n_state = sum(x.size for x in jax.tree.leaves(st))
    assert n_state < params["w"].size  # sub-linear


def test_int_params_skipped():
    params = {"w": jnp.zeros((4, 4)), "remap": jnp.arange(4, dtype=jnp.int32)}
    opt = adamw(lr=0.1)
    state = opt.init(params)
    grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2), allow_int=True)(params)
    u, state = opt.update(grads, state, params, jnp.asarray(0))
    p2 = apply_updates(params, u)
    np.testing.assert_array_equal(np.asarray(p2["remap"]),
                                  np.arange(4, dtype=np.int32))
    assert p2["remap"].dtype == jnp.int32


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = sgd(lr=1.0, max_grad_norm=1.0)
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    u, _ = opt.update(huge, state, params, jnp.asarray(0))
    assert float(global_norm(u)) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.11
    assert float(lr(jnp.asarray(100))) < float(lr(jnp.asarray(50)))


def test_default_optimizer_thresholds():
    assert default_optimizer_for(8e9) == "adamw"
    assert default_optimizer_for(110e9) == "adafactor"
    assert default_optimizer_for(1e12) == "adafactor"
