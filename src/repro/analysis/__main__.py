"""CLI: ``python -m repro.analysis`` — lint + kernel contracts, exit
non-zero on any finding. ``--root DIR`` lints a different source tree
(used by the fixture tests); ``--no-contracts`` / ``--no-lint`` run one
leg only."""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas hot-path linter + kernel contract checker")
    p.add_argument("--root", default=None,
                   help="directory containing the `repro` package to lint "
                        "(default: the installed tree)")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule-id allowlist (e.g. RA001,RA007)")
    p.add_argument("--no-lint", action="store_true")
    p.add_argument("--no-contracts", action="store_true")
    p.add_argument("--arch", action="append", default=None,
                   help="restrict contract checks to these arch ids "
                        "(repeatable; default: all)")
    args = p.parse_args(argv)

    failed = False
    if not args.no_lint:
        from repro.analysis.lint import run_lint
        rules = args.rules.split(",") if args.rules else None
        report = run_lint(root=args.root, rules=rules)
        for f in report.findings:
            print(f.format())
        if report.suppressed:
            print(f"[lint] {len(report.suppressed)} suppressed finding(s):")
            for f in report.suppressed:
                print(f"  {f.format()} — {f.reason}")
        print(f"[lint] {len(report.findings)} finding(s)")
        failed |= not report.ok

    if not args.no_contracts:
        from repro.analysis.kernel_contracts import check_kernel_contracts
        report = check_kernel_contracts(arch_ids=args.arch)
        for f in report.findings:
            print(f.format())
        if report.waived:
            print(f"[contracts] {len(report.waived)} waived finding(s):")
            for f in report.waived:
                print(f"  {f.format()}")
        print(f"[contracts] {len(report.findings)} finding(s) over "
              f"{len(report.checked)} (kernel, config) pairs")
        failed |= not report.ok

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
