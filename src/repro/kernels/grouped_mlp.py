"""Grouped (per-expert) SwiGLU Pallas kernel — megablocks-style MoE compute.

Tokens arrive SORTED by expert (``x: [T, d]``, ``group_sizes: [E]``). The
wrapper pads each expert's segment to a multiple of the token block so every
grid block maps to exactly one expert; a scalar-prefetched ``block_expert``
table then indexes the expert weight tables in the BlockSpec index maps —
the dense one-hot dispatch einsum (GShard path) is replaced by pure gathers.

This is the TPU-native realization of the paper's deployment claim: after
MergeMoE halves the expert count, each merged expert's token group DOUBLES,
so blocks are fuller and fewer — better MXU utilization at identical
arithmetic (see EXPERIMENTS.md §Perf, MoE serving iteration).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(be_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[0], preferred_element_type=F32)
    u = jnp.dot(x, wu_ref[0], preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[0], preferred_element_type=F32)

    @pl.when(j == nf - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def _kernel_q(be_ref, x_ref, qg_ref, qu_ref, qd_ref, sg_ref, su_ref, sd_ref,
              o_ref, acc_ref, *, nf: int):
    """Int8 variant: weight blocks arrive as int8 + per-output-channel fp32
    scales and are dequantized IN VMEM — HBM moves one byte per weight plus
    the (tiny) scale rows. The dequantized weights stay fp32 through the
    whole SwiGLU and the output downcasts ONCE at the flush — the same
    dataflow as the jnp dequant oracle, which the kernel matches bit for bit
    when the f axis is unblocked (intermediate model-dtype roundings would
    be cancelled by XLA's excess-precision pass and are deliberately
    absent; DESIGN.md §8)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x32 = x_ref[...].astype(F32)
    wg = qg_ref[0].astype(F32) * sg_ref[0]
    wu = qu_ref[0].astype(F32) * su_ref[0]
    wd = qd_ref[0].astype(F32) * sd_ref[0]
    g = jnp.dot(x32, wg)
    u = jnp.dot(x32, wu)
    h = jax.nn.silu(g) * u
    acc_ref[...] += jnp.dot(h, wd)

    @pl.when(j == nf - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _segment_layout(group_sizes, T: int, E: int, bt: int):
    """Shared sort-free segment layout: pad each expert's token segment to a
    multiple of ``bt`` and derive (dest row scatter indices, block->expert
    table, padded row count). See the duplicate-proof ``jnp.repeat`` note in
    :func:`grouped_swiglu`."""
    starts = jnp.cumsum(group_sizes) - group_sizes            # [E]
    padded_sizes = ((group_sizes + bt - 1) // bt) * bt
    padded_starts = jnp.cumsum(padded_sizes) - padded_sizes
    Tp = T + E * (bt - 1)
    Tp = ((Tp + bt - 1) // bt) * bt
    nb = Tp // bt
    eid = jnp.repeat(jnp.arange(E, dtype=jnp.int32), group_sizes,
                     total_repeat_length=T)
    dest = padded_starts[eid] + (jnp.arange(T) - starts[eid])
    block_expert = jnp.repeat(jnp.arange(E, dtype=jnp.int32),
                              padded_sizes // bt,
                              total_repeat_length=nb)
    return dest, block_expert, Tp, nb


@functools.partial(jax.jit, static_argnames=("block_t", "block_f",
                                             "interpret"))
def grouped_swiglu(x, wg, wu, wd, group_sizes, block_t: int = 128,
                   block_f: int = 512, interpret: bool = False):
    """x: [T, d] sorted by expert; wg/wu: [E, d, f]; wd: [E, f, d];
    group_sizes: [E] int32 summing to T. Returns [T, d]."""
    T, d = x.shape
    E, _, f = wg.shape
    bt = block_t
    bf = _block(f, block_f)
    nf = f // bf

    # ---- pad each expert segment to a multiple of bt (static worst case:
    # T + E*(bt-1) rows), build block -> expert map + row scatter indices.
    # Zero-sized groups (routine after aggressive merging: the remap empties
    # every absorbed expert's bucket) make `starts`/`padded_starts` contain
    # duplicate entries, which a searchsorted-based mapping must special-case;
    # instead both the row->expert and block->expert tables are built with
    # ``jnp.repeat(..., total_repeat_length=...)``, which emits each expert id
    # exactly size/blocks-per-expert times and is duplicate-proof by
    # construction (trailing padding repeats the last id onto all-zero rows,
    # whose output is discarded; blocks beyond the last padded segment rerun
    # the last non-empty expert on zero rows — harmless, output discarded).
    dest, block_expert, Tp, nb = _segment_layout(group_sizes, T, E, bt)
    xp = jnp.zeros((Tp, d), x.dtype).at[dest].set(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, bf, d), lambda i, j, be: (be[i], j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bt, d), F32)],
    )
    yp = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        interpret=interpret,
    )(block_expert, xp, wg, wu, wd)
    return yp[dest]


def grouped_swiglu_q(x, qt, group_sizes, block_t: int = 128,
                     block_f: int = 512, interpret: bool = False):
    """Int8 grouped SwiGLU: same segment layout as :func:`grouped_swiglu`,
    but the expert tables stream from HBM as int8 blocks plus fp32
    per-output-channel scale rows and are dequantized inside the kernel —
    half the weight traffic of the bf16 path at identical fp32 matmul
    accumulation.

    ``qt``: :class:`repro.core.quant.QuantizedExpertTables` with tables
    ``[E, d, f]`` / ``[E, f, d]`` and keepdim scales ``[E, 1, f]`` /
    ``[E, 1, d]``. With the f axis unblocked (``block_f >= f``) the kernel
    is bitwise-equal to ``ref.grouped_swiglu_q``; blocking f reassociates
    the fp32 accumulation across f-blocks — allclose, not bitwise
    (DESIGN.md §8). Deliberately UNJITTED: the production entry point is
    ``ops.grouped_swiglu_q`` (which jits); the interpret-mode validation
    path runs eagerly so XLA cannot re-fuse arithmetic across the
    kernel/wrapper boundary out from under the bitwise contract."""
    T, d = x.shape
    E, _, f = qt.wg.shape
    bt = block_t
    bf = _block(f, block_f)
    nf = f // bf

    dest, block_expert, Tp, nb = _segment_layout(group_sizes, T, E, bt)
    xp = jnp.zeros((Tp, d), x.dtype).at[dest].set(x)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, d, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, bf, d), lambda i, j, be: (be[i], j, 0)),
            pl.BlockSpec((1, 1, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, 1, bf), lambda i, j, be: (be[i], 0, j)),
            pl.BlockSpec((1, 1, d), lambda i, j, be: (be[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j, be: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bt, d), F32)],
    )
    yp = pl.pallas_call(
        functools.partial(_kernel_q, nf=nf),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        interpret=interpret,
    )(block_expert, xp, qt.wg, qt.wu, qt.wd,
      qt.wg_scale, qt.wu_scale, qt.wd_scale)
    return yp[dest]
