"""Benchmark harness entry point — one function per paper table/figure plus
the roofline summary. Prints ``name,value,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import paper_tables as PT


def _emit(name: str, rows) -> None:
    print(f"\n== {name} ==")
    for r in rows:
        print("csv," + name + "," + json.dumps(r))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slower sweeps (ratio/samples)")
    args = ap.parse_args()

    t0 = time.perf_counter()
    _emit("table1_3_quality", PT.table_quality())
    _emit("table4_generalization", PT.table_generalization())
    _emit("table5_ablation", PT.table_ablation())
    _emit("fig3_timecost", PT.fig_timecost())
    if not args.fast:
        _emit("fig2_ratio", PT.fig_ratio())
        _emit("fig4_samples", PT.fig_samples())

    # roofline summary (from dry-run artifacts, if present)
    try:
        from benchmarks import roofline as RL
        rows = [RL.row(r) for r in RL.load_records("pod")]
        worst = [r for r in rows if not r.get("skip") and r["kind"] == "train"]
        worst.sort(key=lambda r: r["roofline_fraction"])
        _emit("roofline_train_cells", [
            {"arch": r["arch"], "shape": r["shape"],
             "dominant": r["dominant"],
             "fraction": round(r["roofline_fraction"], 4)} for r in worst])
    except Exception as e:  # dry-run artifacts absent
        print(f"csv,roofline,skipped: {e}")

    print(f"\n[benchmarks] total {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
