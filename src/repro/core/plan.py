"""Declarative compression plans + the merge-strategy registry.

The paper frames MergeMoE as a PER-LAYER decision: which layers to merge,
down to how many experts, with which construction. A ``CompressionPlan`` makes
that decision explicit and serializable instead of baking one global
``(method, merged_experts, split)`` triple into ``compress_model``:

    plan = PLAN.uniform(cfg, method="mergemoe", merged_experts=4, split=28)
    plan = PLAN.suffix(cfg, method="mergemoe", merged_experts=4, frac=0.4)
    plan = PLAN.for_target_ratio(cfg, target_ratio=1.6, stats=stream.stats())

Plans are executed by :func:`repro.core.compress.compress_with_plan` and
persisted alongside the compressed artifact
(:func:`repro.ckpt.checkpoint.save_compressed`).

Strategies are self-describing classes registered with ``@register_method``;
each declares which calibration inputs it needs (``requires`` ⊆ {"x",
"counts", "router"}) so the executor only materializes what a layer's method
actually consumes — this replaces the old ``METHODS`` dict plus the
``if method == "msmoe"`` special case in ``merge_layer``.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core import merge as MG
from repro.core.errors import TechniqueInapplicable
from repro.models.config import ModelConfig

PLAN_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

class MergeStrategy:
    """One way of collapsing N experts into M. Subclasses declare their
    calibration ``requires`` and implement :meth:`merge`."""

    name: str = ""
    #: subset of {"x", "counts", "router"} the strategy consumes. Everything
    #: it does not list may be passed as None by the executor.
    requires: Tuple[str, ...] = ()

    def merge(self, wg, wu, wd, counts, X, M, *, router=None,
              **kw) -> MG.MergeResult:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<MergeStrategy {self.name} requires={self.requires}>"


_REGISTRY: Dict[str, MergeStrategy] = {}


def register_method(name: str):
    """Class decorator: ``@register_method("mergemoe")``. The class is
    instantiated once and becomes addressable from plans and the CLI."""
    def deco(cls: Type[MergeStrategy]) -> Type[MergeStrategy]:
        inst = cls()
        inst.name = name
        _REGISTRY[name] = inst
        return cls
    return deco


def get_strategy(name: str) -> MergeStrategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown merge method {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def available_methods() -> List[str]:
    return sorted(_REGISTRY)


@register_method("mergemoe")
class MergeMoEStrategy(MergeStrategy):
    """Paper §4: cluster -> frequency-weighted T2/T3 average -> least-squares
    down projection against the merged cluster outputs."""
    requires = ("x", "counts")

    def merge(self, wg, wu, wd, counts, X, M, *, router=None, **kw):
        return MG.merge_mergemoe(wg, wu, wd, counts, X, M, **kw)


@register_method("msmoe")
class MSMoEStrategy(MergeStrategy):
    """M-SMoE (Li et al., 2023): frequency-weighted parameter averaging,
    clustered on the router columns (the routing-policy view)."""
    requires = ("counts", "router")

    def merge(self, wg, wu, wd, counts, X, M, *, router=None, **kw):
        return MG.merge_msmoe(wg, wu, wd, counts, X, M, router=router)


@register_method("average")
class AverageStrategy(MergeStrategy):
    """Uniform parameter averaging within weight-similarity clusters."""
    requires = ("counts",)

    def merge(self, wg, wu, wd, counts, X, M, *, router=None, **kw):
        return MG.merge_average(wg, wu, wd, counts, X, M)


@register_method("zipit")
class ZipItStrategy(MergeStrategy):
    """ZipIt-style activation-correlation neuron matching before averaging."""
    requires = ("x", "counts")

    def merge(self, wg, wu, wd, counts, X, M, *, router=None, **kw):
        return MG.merge_zipit(wg, wu, wd, counts, X, M)


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    """Compression decision for one layer."""
    layer: int
    method: str
    merged_experts: int

    def to_dict(self) -> dict:
        return {"layer": self.layer, "method": self.method,
                "merged_experts": self.merged_experts}

    @classmethod
    def from_dict(cls, d: Mapping) -> "LayerSpec":
        return cls(layer=int(d["layer"]), method=str(d["method"]),
                   merged_experts=int(d["merged_experts"]))


#: storage dtypes a plan may request for the merged expert tables.
#: "bf16" keeps the model dtype; "int8" stores symmetric
#: per-expert-per-output-channel int8 + fp32 scales (DESIGN.md §8).
WEIGHT_DTYPES = ("bf16", "int8")


@dataclass(frozen=True)
class CompressionPlan:
    """An ordered set of per-layer merge decisions.

    The merged layers must form a contiguous SUFFIX of the stack (the model
    splits into an untouched prefix ``stack`` and a compressed ``stack_c`` at
    ``split``); methods and budgets may differ per layer.

    ``mesh`` records the device mesh the plan was built/executed under
    (``(("data", 4), ("model", 2))``-style pairs, or None for single-device).
    It is provenance METADATA only: execution is bit-for-bit identical across
    mesh shapes (DESIGN.md §6), so a plan may be replayed on any mesh.

    ``weight_dtype`` picks the STORAGE dtype of the merged expert tables —
    the second, multiplicative axis of the memory budget next to the
    per-layer M: ``"bf16"`` (default) or ``"int8"``
    (per-expert-per-output-channel symmetric quantization applied at the end
    of ``compress_with_plan``, DESIGN.md §8). Orthogonal to the merge
    decisions: the planner's budget math stays in the bf16 byte model, and
    quantization is deterministic on the solved tables, so the §6 mesh
    bit-for-bit contract is unaffected.
    """
    specs: Tuple[LayerSpec, ...]
    mesh: Optional[Tuple[Tuple[str, int], ...]] = None
    weight_dtype: str = "bf16"

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(
            sorted(self.specs, key=lambda s: s.layer)))
        if self.mesh is not None:
            object.__setattr__(self, "mesh", tuple(
                (str(a), int(s)) for a, s in
                (self.mesh.items() if isinstance(self.mesh, Mapping)
                 else self.mesh)))

    def with_mesh(self, mesh) -> "CompressionPlan":
        """Same plan annotated with the mesh it ran under. Accepts a
        jax Mesh, an {axis: size} mapping, pair tuples, or None."""
        if mesh is not None and hasattr(mesh, "shape") \
                and not isinstance(mesh, (Mapping, tuple)):
            mesh = {str(k): int(v) for k, v in mesh.shape.items()}
        return CompressionPlan(self.specs, mesh, self.weight_dtype)

    # ---- views ------------------------------------------------------------
    @property
    def split(self) -> int:
        """First merged layer."""
        if not self.specs:
            raise ValueError("empty plan has no split")
        return self.specs[0].layer

    @property
    def layers(self) -> Tuple[int, ...]:
        return tuple(s.layer for s in self.specs)

    @property
    def merged_per_layer(self) -> Tuple[int, ...]:
        return tuple(s.merged_experts for s in self.specs)

    @property
    def max_merged(self) -> int:
        return max(s.merged_experts for s in self.specs)

    @property
    def methods(self) -> Tuple[str, ...]:
        return tuple(s.method for s in self.specs)

    @property
    def is_uniform(self) -> bool:
        return (len({s.merged_experts for s in self.specs}) == 1
                and len({s.method for s in self.specs}) == 1)

    def spec_for(self, layer: int) -> LayerSpec:
        for s in self.specs:
            if s.layer == layer:
                return s
        raise KeyError(layer)

    # ---- validation -------------------------------------------------------
    def validate(self, cfg: ModelConfig) -> "CompressionPlan":
        """Checks the plan is executable against ``cfg``; returns self."""
        if cfg.moe is None:
            raise TechniqueInapplicable(
                f"{cfg.name} ({cfg.family}) has no routed experts "
                "(DESIGN.md §4).")
        if not self.specs:
            raise ValueError("plan has no layers")
        N, L = cfg.moe.n_experts, cfg.n_layers
        if self.layers != tuple(range(self.split, L)):
            raise ValueError(
                f"merged layers must form a contiguous suffix of "
                f"[0, {L}); got {self.layers}")
        for s in self.specs:
            if not 1 <= s.merged_experts <= N:
                raise ValueError(
                    f"layer {s.layer}: merged_experts={s.merged_experts} "
                    f"outside [1, {N}]")
            get_strategy(s.method)       # raises on unregistered methods
        if self.weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(
                f"weight_dtype={self.weight_dtype!r} not in {WEIGHT_DTYPES}")
        return self

    def apply_to(self, cfg: ModelConfig) -> ModelConfig:
        """Config view after executing this plan."""
        self.validate(cfg)
        return cfg.compressed_per_layer(self.merged_per_layer, self.split)

    # ---- calibration requirements -----------------------------------------
    def requirements(self) -> Tuple[str, ...]:
        """Union of the calibration inputs any layer's strategy consumes."""
        req = set()
        for s in self.specs:
            req.update(get_strategy(s.method).requires)
        return tuple(sorted(req))

    # ---- (de)serialization -------------------------------------------------
    def to_json_dict(self) -> dict:
        d = {"version": PLAN_FORMAT_VERSION,
             "weight_dtype": self.weight_dtype,
             "specs": [s.to_dict() for s in self.specs]}
        if self.mesh is not None:
            d["mesh"] = {a: s for a, s in self.mesh}
        return d

    @classmethod
    def from_json_dict(cls, d: Mapping) -> "CompressionPlan":
        mesh = d.get("mesh")
        return cls(specs=tuple(LayerSpec.from_dict(s) for s in d["specs"]),
                   mesh=None if mesh is None else tuple(
                       (str(a), int(s)) for a, s in mesh.items()),
                   # absent in pre-int8 plan files -> bf16 (back-compat)
                   weight_dtype=str(d.get("weight_dtype", "bf16")))

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), indent=1)

    @classmethod
    def from_json(cls, text: str) -> "CompressionPlan":
        return cls.from_json_dict(json.loads(text))

    @classmethod
    def load(cls, path) -> "CompressionPlan":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _default_split(cfg: ModelConfig, split: Optional[int]) -> int:
    if split is None:
        split = int(cfg.n_layers * 0.6)   # paper's suffix convention
    if not 0 <= split < cfg.n_layers:
        raise ValueError(f"split={split} outside [0, {cfg.n_layers})")
    return split


def uniform(cfg: ModelConfig, *, method: str = "mergemoe",
            merged_experts: int, split: Optional[int] = None,
            weight_dtype: str = "bf16") -> CompressionPlan:
    """Same method and budget for every layer in [split, n_layers) — the
    legacy ``compress_model(method, merged_experts, split)`` surface."""
    split = _default_split(cfg, split)
    return CompressionPlan(tuple(
        LayerSpec(l, method, merged_experts)
        for l in range(split, cfg.n_layers)),
        weight_dtype=weight_dtype).validate(cfg)


def suffix(cfg: ModelConfig, *, method: str = "mergemoe",
           merged_experts: int, frac: float = 0.4,
           weight_dtype: str = "bf16") -> CompressionPlan:
    """Merge the last ``frac`` of the stack uniformly (paper App. C.2 merges
    the final ~40% of layers)."""
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"frac={frac} outside (0, 1]")
    split = cfg.n_layers - max(1, int(round(cfg.n_layers * frac)))
    return uniform(cfg, method=method, merged_experts=merged_experts,
                   split=split, weight_dtype=weight_dtype)


def expert_bytes(cfg: ModelConfig, weight_dtype: str = "bf16") -> int:
    """Bytes of ONE expert's three projection matrices at ``weight_dtype``.

    int8 stores one byte per weight plus the fp32 per-output-channel scale
    rows: ``2f`` columns for wg/wu and ``d`` for wd (DESIGN.md §8)."""
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    if weight_dtype == "int8":
        return 3 * d * f + 4 * (2 * f + d)
    return 3 * d * f * cfg.param_dtype.itemsize


def _total_bytes(cfg: ModelConfig) -> int:
    """Analytic full-model byte count (same napkin model as ``param_count``,
    at the parameter dtype)."""
    return cfg.param_count() * cfg.param_dtype.itemsize


def plan_live_ratio(cfg: ModelConfig, plan: CompressionPlan) -> float:
    """Analytic live-byte compression ratio of ``plan`` (the byte model the
    budget planner optimizes: pad rows excluded, napkin totals)."""
    per_expert = expert_bytes(cfg)
    total = _total_bytes(cfg)
    saved = sum((cfg.moe.n_experts - m) * per_expert
                for m in plan.merged_per_layer)
    return total / (total - saved)


def layer_importance(stats: Optional[Mapping[int, np.ndarray]],
                     layers: Sequence[int], n_experts: int) -> np.ndarray:
    """Per-layer merge-sensitivity proxy from calibration usage counts.

    Importance = the routing distribution's PERPLEXITY (exp of entropy): the
    effective number of experts the layer actually uses. A layer whose
    traffic concentrates on few experts (low perplexity) loses little when
    merged hard; a layer that spreads tokens across many experts needs a
    larger M. Uniform importance when no stats are given.
    """
    if stats is None:
        return np.ones(len(layers))
    imp = np.ones(len(layers))
    for i, l in enumerate(layers):
        c = np.asarray(stats.get(l), np.float64) if l in stats else None
        if c is None or c.sum() <= 0:
            imp[i] = float(n_experts)
            continue
        p = c / c.sum()
        ent = -np.sum(p * np.log(np.where(p > 0, p, 1.0)))
        imp[i] = float(np.exp(ent))
    return imp


def for_target_ratio(cfg: ModelConfig, *, target_ratio: float,
                     stats: Optional[Mapping[int, np.ndarray]] = None,
                     method: str = "mergemoe", split: Optional[int] = None,
                     min_merged: int = 1,
                     weight_dtype: str = "bf16") -> CompressionPlan:
    """Budget-driven planner: allocate per-layer M so the compressed model's
    (live) bytes hit ``total_bytes / target_ratio``.

    Greedy marginal allocation: start every suffix layer at M = N and
    repeatedly decrement the layer with the cheapest marginal quality cost
    ``importance_l * N / (M (M - 1))`` (the 1/M curvature makes early
    decrements cheap and deep ones expensive, so low-importance layers are
    squeezed harder but no layer collapses for free) until the byte target is
    met. Deterministic given (cfg, stats).
    """
    if cfg.moe is None:
        raise TechniqueInapplicable(
            f"{cfg.name} ({cfg.family}) has no routed experts (DESIGN.md §4).")
    if target_ratio <= 1.0:
        raise ValueError(f"target_ratio must exceed 1.0, got {target_ratio}")
    split = _default_split(cfg, split)
    layers = list(range(split, cfg.n_layers))
    N = cfg.moe.n_experts
    per_expert = expert_bytes(cfg)
    total = _total_bytes(cfg)
    need_saving = total - total / target_ratio

    imp = layer_importance(stats, layers, N)
    M = np.full(len(layers), N, np.int64)
    saved = 0.0

    def marginal(i):
        return imp[i] * N / (M[i] * (M[i] - 1))

    while saved < need_saving:
        cand = [i for i in range(len(layers)) if M[i] > min_merged]
        if not cand:
            max_ratio = total / (total - float(len(layers) * (N - min_merged)
                                               * per_expert))
            raise ValueError(
                f"target_ratio={target_ratio} unreachable by expert merging "
                f"alone over layers [{split}, {cfg.n_layers}) "
                f"(max ≈ {max_ratio:.3f}); lower the ratio or the split")
        i = min(cand, key=marginal)
        M[i] -= 1
        saved += per_expert

    # weight_dtype rides along without altering the M allocation: the greedy
    # budget math stays in the bf16 byte model, and int8 composes on top
    # (target_ratio then understates the final ratio — by design, the two
    # axes are reported separately in the compression report).
    return CompressionPlan(tuple(
        LayerSpec(l, method, int(M[i]))
        for i, l in enumerate(layers)),
        weight_dtype=weight_dtype).validate(cfg)
