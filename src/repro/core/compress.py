"""End-to-end MergeMoE compression pipeline.

``compress_model(cfg, params, method, merged_experts, split, batches)``:
  1. capture calibration activations + usage counts from the ORIGINAL model,
  2. merge every MoE layer in [split, n_layers) independently (the paper's
     back-to-front traversal is equivalent under pure-functional capture —
     DESIGN.md §3),
  3. return (compressed_cfg, compressed_params) with the suffix stack's expert
     tables replaced by M merged experts + the [N]->[M] remap (matrix A).

Works on any MoE config; raises TechniqueInapplicable for expert-free
architectures (DESIGN.md §4).
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as CAL
from repro.core import merge as MG
from repro.core.errors import TechniqueInapplicable, CalibrationError
from repro.models.config import ModelConfig

# Paper Fig. 4: below ~32 calibration samples the least-squares system is
# under-determined and quality collapses to chance.
MIN_SAMPLE_WARN = 32


def _slice_layers(tree, sel):
    return jax.tree.map(lambda a: a[sel], tree)


def compress_model(cfg: ModelConfig, params: dict, *, method: str = "mergemoe",
                   merged_experts: int, split: int | None = None,
                   batches: Iterable[dict], max_tokens: int | None = None,
                   strict_samples: bool = False,
                   ) -> Tuple[ModelConfig, dict, Dict]:
    if cfg.moe is None:
        raise TechniqueInapplicable(
            f"{cfg.name} ({cfg.family}) has no routed experts (DESIGN.md §4).")
    if cfg.moe_merged:
        raise ValueError("model is already compressed")

    new_cfg = cfg.compressed(merged_experts, split)
    split = new_cfg.moe_split
    L, N, M = cfg.n_layers, cfg.moe.n_experts, merged_experts

    t0 = time.perf_counter()
    calib = CAL.collect(cfg, params, batches, max_tokens_per_layer=max_tokens)
    t_calib = time.perf_counter() - t0

    n_samples = calib[split].x.shape[0]
    if n_samples < MIN_SAMPLE_WARN and strict_samples:
        raise CalibrationError(
            f"{n_samples} calibration tokens < critical threshold "
            f"{MIN_SAMPLE_WARN} (paper Fig. 4)")

    stack = params["stack"]
    moe_p = stack["moe"]
    router_all = np.asarray(moe_p["router"], np.float32)      # [L, d, N]

    t0 = time.perf_counter()
    merged: List[MG.MergeResult] = []
    for l in range(split, L):
        res = MG.merge_layer(
            method,
            np.asarray(moe_p["wg"][l], np.float32),
            np.asarray(moe_p["wu"][l], np.float32),
            np.asarray(moe_p["wd"][l], np.float32),
            calib[l].counts,
            calib[l].x,
            M,
            router=router_all[l] if method == "msmoe" else None,
        )
        merged.append(res)
    t_merge = time.perf_counter() - t0

    # ---- assemble the compressed parameter tree
    dt = cfg.param_dtype
    suffix = _slice_layers(stack, slice(split, L))
    suffix_moe = dict(suffix["moe"])
    suffix_moe["wg"] = jnp.asarray(np.stack([r.wg for r in merged]), dt)
    suffix_moe["wu"] = jnp.asarray(np.stack([r.wu for r in merged]), dt)
    suffix_moe["wd"] = jnp.asarray(np.stack([r.wd for r in merged]), dt)
    suffix_moe["remap"] = jnp.asarray(np.stack([r.remap for r in merged]),
                                      jnp.int32)
    suffix = dict(suffix)
    suffix["moe"] = suffix_moe

    new_params = {k: v for k, v in params.items() if k != "stack"}
    if split > 0:
        new_params["stack"] = _slice_layers(stack, slice(0, split))
    new_params["stack_c"] = suffix

    orig = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    comp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(new_params))
    info = {
        "method": method,
        "layers_merged": list(range(split, L)),
        "n_experts": N,
        "merged_experts": M,
        "calib_tokens": int(n_samples),
        "t_calibrate_s": t_calib,
        "t_merge_s": t_merge,
        "bytes_original": int(orig),
        "bytes_compressed": int(comp),
        "compression_ratio": float(orig) / float(comp),
        "resid": [r.info.get("resid") for r in merged
                  if r.info.get("resid") is not None],
    }
    return new_cfg, new_params, info
