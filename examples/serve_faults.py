"""End-to-end fault-tolerant serving example (DESIGN.md §12).

Serves the same Poisson request trace twice — once clean, once with a
seeded :class:`FaultPlan` injecting

* a NaN-poisoned slot inside the fused decode block (the numeric-health
  sentinel must quarantine exactly that slot),
* a transient device failure (retried within the engine's bounded retry
  budget, invisible in the output tokens), and
* an allocator-exhaustion burst (FIFO heads get deferred until their
  deadlines lapse and they are shed with reason ``pool_pressure``)

— and then proves the degradation is SURGICAL and REPLAYABLE:

1. every request that still finishes ``ok`` under faults is bitwise
   identical to the clean run (slot quarantine and shedding never
   perturb healthy lanes),
2. the quarantined request's tokens are a strict prefix of what it
   decoded cleanly (it was cut off, not corrupted),
3. re-running with the same fault seed reproduces the identical fault
   trace (digest over the ordered firings) and identical tokens.

    PYTHONPATH=src python examples/serve_faults.py --requests 8
"""
import argparse
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, FaultPlan, FaultSpec
from repro.serving import poisson_trace

PROMPT_LEN = 8
K = 8  # fused decode block


def fault_plan(seed):
    """NaN-poison slot 0 inside the first busy decode block (it starts at
    step 3 once the first two Poisson arrivals are due, so step 5 lands
    mid-block), fail the decode block starting at step 11 twice (within
    the default ``device_retries=2`` budget), and report the pool
    exhausted for admissions falling in steps [16, 32) — covering step 19,
    where the first slots come free and the FIFO head would re-admit."""
    return FaultPlan(seed=seed, specs=(
        FaultSpec(site="decode", kind="nan_logits", steps=(5,), slots=(0,)),
        FaultSpec(site="decode", kind="transient", steps=(11,), fails=2),
        FaultSpec(site="alloc", kind="exhaust", steps=tuple(range(16, 32))),
    ))


def serve_trace(cfg, params, requests, *, plan=None, n_slots=2,
                max_new_tokens=12, rate=0.5, ttl_uid=2, ttl=10.0):
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=32,
                              prefill_buckets=(PROMPT_LEN,), decode_block=K),
                 cfg=cfg, params=params)
    # warmup compiles prefill + the fused block; rewind the step clock so
    # the plan's absolute-step schedule lands where the docstring says.
    for _ in range(n_slots):
        eng.submit(np.zeros(PROMPT_LEN, np.int32), max_new_tokens=2)
    eng.run()
    for c in eng.counters:
        eng.counters[c] = 0
    eng._step_count = 0
    eng._faults = plan

    rng = np.random.default_rng(0)
    arrivals = poisson_trace(requests, rate=rate, seed=1)
    for i in range(requests):
        # only one request carries a deadline: tight enough that an
        # injected exhaustion burst defers it past expiry, loose enough
        # that the clean run admits it comfortably
        eng.submit(rng.integers(0, cfg.vocab_size, size=PROMPT_LEN,
                                dtype=np.int32),
                   max_new_tokens=max_new_tokens,
                   arrival_time=float(arrivals[i]), uid=i,
                   ttl=ttl if i == ttl_uid else None)
    done = eng.run()
    out = {r.uid: (r.status, list(r.out_tokens), r.shed_reason)
           for r in done}
    return out, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--fault-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))

    clean, ceng = serve_trace(cfg, params, args.requests,
                              max_new_tokens=args.max_new_tokens)
    assert all(s == "ok" for s, _, _ in clean.values())
    assert all(ceng.counters[c] == 0
               for c in ("shed", "quarantined", "transient_retries"))
    print(f"[clean   ] {args.requests} requests, all ok, "
          f"0 sheds / 0 quarantines / 0 retries")

    plan = fault_plan(args.fault_seed)
    faulty, eng = serve_trace(cfg, params, args.requests, plan=plan,
                              max_new_tokens=args.max_new_tokens)
    statuses = Counter(s for s, _, _ in faulty.values())
    reasons = Counter(r for _, _, r in faulty.values() if r)
    print(f"[degraded] statuses {dict(statuses)}  shed reasons "
          f"{dict(reasons)}  counters "
          f"{ {c: eng.counters[c] for c in ('shed', 'quarantined', 'transient_retries')} }")
    print(f"[degraded] fired faults {plan.counts()}  "
          f"trace digest {plan.trace_digest()[:16]}")

    # 1. the poisoned slot — and only it — was quarantined
    bad = [u for u, (s, _, _) in faulty.items() if s == "failed_numeric"]
    assert len(bad) == 1 and eng.counters["quarantined"] == 1
    toks, ctoks = faulty[bad[0]][1], clean[bad[0]][1]
    assert toks == ctoks[:len(toks)] and len(toks) < len(ctoks), \
        "quarantined request must be a strict prefix of its clean decode"

    # 2. healthy lanes are bitwise untouched by their neighbours' faults
    ok = [u for u, (s, _, _) in faulty.items() if s == "ok"]
    assert ok and all(faulty[u][1] == clean[u][1] for u in ok), \
        "a healthy slot diverged from the fault-free run"

    # 3. the transient failures were absorbed by the retry budget and the
    #    exhaustion burst shed at least one deadline-lapsed head
    assert eng.counters["transient_retries"] == 2
    assert statuses.get("shed", 0) == eng.counters["shed"] > 0
    assert set(reasons) == {"pool_pressure"}

    # 4. same seed -> identical fault trace and identical tokens
    replay_plan = fault_plan(args.fault_seed)
    replay, _ = serve_trace(cfg, params, args.requests, plan=replay_plan,
                            max_new_tokens=args.max_new_tokens)
    assert replay_plan.trace_digest() == plan.trace_digest()
    assert replay == faulty, "same-seed replay diverged"

    print(f"fault tolerance is SURGICAL and REPLAYABLE: {len(ok)} healthy "
          f"requests bitwise == clean, quarantined uid {bad[0]} a strict "
          f"prefix, {eng.counters['transient_retries']} retries absorbed, "
          f"{eng.counters['shed']} pool-pressure sheds, same-seed replay "
          f"identical")


if __name__ == "__main__":
    main()
