"""zamba2-2.7b — hybrid: Mamba2 blocks + ONE shared attention/MLP block
applied every 6 blocks [arXiv:2411.15242; hf].

54L d_model=2560, shared attn 32H (kv=32) d_ff=10240, vocab=32000,
ssm_state=64. Sub-quadratic -> eligible for long_500k.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4,
                  chunk_size=512),
    hybrid_attn_every=6,
    remat="full",
)
