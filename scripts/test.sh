#!/usr/bin/env bash
# Tier-1 verification entry point.
#
#   scripts/test.sh              # fast suite (slow-marked cases deselected)
#   scripts/test.sh -m slow      # only the slow smoke cases
#   scripts/test.sh tests/test_kernels.py -k grouped
#
# Extra arguments are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
