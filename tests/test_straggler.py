"""StragglerMonitor: warmup gating, rolling-median ratio, window hygiene
(slow steps must not poison the median), patience/consecutive accounting,
and the start/end timing wrapper."""
import pytest

from repro.distributed.straggler import StragglerMonitor


def test_warmup_never_flags():
    m = StragglerMonitor(warmup=3, threshold=2.0)
    # even a 100x outlier is unflagged until warmup observations are banked
    for dur in (0.1, 10.0, 0.1):
        rep = m.observe(dur)
        assert not rep.is_straggler and rep.ratio == 1.0
        assert rep.median_s == dur  # pre-warmup: median is the sample itself


def test_flags_above_threshold_ratio():
    m = StragglerMonitor(warmup=3, threshold=2.0)
    for _ in range(5):
        m.observe(0.1)
    ok = m.observe(0.19)
    assert not ok.is_straggler and ok.ratio == pytest.approx(1.9)
    bad = m.observe(0.25)
    assert bad.is_straggler and bad.ratio == pytest.approx(2.5)
    assert bad.median_s == pytest.approx(0.1)


def test_slow_steps_excluded_from_window():
    """A sustained stall must keep ratios measured against the HEALTHY
    median — if flagged steps entered the window the median would drift up
    and the detector would acquit the straggler."""
    m = StragglerMonitor(warmup=3, threshold=2.0, patience=100)
    for _ in range(10):
        m.observe(0.1)
    for _ in range(20):
        rep = m.observe(0.5)
        assert rep.is_straggler
        assert rep.median_s == pytest.approx(0.1)
    assert max(m.window) == pytest.approx(0.1)


def test_patience_and_consecutive_reset():
    m = StragglerMonitor(warmup=3, threshold=2.0, patience=3)
    for _ in range(5):
        m.observe(0.1)
    assert m.observe(0.5).consecutive == 1
    assert m.observe(0.5).consecutive == 2
    # one clean step resets the streak: transient blips never restart
    assert m.observe(0.1).consecutive == 0
    m.observe(0.5), m.observe(0.5)
    rep = m.observe(0.5)
    assert rep.consecutive == 3 and rep.should_restart
    # restart stays recommended while the stall persists
    assert m.observe(0.5).should_restart


def test_no_restart_below_patience():
    m = StragglerMonitor(warmup=3, threshold=2.0, patience=5)
    for _ in range(5):
        m.observe(0.1)
    for i in range(4):
        rep = m.observe(0.5)
        assert not rep.should_restart, f"restart after only {i + 1} flags"


def test_window_is_bounded_and_rolls():
    # a sub-threshold regime shift (1.8x < 2.0x) is absorbed: the steps are
    # unflagged, enter the window, and roll the old regime out
    m = StragglerMonitor(window=4, warmup=2, threshold=2.0)
    for dur in (0.1, 0.1, 0.1, 0.1, 0.18, 0.18, 0.18, 0.18):
        assert not m.observe(dur).is_straggler
    assert len(m.window) == 4
    assert m.observe(0.2).median_s == pytest.approx(0.18)


def test_step_counter_and_report_fields():
    m = StragglerMonitor(warmup=1)
    r1, r2 = m.observe(0.1), m.observe(0.1)
    assert (r1.step, r2.step) == (1, 2)
    assert r2.duration_s == pytest.approx(0.1)


def test_start_end_step_times_the_interval():
    m = StragglerMonitor(warmup=3)
    m.start_step()
    rep = m.end_step()
    assert rep.duration_s >= 0.0 and rep.step == 1
    with pytest.raises(AssertionError, match="start_step"):
        m.end_step()  # timer is single-shot: must re-arm
