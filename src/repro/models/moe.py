"""Mixture-of-Experts layer.

Three dispatch paths:

* ``dense``  — GShard/GSPMD-style capacity-based one-hot dispatch. Static
  shapes, partitions cleanly under pjit (tokens on the ``data`` axis, experts
  on the ``model`` axis -> XLA inserts the all-to-all). Used by train/dry-run.
* ``ragged`` — sort-by-expert grouped matmul (single-device / serving path;
  the Pallas grouped-matmul kernel plugs in here).
* ``gather`` — ragged that specializes decode-SHAPED calls (one token per
  sequence, at most ``gather_max_tokens`` of them) to a per-token
  weight-row gather kernel (``kernels/decode_moe.py``): no
  argsort/bincount/scatter, no per-expert segment padding. Selection is on
  static shapes at trace time; prefill buckets (S > 1) keep the grouped
  kernel.

Compressed (merged) models keep the ORIGINAL router ``[d, N]`` and add an
int32 ``remap`` table ``[N] -> [M]`` (the paper's matrix ``A``, stored as the
index form); expert tables then hold ``M`` merged experts. This reproduces the
paper's implicit-A trick (App. B) with an XLA-friendly gather.

Calibration capture: ``moe_apply(..., capture=True)`` additionally returns
the expert-input activations and per-expert usage counts that
``repro.core`` consumes to build the merge.

Quantized expert tables (DESIGN.md §8): a layer whose params carry a
``qexp`` subtree instead of ``wg/wu/wd`` stores the tables as int8 plus
per-expert-per-output-channel fp32 scales
(:class:`repro.core.quant.QuantizedExpertTables`). All three dispatch paths
accept it — ragged and gather route through the int8 kernels (dequant fused
in-kernel), dense dequantizes up front (train/dry-run path, not
bandwidth-bound). Routing, remap, and the §5 live-masking are untouched:
quantization changes the bits under the expert tables, never the dispatch.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.numerics import ein, ein32, dot as _ndot, constrain

from repro.models.config import ModelConfig
from repro.models.layers import _dense_init, mlp_init, mlp_apply

F32 = jnp.float32


class MoEOutput(NamedTuple):
    y: jax.Array                       # [B, S, d]
    aux_loss: jax.Array                # scalar load-balance loss
    # capture (zeros-shaped when capture=False to keep pytree static)
    expert_inputs: Optional[jax.Array]   # [B, S, d] inputs fed to experts
    usage_counts: Optional[jax.Array]    # [N] how often each ORIGINAL expert was picked
    topk_idx: Optional[jax.Array]        # [B, S, k] original-expert indices


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def moe_init(cfg: ModelConfig, key, n_real: int | None = None) -> dict:
    """n_real: number of physically stored experts (M after MergeMoE
    compression); router/remap always span the ORIGINAL n_experts.

    ``live`` counts the routable rows of the expert tables. Heterogeneous
    plans pad every suffix layer's tables to the plan's max M, and
    ``live`` < n_real marks the pad rows; :func:`route` masks the router
    logits of any original expert whose remap lands on a pad row, so the
    zero-filled padding is unreachable (DESIGN.md §5)."""
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    R = n_real or E
    dt = cfg.param_dtype
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(kr, (d, E), F32),  # router kept fp32 (tiny)
        "wg": _dense_init(kg, (R, d, f), dt),
        "wu": _dense_init(ku, (R, d, f), dt),
        "wd": _dense_init(kd, (R, f, d), dt),
        # identity remap = uncompressed; [N]->[M] after merging.
        "remap": jnp.arange(E, dtype=jnp.int32) % R,
        "live": jnp.asarray(R, jnp.int32),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(d, m.n_shared_experts * f, dt, ks)
    return p


def n_real_experts(p: dict) -> int:
    """Number of physically stored experts (M after compression, else N)."""
    if "qexp" in p:
        return p["qexp"]["wg"].shape[0]
    return p["wg"].shape[0]


def _quant_tables(p: dict):
    """The layer's ``QuantizedExpertTables`` view, or None when the tables
    are plain bf16/f32 leaves."""
    if "qexp" not in p:
        return None
    from repro.core.quant import QuantizedExpertTables
    return QuantizedExpertTables.from_tree(p["qexp"])


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def _topk_iterative(probs: jax.Array, k: int):
    """Partition-friendly top-k: k argmax/mask passes (elementwise over the
    token dims, so GSPMD never gathers the token axis — lax.top_k lowers to a
    variadic sort that forced [B,S,E] all-gathers; §Perf iteration A1)."""
    E = probs.shape[-1]
    ws, ids = [], []
    cur = probs
    iota = jax.lax.broadcasted_iota(jnp.int32, probs.shape, probs.ndim - 1)
    for _ in range(k):
        w = jnp.max(cur, axis=-1)
        i = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        ws.append(w)
        ids.append(i)
        cur = jnp.where(iota == i[..., None], -jnp.inf, cur)
    return jnp.stack(ws, axis=-1), jnp.stack(ids, axis=-1)


def route(cfg: ModelConfig, p: dict, x: jax.Array):
    """Returns (topk_weights [.., k] fp32, topk_idx [.., k] int32 in ORIGINAL
    expert space, probs [.., N])."""
    m = cfg.moe
    logits = ein32("...d,de->...e", x.astype(F32), p["router"])
    if "live" in p:
        # Router-logit masking: an original expert whose remap target is a
        # pad row (>= live, possible only in heterogeneous-M suffix layers)
        # can never win top-k. No-op for valid remaps — every entry already
        # points below ``live`` — so masked and unmasked routing agree
        # exactly; the mask guarantees the zero-padded tables stay
        # unreachable even under a corrupted remap (DESIGN.md §5).
        logits = jnp.where(p["remap"] >= p["live"], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = _topk_iterative(probs, m.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)  # renormalize among top-k
    return w, idx, probs


def route_infer(cfg: ModelConfig, p: dict, x: jax.Array):
    """Inference-only routing: (topk_weights [.., k] fp32, topk_idx [.., k]).

    Selects top-k directly on the (live-masked) router LOGITS — softmax is
    strictly monotone, so the selection matches :func:`route` — and computes
    the combine weights as a softmax over just the k selected logits:
    ``exp(l_i) / Σ_topk exp(l_j)``, the same value :func:`route` reaches by
    renormalizing the full softmax. Skips materializing the [.., N] ``probs``
    tensor entirely; it exists only to feed :func:`balance_loss`, which
    decode throws away every step. Training/capture keep :func:`route`."""
    m = cfg.moe
    logits = ein32("...d,de->...e", x.astype(F32), p["router"])
    if "live" in p:
        # same fail-closed pad-row mask as route() (DESIGN.md §5)
        logits = jnp.where(p["remap"] >= p["live"], -jnp.inf, logits)
    lw, idx = _topk_iterative(logits, m.top_k)
    return jax.nn.softmax(lw, axis=-1), idx


def balance_loss(cfg: ModelConfig, probs: jax.Array, idx: jax.Array) -> jax.Array:
    """Switch-style auxiliary load-balancing loss over ORIGINAL experts."""
    E = cfg.moe.n_experts
    me = jnp.mean(probs.reshape(-1, E), axis=0)                      # mean prob
    sel = jax.nn.one_hot(idx.reshape(-1, cfg.moe.top_k), E, dtype=F32)
    ce = jnp.mean(jnp.sum(sel, axis=1), axis=0)                      # tokens/expert
    return E * jnp.sum(me * ce) / cfg.moe.top_k


# ---------------------------------------------------------------------------
# dense (capacity) dispatch — GShard style, group-local
# ---------------------------------------------------------------------------

def _capacity(m, G: int, E: int) -> int:
    c = int(m.top_k * G * m.capacity_factor / E)
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def capacity_experts(cfg: ModelConfig, p: dict) -> int:
    """Expert count used to SIZE dense-dispatch capacity (shapes are static,
    so this must come from the config, not the traced ``live`` leaf).

    For a heterogeneous compressed suffix the tables are padded to max-M but
    a layer may route all its traffic onto as few as min(live) rows; sizing
    capacity by the padded width would under-provision those layers and drop
    tokens an unpadded model would keep. Sizing by the SMALLEST live count
    gives every suffix layer at least the per-expert slots its own unpadded
    model would compute (DESIGN.md §5).

    Suffix tables are identified by their width (``moe_merged``). When a
    plan's max M equals the original N the prefix stack matches too and is
    conservatively sized by min(live) as well — over-provisioned capacity is
    wasted slots, never extra drops."""
    E = n_real_experts(p)
    if (cfg.moe_merged_layers is not None
            and E == cfg.moe_merged):        # suffix-width expert tables
        return min(cfg.moe_merged_layers)
    return E


def _dispatch_tensors(cfg: ModelConfig, w, idx, E: int, C: int):
    """Build combine [G, E, C] fp32 and dispatch [G, E, C] bool per group.

    w, idx: [G, k]. Tokens beyond capacity are dropped (standard GShard).
    """
    m = cfg.moe
    G = w.shape[0]
    counts = jnp.zeros((E,), jnp.int32)
    combine = jnp.zeros((G, E, C), F32)
    for j in range(m.top_k):
        mj = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)           # [G, E]
        loc = jnp.cumsum(mj, axis=0) - mj + counts[None, :]          # position
        counts = counts + jnp.sum(mj, axis=0)
        keep = (loc < C) & (mj > 0)
        slot = jax.nn.one_hot(jnp.where(keep, loc, C), C, dtype=F32)  # OOB -> 0
        combine = combine + w[:, j, None, None] * mj[..., None] * slot
    dispatch = combine > 0.0
    return combine, dispatch


def _moe_dense_groups(cfg: ModelConfig, p: dict, x2: jax.Array, w, idx):
    """x2: [n_groups, G, d]; w/idx: [n_groups, G, k] (idx already remapped to
    REAL experts). Returns [n_groups, G, d]."""
    m = cfg.moe
    E = n_real_experts(p)
    G = x2.shape[1]
    # capacity sized by the LIVE expert count (== E except in heterogeneous
    # suffixes): merged experts absorb their whole cluster's traffic, so
    # per-expert slots scale up as N/M automatically.
    C = _capacity(m, G, capacity_experts(cfg, p))

    combine, dispatch = jax.vmap(
        lambda wg, ig: _dispatch_tensors(cfg, wg, ig, E, C))(w, idx)

    dt = x2.dtype
    qt = _quant_tables(p)
    if qt is not None:
        # dense dispatch is the train/dry-run path — not bandwidth-bound, so
        # a one-shot dequant to the activation dtype before the einsum keeps
        # it simple (ragged/gather stream int8 through the kernels instead).
        wg_t, wu_t, wd_t = qt.dequant(dt)
        p = dict(p, wg=wg_t, wu=wu_t, wd=wd_t)
    # dispatched tokens: groups stay on the batch axes, experts go to "model"
    # (expert parallelism; GSPMD realizes the reshard as an all-to-all)
    xe = ein("gtec,gtd->gecd", dispatch.astype(dt), x2).astype(dt)           # [g,E,C,d]
    xe = constrain(xe, "DP", "M", None, None)
    h_g = ein("gecd,edf->gecf", xe, p["wg"])
    h_u = ein("gecd,edf->gecf", xe, p["wu"])
    h = (jax.nn.silu(h_g) * h_u).astype(dt)
    ye = ein("gecf,efd->gecd", h, p["wd"]).astype(dt)           # [g,E,C,d]
    ye = constrain(ye, "DP", "M", None, None)
    y = ein("gtec,gecd->gtd", combine.astype(dt), ye).astype(dt)
    # NOTE: deliberately unconstrained — the combine contraction is partial
    # over the expert ("model") axis, and the caller's sequence-parallel
    # residual constraint pulls a reduce-scatter through here. An explicit
    # replicated-token constraint at this point forced a 2x-cost all-reduce
    # (§Perf iteration A2).
    return y


# ---------------------------------------------------------------------------
# ragged (sort-based) dispatch — serving / kernel path
# ---------------------------------------------------------------------------

def _moe_ragged(cfg: ModelConfig, p: dict, xf: jax.Array, w, idx):
    """xf: [T, d]; w/idx: [T, k] (idx in REAL expert space). Dropless."""
    m = cfg.moe
    E = n_real_experts(p)
    T, d = xf.shape
    k = m.top_k
    flat_idx = idx.reshape(-1)                       # [T*k]
    order = jnp.argsort(flat_idx)
    tok_of = order // k                              # source token per slot
    xs = jnp.take(xf, tok_of, axis=0)                # [T*k, d] sorted by expert
    group_sizes = jnp.bincount(flat_idx, length=E).astype(jnp.int32)

    from repro.kernels import ops as kops
    qt = _quant_tables(p)
    if qt is not None:
        ys = kops.grouped_swiglu_q(xs, qt, group_sizes)
    else:
        ys = kops.grouped_swiglu(xs, p["wg"], p["wu"], p["wd"], group_sizes)

    wf = w.reshape(-1)[order].astype(F32)            # weight per sorted slot
    out = jnp.zeros((T, d), F32).at[tok_of].add(ys.astype(F32) * wf[:, None])
    return out.astype(xf.dtype)


# ---------------------------------------------------------------------------
# gather dispatch — decode-mode (tiny T) kernel path
# ---------------------------------------------------------------------------

def _moe_gather(cfg: ModelConfig, p: dict, xf: jax.Array, w, idx):
    """xf: [T, d]; w/idx: [T, k] (idx in REAL expert space). Dropless.

    Per-token weight-row gather + fused SwiGLU: no argsort/bincount/scatter,
    no per-expert segment padding — the decode-mode specialization
    (``kernels/decode_moe.py``). Per-row arithmetic and the fp32 combine
    match :func:`_moe_ragged` exactly."""
    from repro.kernels import ops as kops
    qt = _quant_tables(p)
    if qt is not None:
        y = kops.gather_swiglu_q(xf, qt, idx, w.astype(F32))
    else:
        y = kops.gather_swiglu(xf, p["wg"], p["wu"], p["wd"], idx,
                               w.astype(F32))
    return y.astype(xf.dtype)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array,
              capture: bool = False, need_aux: bool = True) -> MoEOutput:
    """x: [B, S, d] (or [B, 1, d] for decode).

    ``need_aux=False`` (serving prefill/decode): routing goes through
    :func:`route_infer` — no [.., N] probs materialization, no
    :func:`balance_loss` — and ``aux_loss`` is a constant zero. Training and
    calibration capture keep the full :func:`route` path."""
    m = cfg.moe
    B, S, d = x.shape
    if capture or need_aux:
        w, idx, probs = route(cfg, p, x)
        aux = balance_loss(cfg, probs, idx)
    else:
        w, idx = route_infer(cfg, p, x)
        aux = jnp.zeros((), F32)
    ridx = jnp.take(p["remap"], idx)                 # original -> real experts

    T = B * S
    xf = x.reshape(T, d)
    wf = w.reshape(T, m.top_k)
    rf = ridx.reshape(T, m.top_k)

    if m.ep_axis is not None and m.ep_degree > 1 \
            and m.dispatch in ("gather", "ragged"):
        # Expert-parallel dispatch (DESIGN.md §13): tables are sharded over
        # the ``ep_axis`` mesh axis and this trace is inside a shard_map.
        # The combine-mode selection mirrors the single-device rule below
        # (T here is the per-data-shard slice — smaller than the global
        # count, so a single-device gather-shaped call stays gather-shaped).
        from repro.models.moe_ep import moe_apply_ep
        gather_mode = (m.dispatch == "gather" and S == 1
                       and T <= m.gather_max_tokens)
        y = moe_apply_ep(cfg, p, xf, wf, rf, gather_mode)
    elif m.dispatch == "gather":
        # trace-time selection (shapes are static, so each jit
        # specialization picks exactly one path): gather only for
        # decode-SHAPED calls — one token per sequence (S == 1) and at most
        # ``gather_max_tokens`` of them. Prefill buckets (S > 1) always
        # keep the sort-based grouped kernel, whatever their token count
        # (DESIGN.md §7).
        if S == 1 and T <= m.gather_max_tokens:
            y = _moe_gather(cfg, p, xf, wf, rf)
        else:
            y = _moe_ragged(cfg, p, xf, wf, rf)
    elif m.dispatch == "ragged":
        y = _moe_ragged(cfg, p, xf, wf, rf)
    else:
        G = min(m.group_size, T)
        n_groups = -(-T // G)
        pad = n_groups * G - T
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
            wf = jnp.pad(wf, ((0, pad), (0, 0)))
            rf = jnp.pad(rf, ((0, pad), (0, 0)))
        y = _moe_dense_groups(cfg, p,
                              xf.reshape(n_groups, G, d),
                              wf.reshape(n_groups, G, m.top_k),
                              rf.reshape(n_groups, G, m.top_k))
        y = y.reshape(n_groups * G, d)[:T]

    y = y.reshape(B, S, d)
    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x)

    if capture:
        counts = jnp.sum(
            jax.nn.one_hot(idx.reshape(-1, m.top_k), m.n_experts, dtype=F32),
            axis=(0, 1))
        return MoEOutput(y, aux, x, counts, idx)
    return MoEOutput(y, aux, None, None, None)
