"""MergeMoE expert merging (paper §4) + baselines (§5.1).

Row-major conventions (samples are rows): expert weights wg/wu: [N, d, f],
wd: [N, f, d]; calibration inputs X: [T, d]. The paper's column-major
``T1 P = Q`` least-squares becomes ``P @ T1r ≈ Q`` with ``T1r = lstsq(P, Q)``;
the final down projection is ``T1r @ Wd_blocks``, which collapses to
``lstsq(P, Z)`` with ``Z = Σ_j B_ji E_j(X)`` — the frequency-weighted target
outputs. Both forms are implemented; ``tests/test_merge.py`` asserts they
agree, and the simplified form is the default (it never materializes the
[T, |C|·f] stacked activations).

All solves run in fp64 on host (numpy) — this is the offline compression pass;
model-side compute stays bf16/f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core import clustering as C


@dataclass
class MergeResult:
    wg: np.ndarray        # [M, d, f]
    wu: np.ndarray        # [M, d, f]
    wd: np.ndarray        # [M, f, d]
    remap: np.ndarray     # [N] int32 -> [0, M)
    assign: np.ndarray    # [N] cluster ids (== remap)
    weights: np.ndarray   # [N] intra-cluster merge weights (B entries)
    info: Dict


def _silu(x):
    return x / (1.0 + np.exp(-x))


def expert_forward(X, wg_i, wu_i, wd_i):
    """SwiGLU expert on row-major samples: [T, d] -> [T, d] (fp64)."""
    return (_silu(X @ wg_i) * (X @ wu_i)) @ wd_i


def _ridge_lstsq(P: np.ndarray, Z: np.ndarray, ridge: float) -> np.ndarray:
    """argmin_W ||P W - Z||_F^2 + ridge*tr(WᵀW)·scale ;  P: [T, f], Z: [T, d]."""
    f = P.shape[1]
    G = P.T @ P
    lam = ridge * (np.trace(G) / max(f, 1) + 1e-12)
    return np.linalg.solve(G + lam * np.eye(f), P.T @ Z)


# ---------------------------------------------------------------------------
# MergeMoE (ours)
# ---------------------------------------------------------------------------

def merge_mergemoe(wg, wu, wd, counts, X, M, *, ridge: float = 1e-6,
                   literal_t1: bool = False) -> MergeResult:
    """The paper's method. X: [T, d] calibration inputs for THIS layer."""
    wg = np.asarray(wg, np.float64)
    wu = np.asarray(wu, np.float64)
    wd = np.asarray(wd, np.float64)
    X = np.asarray(X, np.float64)
    N, d, f = wg.shape

    assign = C.cluster_experts(wg, wu, counts, M, metric="weights")
    w = C.merge_weights(assign, counts, M)

    out_g = np.zeros((M, d, f))
    out_u = np.zeros((M, d, f))
    out_d = np.zeros((M, f, d))
    resid = np.zeros(M)
    for c in range(M):
        members = np.where(assign == c)[0]
        wm = w[members]                                   # sums to 1
        # T2/T3 = weighted average (Eq. 4)
        g_m = np.einsum("j,jdf->df", wm, wg[members])
        u_m = np.einsum("j,jdf->df", wm, wu[members])
        # merged intermediate activations P = σ(X g_m) ⊙ (X u_m)
        P = _silu(X @ g_m) * (X @ u_m)                    # [T, f]
        if literal_t1:
            # paper-literal: stack member intermediates Q [T, |C|f], solve
            # T1r = lstsq(P, Q), then wd = T1r @ blockdiag-weighted Wd stack.
            Q = np.concatenate(
                [_silu(X @ wg[j]) * (X @ wu[j]) for j in members], axis=1)
            T1r = _ridge_lstsq(P, Q, ridge)               # [f, |C|f]
            Wd_blocks = np.concatenate(
                [wj * wd[j] for wj, j in zip(wm, members)], axis=0)
            d_m = T1r @ Wd_blocks
        else:
            # simplified (equivalent): solve directly against merged outputs
            Z = np.zeros((X.shape[0], d))
            for wj, j in zip(wm, members):
                Z += wj * expert_forward(X, wg[j], wu[j], wd[j])
            d_m = _ridge_lstsq(P, Z, ridge)               # [f, d]
            resid[c] = float(np.linalg.norm(P @ d_m - Z) /
                             (np.linalg.norm(Z) + 1e-12))
        out_g[c], out_u[c], out_d[c] = g_m, u_m, d_m

    return MergeResult(out_g, out_u, out_d, assign.astype(np.int32), assign, w,
                       info={"method": "mergemoe", "resid": resid})


# ---------------------------------------------------------------------------
# M-SMoE (Li et al., 2023): frequency-weighted PARAMETER averaging
# ---------------------------------------------------------------------------

def merge_msmoe(wg, wu, wd, counts, X, M, *, router=None) -> MergeResult:
    wg = np.asarray(wg, np.float64)
    wu = np.asarray(wu, np.float64)
    wd = np.asarray(wd, np.float64)
    N = wg.shape[0]
    assign = C.cluster_experts(wg, wu, counts, M, router=router,
                               metric="router" if router is not None else "weights")
    w = C.merge_weights(assign, counts, M)
    out = []
    for mat in (wg, wu, wd):
        m = np.zeros((M,) + mat.shape[1:])
        for c in range(M):
            members = np.where(assign == c)[0]
            m[c] = np.einsum("j,j...->...", w[members], mat[members])
        out.append(m)
    return MergeResult(out[0], out[1], out[2], assign.astype(np.int32),
                       assign, w, info={"method": "msmoe"})


# ---------------------------------------------------------------------------
# Average (Choshen et al., 2022 adapted): uniform parameter averaging
# ---------------------------------------------------------------------------

def merge_average(wg, wu, wd, counts, X, M) -> MergeResult:
    N = wg.shape[0]
    assign = C.cluster_experts(wg, wu, counts, M, metric="weights")
    uniform = np.ones(N)
    w = C.merge_weights(assign, uniform, M)   # uniform within cluster
    out = []
    for mat in (np.asarray(wg, np.float64), np.asarray(wu, np.float64),
                np.asarray(wd, np.float64)):
        m = np.zeros((M,) + mat.shape[1:])
        for c in range(M):
            members = np.where(assign == c)[0]
            m[c] = mat[members].mean(axis=0)
        out.append(m)
    return MergeResult(out[0], out[1], out[2], assign.astype(np.int32),
                       assign, w, info={"method": "average"})


# ---------------------------------------------------------------------------
# ZipIt (Stoica et al., 2023 adapted): activation-correlation neuron matching
# ---------------------------------------------------------------------------

def merge_zipit(wg, wu, wd, counts, X, M) -> MergeResult:
    """Adaptation of ZipIt to expert merging: within each cluster, members are
    zipped into the center one at a time; intermediate neurons of the member
    are permuted to the center's most-correlated neurons (greedy match on the
    calibration activations), then frequency-weighted-averaged."""
    wg = np.asarray(wg, np.float64)
    wu = np.asarray(wu, np.float64)
    wd = np.asarray(wd, np.float64)
    X = np.asarray(X, np.float64)
    N, d, f = wg.shape
    assign = C.cluster_experts(wg, wu, counts, M, metric="weights")
    w = C.merge_weights(assign, counts, M)
    cnt = np.asarray(counts, np.float64)

    def acts(i):
        h = _silu(X @ wg[i]) * (X @ wu[i])
        h = h - h.mean(axis=0, keepdims=True)
        n = np.linalg.norm(h, axis=0) + 1e-8
        return h / n

    out_g = np.zeros((M, d, f))
    out_u = np.zeros((M, d, f))
    out_d = np.zeros((M, f, d))
    for c in range(M):
        members = list(np.where(assign == c)[0])
        # center = most used member
        center = members[int(np.argmax(cnt[members]))]
        g_m, u_m, d_m = wg[center].copy(), wu[center].copy(), wd[center].copy()
        mass = max(cnt[center], 1.0)
        base = acts(center)
        for j in members:
            if j == center:
                continue
            corr = base.T @ acts(j)                       # [f, f]
            # greedy one-to-one matching
            perm = np.full(f, -1, np.int64)
            flat = np.argsort(-corr, axis=None)
            used_r, used_c = np.zeros(f, bool), np.zeros(f, bool)
            filled = 0
            for idx in flat:
                r, cc = divmod(int(idx), f)
                if not used_r[r] and not used_c[cc]:
                    perm[r] = cc
                    used_r[r], used_c[cc] = True, True
                    filled += 1
                    if filled == f:
                        break
            wj = max(cnt[j], 1.0)
            a = mass / (mass + wj)
            b = wj / (mass + wj)
            g_m = a * g_m + b * wg[j][:, perm]
            u_m = a * u_m + b * wu[j][:, perm]
            d_m = a * d_m + b * wd[j][perm, :]
            mass += wj
        out_g[c], out_u[c], out_d[c] = g_m, u_m, d_m
    return MergeResult(out_g, out_u, out_d, assign.astype(np.int32),
                       assign, w, info={"method": "zipit"})


# Compatibility view of the registry in repro.core.plan (the canonical home
# of strategy registration); kept so ``for method in MG.METHODS`` call sites
# and the CLI keep working.
METHODS = {
    "mergemoe": merge_mergemoe,
    "msmoe": merge_msmoe,
    "average": merge_average,
    "zipit": merge_zipit,
}


def merge_layer(method: str, wg, wu, wd, counts, X, M, *,
                router=None, **kw) -> MergeResult:
    """Single-layer merge through the strategy registry. Prefer building a
    :class:`repro.core.plan.CompressionPlan` for whole-model compression."""
    from repro.core import plan as PLAN   # local: plan imports this module
    return PLAN.get_strategy(method).merge(wg, wu, wd, counts, X, M,
                                           router=router, **kw)
