"""Core neural layers: RMSNorm, RoPE, GQA attention (train/prefill/decode),
SwiGLU MLP. Pure-functional: ``*_init`` builds a param dict, ``*_apply`` runs it.

Precision policy: parameters stored in ``cfg.dtype`` (default bf16); norms and
softmax run in fp32; matmuls accumulate fp32 via ``preferred_element_type``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.kernels import ops
from repro.models.numerics import ein, ein32, dot as _ndot, constrain, bf16_cotangent

from repro.models.config import ModelConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2], fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=F32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    angles = positions[..., :, None].astype(F32) * inv_freq  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                      # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.param_dtype
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, (d, nq * hd), dt),
        "wk": _dense_init(kk, (d, nkv * hd), dt),
        "wv": _dense_init(kv, (d, nkv * hd), dt),
        "wo": _dense_init(ko, (nq * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    B, S, _ = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = ein("bsd,dh->bsh", x, p["wq"])
    k = ein("bsd,dh->bsh", x, p["wk"])
    v = ein("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.astype(x.dtype).reshape(B, S, nq, hd)
    k = k.astype(x.dtype).reshape(B, S, nkv, hd)
    v = v.astype(x.dtype).reshape(B, S, nkv, hd)
    return q, k, v


def _sdpa(q, k, v, mask, n_rep: int):
    """q:[B,Sq,nq,hd] k,v:[B,Skv,nkv,hd]; GQA expanded to flat heads so the
    whole attention computation is head-parallel on the "model" axis (no
    partial-sum all-reduces). fp32 softmax. The surrounding named_scope lets
    hlo_analysis attribute these buffers for flash-kernel-adjusted traffic
    accounting (the Pallas kernel replaces this on real TPUs)."""
    B, Sq, nq, hd = q.shape
    with jax.named_scope("sdpa"):
        q = bf16_cotangent(constrain(q, "DP", None, "M", None))
        if n_rep > 1:
            # K/V are replicated across "model" (Megatron-GQA); the repeat is
            # local and the head constraint slices each device's share.
            k = jnp.repeat(k, n_rep, axis=2)
            v = jnp.repeat(v, n_rep, axis=2)
        k = bf16_cotangent(constrain(k, "DP", None, "M", None))
        v = bf16_cotangent(constrain(v, "DP", None, "M", None))
        logits = ein32("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        if mask is not None:
            logits = jnp.where(mask, logits, jnp.finfo(F32).min)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = ein("bhqk,bkhd->bqhd", probs, v).astype(v.dtype)
        # pin the attention OUTPUT head-sharded and cut its cotangent to
        # bf16: the backward then reshards the small [B,S,H,hd] cotangent
        # instead of dragging S@M sharding into the f32 [B,H,S,S] logits
        # (which cost ~490 GiB/dev of all-to-all on kimi; §Perf A3)
        out = bf16_cotangent(constrain(out, "DP", None, "M", None))
    return out


def attn_apply(cfg: ModelConfig, p: dict, x: jax.Array, *, inv_freq,
               positions=None, causal: bool = True,
               kv: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross-attention).

    kv: optional encoder output for cross-attention (whisper decoder); when
    given, keys/values come from ``kv`` and no causal mask is used.
    """
    B, S, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if kv is None:
        q, k, v = _qkv(cfg, p, x)
        if positions is None:
            positions = jnp.arange(S)[None, :]
        if inv_freq is not None:
            q = apply_rope(q, positions, inv_freq)
            k = apply_rope(k, positions, inv_freq)
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((S, S), bool))[None, None, :, :]
    else:
        # cross-attention: q from x, k/v from encoder sequence (no RoPE)
        q, _, _ = _qkv(cfg, p, x)
        _, k, v = _qkv(cfg, p, kv)
        mask = None
    out = _sdpa(q, k, v, mask, n_rep)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)


def attn_prefill(cfg: ModelConfig, p: dict, x: jax.Array, *, inv_freq):
    """Causal full-sequence attention that also returns the (k, v) to seed a
    decode cache. Returns (out [B,S,d], k [B,S,nkv,hd], v [B,S,nkv,hd])."""
    B, S, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = jnp.arange(S)[None, :]
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    mask = jnp.tril(jnp.ones((S, S), bool))[None, None, :, :]
    out = _sdpa(q, k, v, mask, n_rep)
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, k, v


def attn_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache_k, cache_v,
                pos: jax.Array, *, inv_freq):
    """Single-token decode with a KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, nkv, hd]; pos: scalar int32 (current
    length). Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = jnp.full((B, 1), pos, jnp.int32)
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    S_max = cache_k.shape[1]
    valid = (jnp.arange(S_max) <= pos)[None, None, None, :]
    out = _sdpa(q, cache_k, cache_v, valid, n_rep)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, cache_k, cache_v


def attn_decode_slots(cfg: ModelConfig, p: dict, x: jax.Array, cache_k,
                      cache_v, pos: jax.Array, *, inv_freq):
    """Single-token decode with PER-SLOT positions (continuous batching).

    Unlike :func:`attn_decode` (one scalar ``pos`` for the whole batch), every
    batch row is an independent serving slot at its own sequence length:
    ``pos[b]`` is the position the new token of slot ``b`` is written to, and
    the causal mask is per-slot. Rows past ``pos[b]`` may hold stale KV from
    an evicted request — they are masked here and each row is rewritten the
    step it becomes current, so stale entries are never attended.

    x: [B, 1, d]; cache_k/v: [B, S_max, nkv, hd]; pos: [B] int32.
    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = pos[:, None]                              # [B, 1]
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    b_iota = jnp.arange(B)
    cache_k = cache_k.at[b_iota, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[b_iota, pos].set(v[:, 0].astype(cache_v.dtype))
    S_max = cache_k.shape[1]
    valid = (jnp.arange(S_max)[None, :] <= pos[:, None])[:, None, None, :]
    out = _sdpa(q, cache_k, cache_v, valid, n_rep)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, cache_k, cache_v


def attn_verify_slots(cfg: ModelConfig, p: dict, x: jax.Array, cache_k,
                      cache_v, pos: jax.Array, *, inv_freq):
    """T-token attention with PER-SLOT positions (speculative verify).

    The multi-position sibling of :func:`attn_decode_slots`: slot ``b``'s
    ``T`` input tokens occupy sequence positions ``pos[b] .. pos[b]+T-1``,
    their KV is scattered into those cache rows, and query ``i`` attends
    rows ``<= pos[b]+i`` (the committed prefix plus the draft prefix up to
    itself). Writes past ``s_max`` fall out of bounds and are DROPPED by
    JAX scatter semantics. That is safe for COMMITTED tokens because the
    engine's capacity check reserves verify headroom: admission enforces
    ``prompt + max_new + spec_k <= s_max + 1`` in speculative mode, so
    every query position whose logits can feed a committed sample is
    ``<= s_max - 1`` and reads only rows that were actually written.
    Without that headroom a near-capacity slot's dropped writes would
    leave verify logits at those positions reading stale KV
    (tests/test_spec_decode.py pins the edge). Rows past the written
    window carry stale KV from evicted requests or rolled-back drafts;
    the per-slot mask hides them, same as the decode path.

    x: [B, T, d]; cache_k/v: [B, S_max, nkv, hd]; pos: [B] int32.
    Returns (out [B,T,d], new_cache_k, new_cache_v).
    """
    B, T, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = pos[:, None] + jnp.arange(T)[None, :]     # [B, T]
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    b_iota = jnp.arange(B)[:, None]
    cache_k = cache_k.at[b_iota, positions].set(k.astype(cache_k.dtype))
    cache_v = cache_v.at[b_iota, positions].set(v.astype(cache_v.dtype))
    S_max = cache_k.shape[1]
    valid = (jnp.arange(S_max)[None, None, :]
             <= positions[:, :, None])[:, None, :, :]     # [B, 1, T, S_max]
    out = _sdpa(q, cache_k, cache_v, valid, n_rep)
    out = out.reshape(B, T, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, cache_k, cache_v


def _paged_write(pool, scales, blk, r, val):
    """Scatter KV rows into the block pool (DESIGN.md §11).

    pool: [n_blocks, bs, nkv, hd] (model dtype, or int8 when ``scales`` is
    given); scales: [n_blocks, bs, nkv] fp32 or None; blk/r: [...] int32
    block ids / in-block rows; val: [..., nkv, hd]. Sentinel block ids
    (``>= n_blocks``) drop by JAX scatter semantics — that single mechanism
    retires frozen slots, admission pads, and over-bucket garbage rows.
    Writable blocks are disjoint across slots (sharers' first writable row
    is block-aligned past the shared chain), so no scatter collisions."""
    if scales is None:
        return pool.at[blk, r].set(val.astype(pool.dtype)), None
    q, s = Q.quantize_kv(val)
    return pool.at[blk, r].set(q), scales.at[blk, r].set(s)


def attn_decode_paged(cfg: ModelConfig, p: dict, x: jax.Array, kp, vp, ks,
                      vs, tab: jax.Array, pos: jax.Array, *, inv_freq):
    """Single-token decode over the paged KV pool (DESIGN.md §11).

    The paged sibling of :func:`attn_decode_slots`: same per-slot positions
    and mask, but KV rows live in a flat block pool indexed through a
    per-slot block table, and the attention itself goes through the
    ``ops.paged_attention`` dispatch (Pallas kernel on TPU; on CPU the jnp
    oracle, which mirrors :func:`_sdpa` on the gathered view bit for bit —
    masked rows get probability exactly 0, so bf16 paged decode equals the
    dense slot cache bitwise).

    x: [B, 1, d]; kp/vp: [n_blocks, bs, nkv, hd] (int8 when ks/vs given);
    ks/vs: [n_blocks, bs, nkv] fp32 scales or None; tab: [B, mb] int32
    (sentinel = n_blocks); pos: [B] int32. Returns (out, kp, vp, ks, vs).
    """
    B = x.shape[0]
    q, k, v = _qkv(cfg, p, x)
    positions = pos[:, None]                              # [B, 1]
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    nb, bs = kp.shape[0], kp.shape[1]
    mb = tab.shape[1]
    s_max = mb * bs
    j = jnp.minimum(pos // bs, mb - 1)
    blk = jnp.where(pos < s_max, tab[jnp.arange(B), j], nb)
    r = pos % bs
    kp, ks = _paged_write(kp, ks, blk, r, k[:, 0])
    vp, vs = _paged_write(vp, vs, blk, r, v[:, 0])
    lens = pos + 1
    if ks is None:
        out = ops.paged_attention(q[:, 0], kp, vp, tab, lens)
    else:
        out = ops.paged_attention_q(q[:, 0], kp, vp, ks, vs, tab, lens)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, kp, vp, ks, vs


def attn_verify_paged(cfg: ModelConfig, p: dict, x: jax.Array, kp, vp, ks,
                      vs, tab: jax.Array, pos: jax.Array, *, inv_freq):
    """T-token attention over the paged KV pool (verify AND admission).

    The paged sibling of :func:`attn_verify_slots`, and ALSO the paged
    admission forward: admitting a prompt suffix at base positions
    ``pos[b]`` (the shared-prefix row count) is exactly a verify-shaped
    forward whose KV scatters land in the slot's freshly reserved blocks.
    Prefill-shaped (T > 1, no kernel): the pool is gathered through the
    table into a contiguous ``[B, s_max]`` view — dequantized through
    ``quant.dequantize_kv`` when the pool is int8, the SAME helper the
    decode oracle uses, so verify and decode see one KV representation —
    and attention is the exact :func:`_sdpa` arithmetic of the dense path.
    Sentinel table entries clip into range; their rows are masked.

    x: [B, T, d]; pools/tab as :func:`attn_decode_paged`; pos: [B] int32.
    Returns (out [B, T, d], kp, vp, ks, vs).
    """
    B, T, _ = x.shape
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x)
    positions = pos[:, None] + jnp.arange(T)[None, :]     # [B, T]
    if inv_freq is not None:
        q = apply_rope(q, positions, inv_freq)
        k = apply_rope(k, positions, inv_freq)
    nb, bs = kp.shape[0], kp.shape[1]
    mb = tab.shape[1]
    s_max = mb * bs
    j = jnp.minimum(positions // bs, mb - 1)
    b_iota = jnp.arange(B)[:, None]
    blk = jnp.where(positions < s_max, tab[b_iota, j], nb)
    r = positions % bs
    kp, ks = _paged_write(kp, ks, blk, r, k)
    vp, vs = _paged_write(vp, vs, blk, r, v)
    tabc = jnp.clip(tab, 0, nb - 1)
    kc = kp[tabc].reshape(B, s_max, cfg.n_kv_heads, cfg.hd)
    vc = vp[tabc].reshape(B, s_max, cfg.n_kv_heads, cfg.hd)
    if ks is not None:
        kc = Q.dequantize_kv(
            kc, ks[tabc].reshape(B, s_max, cfg.n_kv_heads), x.dtype)
        vc = Q.dequantize_kv(
            vc, vs[tabc].reshape(B, s_max, cfg.n_kv_heads), x.dtype)
    valid = (jnp.arange(s_max)[None, None, :]
             <= positions[:, :, None])[:, None, :, :]     # [B, 1, T, s_max]
    out = _sdpa(q, kc, vc, valid, n_rep)
    out = out.reshape(B, T, cfg.n_heads * cfg.hd)
    out = ein("bsh,hd->bsd", out, p["wo"]).astype(x.dtype)
    return out, kp, vp, ks, vs


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(d_model: int, d_ff: int, dtype, key) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": _dense_init(kg, (d_model, d_ff), dtype),
        "wu": _dense_init(ku, (d_model, d_ff), dtype),
        "wd": _dense_init(kd, (d_ff, d_model), dtype),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    g = ein("...d,df->...f", x, p["wg"])
    u = ein("...d,df->...f", x, p["wu"])
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return ein("...f,fd->...d", h, p["wd"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def embed_init(cfg: ModelConfig, key) -> dict:
    ke, kh = jax.random.split(key)
    dt = cfg.param_dtype
    p = {"tok": _dense_init(ke, (cfg.vocab_size, cfg.d_model), dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(kh, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed_apply(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def lm_head(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = ein32("...d,vd->...v", x, p["tok"])
    else:
        logits = ein32("...d,dv->...v", x, p["head"])
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits
