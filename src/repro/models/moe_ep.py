"""Expert-parallel MoE dispatch: all-to-all pair exchange (DESIGN.md §13).

Runs INSIDE a ``shard_map`` over the mesh axis named by
``cfg.moe.ep_axis``. The expert tables (and ``qexp`` int8 leaves) are
partitioned on that axis — shard ``s`` stores global rows
``[s*E_l, (s+1)*E_l)`` — while tokens arrive replicated across it. The
dataflow per MoE layer:

1. slice my 1/ep of the (padded) token rows — every shard routes the same
   replicated activations, so slicing is free of communication;
2. scatter each (token, j) routed pair into a per-destination send buffer
   ``[ep, C, d]`` (owner = global_id // E_l) and ``lax.all_to_all`` it;
3. run the LOCAL ``gather_swiglu(_q)`` kernel at k=1 over the received
   rows — the per-pair outputs are exactly the per-row terms the
   single-device kernel computes (per-row einsum arithmetic is
   batch-size- and kernel-invariant on this backend; the spec-decode
   bitwise guarantee of §10 is built on the same fact);
4. return the pair outputs via a second all-to-all (fp32-exact wire) or,
   opt-in, an int8 ``compressed_psum`` of the full pair table
   (``combine_wire_dtype='int8'``, tolerance-gated);
5. combine at each token's home slice with the SAME fp32 expression the
   jnp oracles use (``jnp.sum`` over k in gather mode; stable
   expert-sorted scatter-add in ragged mode), then ``all_gather`` the
   token rows back.

Why all-to-all and not all-gather: the a2a payload per token is
``k * d * act_bytes`` each way — independent of E — while all-gathering
activations so every shard can route locally would ship ``ep`` copies of
every token and still leave the combine partial. The a2a exchanges only
the routed pairs, which is also the quantity the interconnect traffic
model meters (``launch/hlo_analysis.decode_traffic_model``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig

F32 = jnp.float32


def moe_apply_ep(cfg: ModelConfig, p: dict, xf: jax.Array, wf: jax.Array,
                 rf: jax.Array, gather_mode: bool) -> jax.Array:
    """EP dispatch for one MoE layer.

    xf: [T, d] tokens (replicated over ``ep_axis``); wf/rf: [T, k] combine
    weights / REAL-expert ids from the replicated router. Returns [T, d]
    replicated — bitwise equal to the single-device ``_moe_gather`` /
    ``_moe_ragged`` result when the wire dtype is fp32.
    """
    from repro.kernels import ops as kops
    from repro.models.moe import n_real_experts, _quant_tables

    m = cfg.moe
    ep, ax = m.ep_degree, m.ep_axis
    T, d = xf.shape
    k = m.top_k
    e_loc = n_real_experts(p)            # LOCAL table rows under shard_map
    me = lax.axis_index(ax)

    # Pad so every shard owns an equal token slice. Pad rows carry x = 0,
    # expert 0, weight 0: they compute SwiGLU(0) = 0 wherever they land and
    # are dropped by the final [:T] slice.
    Tl = -(-T // ep)
    Tp = Tl * ep
    if Tp != T:
        xf = jnp.pad(xf, ((0, Tp - T), (0, 0)))
        wf = jnp.pad(wf, ((0, Tp - T), (0, 0)))
        rf = jnp.pad(rf, ((0, Tp - T), (0, 0)))
    x_my = lax.dynamic_slice_in_dim(xf, me * Tl, Tl, axis=0)
    w_my = lax.dynamic_slice_in_dim(wf, me * Tl, Tl, axis=0)
    r_my = lax.dynamic_slice_in_dim(rf, me * Tl, Tl, axis=0)

    # --- dispatch: pair -> owning shard -----------------------------------
    C = Tl * k                           # per-destination capacity (worst
    rp = r_my.reshape(C)                 # case: every pair one owner)
    owner = rp // e_loc                  # [C] destination shard per pair
    oh = (owner[:, None] == jnp.arange(ep)[None, :]).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              owner[:, None], axis=1)[:, 0]
    xpairs = jnp.take(x_my, jnp.arange(C) // k, axis=0)        # [C, d]

    send_x = jnp.zeros((ep, C, d), xf.dtype).at[owner, pos].set(xpairs)
    send_e = jnp.zeros((ep, C), jnp.int32).at[owner, pos].set(rp)
    recv_x = lax.all_to_all(send_x, ax, 0, 0, tiled=True)      # [ep, C, d]
    recv_e = lax.all_to_all(send_e, ax, 0, 0, tiled=True)      # [ep, C]

    # --- local expert compute (k = 1 per received pair) -------------------
    # Unwritten buffer rows hold x = 0 / global id 0; the sharded wrapper
    # zeroes the weight of any id outside [e_base, e_base + e_loc), so both
    # kinds of non-pair rows contribute exactly fp 0.0.
    flat_x = recv_x.reshape(ep * C, d)
    flat_e = recv_e.reshape(ep * C, 1)
    ones = jnp.ones((ep * C, 1), F32)
    e_base = me * e_loc
    qt = _quant_tables(p)
    if qt is not None:
        y = kops.gather_swiglu_q_sharded(flat_x, qt, flat_e, ones, e_base)
    else:
        y = kops.gather_swiglu_sharded(flat_x, p["wg"], p["wu"], p["wd"],
                                       flat_e, ones, e_base)
    y = y.astype(xf.dtype)               # [ep*C, d] per-pair outputs

    # --- return wire ------------------------------------------------------
    if m.combine_wire_dtype == "int8":
        # Opt-in int8 wire: every shard contributes its computed pairs to a
        # zero-elsewhere [origin, owner, pos] table; compressed_psum ships
        # int8 + one shared scale and sums to the replicated full table
        # (tolerance-gated — stochastic rounding breaks bitwise parity).
        from repro.distributed.compression import compressed_psum
        contrib = lax.dynamic_update_slice(
            jnp.zeros((ep, ep, C, d), F32),
            y.reshape(ep, 1, C, d).astype(F32),
            (jnp.int32(0), me, jnp.int32(0), jnp.int32(0)))
        key = jax.random.PRNGKey(m.combine_wire_seed)
        full = compressed_psum(contrib, ax, key)
        mine = lax.dynamic_slice_in_dim(full, me, 1, axis=0)[0]
        y_pairs = mine[owner, pos].astype(xf.dtype)            # [C, d]
    else:
        # fp32-exact wire: a2a the pair outputs straight back; y_ret[o, p]
        # is my pair p as computed by owner o.
        y_ret = lax.all_to_all(y.reshape(ep, C, d), ax, 0, 0, tiled=True)
        y_pairs = y_ret[owner, pos]                            # [C, d]

    # --- combine (oracle-exact fp32 expressions) --------------------------
    if gather_mode:
        out = jnp.sum(y_pairs.reshape(Tl, k, d).astype(F32)
                      * w_my.reshape(Tl, k, 1).astype(F32), axis=1)
        out = out.astype(xf.dtype)
    else:
        # mirror _moe_ragged's expert-sorted stable scatter-add: restricted
        # to any token slice the per-token add order is (expert asc, j asc)
        # in both, so the fp32 partial sums agree term for term.
        order = jnp.argsort(r_my.reshape(-1))
        tok_of = order // k
        wf_o = w_my.reshape(-1)[order].astype(F32)
        out = jnp.zeros((Tl, d), F32).at[tok_of].add(
            y_pairs[order].astype(F32) * wf_o[:, None])
        out = out.astype(xf.dtype)

    yg = lax.all_gather(out, ax, axis=0, tiled=True)           # [Tp, d]
    return yg[:T]
