"""Deterministic fault injection + engine resilience (DESIGN.md §12):
seeded FaultPlan purity (same seed -> same fault trace), NaN-poisoned
logits quarantined by the numeric-health sentinel with healthy slots
bitwise untouched, bounded transient-failure retry, injected pool
exhaustion -> deferral -> pool-pressure shedding, deadline/TTL expiry,
and bounded-queue backpressure policies.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.core import errors as ERR
from repro.models import model as MD
from repro.serving import Engine, EngineConfig
from repro.serving.faults import FaultPlan, FaultSpec

ARCH = "qwen3-moe-30b-a3b"
P, NEW = 8, 10


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=P, dtype=np.int32)
               for _ in range(2)]
    return cfg, params, prompts


def _engine(cfg, params, *, faults=None, n_slots=2, **kw):
    ec = dict(arch=ARCH, n_slots=n_slots, s_max=32, prefill_buckets=(P,))
    ec.update(kw)
    return Engine(EngineConfig(**ec), cfg=cfg, params=params, faults=faults)


@pytest.fixture(scope="module")
def clean(setup):
    """Fault-free fused-block reference run: uid -> out_tokens."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params)
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    done = eng.run()
    assert all(r.status == "ok" for r in done)
    assert eng.counters["shed"] == eng.counters["quarantined"] == 0
    assert eng.counters["transient_retries"] == 0
    return {r.uid: list(r.out_tokens) for r in done}


# ---------------------------------------------------------------------------
# FaultPlan: pure, seeded, replayable (no engine, no device)
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="scheduler", kind="transient")
    with pytest.raises(ValueError, match="not injectable"):
        FaultSpec(site="alloc", kind="nan_logits")
    with pytest.raises(ValueError, match="outside"):
        FaultSpec(site="decode", kind="transient", p=1.5)
    with pytest.raises(ValueError, match="fails"):
        FaultSpec(site="decode", kind="transient", fails=0)


def _drive(plan):
    """Fixed consultation sequence standing in for an engine trace."""
    for step in range(0, 64, 8):
        plan.poison_mask(step, 8, n_slots=4)
        plan.transient_failures("decode", step)
        plan.exhausted(step)
    plan.corrupt(b"0123456789abcdef", step=0)
    return plan.trace_digest()


def test_same_seed_replays_identical_fault_trace():
    specs = (FaultSpec(site="decode", kind="nan_logits", p=0.3),
             FaultSpec(site="decode", kind="transient", p=0.2, fails=2),
             FaultSpec(site="alloc", kind="exhaust", p=0.25),
             FaultSpec(site="ckpt", kind="corrupt", steps=(0,)))
    d1 = _drive(FaultPlan(seed=7, specs=specs))
    d2 = _drive(FaultPlan(seed=7, specs=specs))
    assert d1 == d2
    assert _drive(FaultPlan(seed=8, specs=specs)) != d1
    # probabilistic firings actually fired (p=0.3 over 32 decode consults)
    plan = FaultPlan(seed=7, specs=specs)
    _drive(plan)
    assert plan.counts().get("nan_logits", 0) >= 1


def test_poison_mask_covers_the_fused_block_span():
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="decode", kind="nan_logits", steps=(5,),
                  slots=(1,)),))
    assert plan.poison_mask(0, 8, 4).tolist() == [False, True, False, False]
    assert not plan.poison_mask(8, 8, 4).any()     # 5 not in [8, 16)
    assert plan.poison_mask(5, 1, 4)[1]            # step loop, exact step
    assert not plan.poison_mask(4, 1, 4).any()


def test_poison_mask_hash_picks_a_slot_when_unpinned():
    plan = FaultPlan(seed=3, specs=(
        FaultSpec(site="decode", kind="nan_logits", steps=(2,)),))
    m1 = plan.poison_mask(0, 8, 4)
    m2 = FaultPlan(seed=3, specs=plan.specs).poison_mask(0, 8, 4)
    assert m1.sum() == 1 and (m1 == m2).all()      # seed-stable pick


def test_transient_failures_sum_over_firing_specs():
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="decode", kind="transient", steps=(8,), fails=2),
        FaultSpec(site="admit", kind="transient", steps=(8,), fails=1)))
    assert plan.transient_failures("decode", 8) == 2
    assert plan.transient_failures("admit", 8) == 1
    assert plan.transient_failures("decode", 16) == 0


def test_corrupt_is_pure_and_deterministic():
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="ckpt", kind="corrupt", steps=(0,),
                  byte_offsets=(3, 100)),))
    data = bytes(range(16))
    out = plan.corrupt(data, step=0)
    assert data == bytes(range(16))                # input untouched
    assert out[3] == data[3] ^ 1
    assert out[100 % 16] == data[100 % 16] ^ 1     # offsets wrap
    assert plan.corrupt(data, step=0) == out
    assert plan.corrupt(data, step=5) == data      # non-firing step: no-op


# ---------------------------------------------------------------------------
# numeric-health sentinel: quarantine without collateral damage
# ---------------------------------------------------------------------------

def _nan_plan(slots=(0,), steps=(2,)):
    return FaultPlan(seed=0, specs=(
        FaultSpec(site="decode", kind="nan_logits", steps=steps,
                  slots=slots),))


@pytest.mark.parametrize("decode_block", [8, 1],
                         ids=["fused-block", "step-loop"])
def test_nan_quarantine_healthy_slots_bitwise(setup, clean, decode_block):
    """A poisoned slot is evicted ``failed_numeric`` with its tokens
    truncated at the fault (a bitwise PREFIX of its fault-free stream);
    the co-resident healthy slot's stream is bitwise identical to the
    fault-free run — quarantine has no blast radius."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, faults=_nan_plan(),
                  decode_block=decode_block)
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    done = {r.uid: r for r in eng.run()}
    bad, good = done[0], done[1]
    assert bad.status == "failed_numeric"
    assert bad.finish_reason == "numeric"
    assert 1 <= len(bad.out_tokens) < len(clean[0])
    assert bad.out_tokens == clean[0][:len(bad.out_tokens)]
    assert good.status == "ok"
    assert good.out_tokens == clean[1]
    assert eng.counters["quarantined"] == 1
    # the plan's record of what fired matches what the engine observed
    assert eng._faults.counts() == {"nan_logits": 1}


def test_nan_quarantine_strict_raises_after_cleanup(setup, clean):
    """Strict mode raises NumericHealthError AFTER evicting the poisoned
    slot, leaving a consistent engine: the healthy slot finishes bitwise
    clean on the next run() call."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, faults=_nan_plan(),
                  numeric_sentinel="strict")
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    with pytest.raises(ERR.NumericHealthError, match="uid"):
        eng.run()
    assert eng.counters["quarantined"] == 1
    done = {r.uid: r for r in eng.run()}           # drain the survivors
    assert done[1].status == "ok"
    assert list(done[1].out_tokens) == clean[1]


def test_sentinel_off_serves_poisoned_garbage(setup):
    """The ladder's floor: with the sentinel off the finite lane is
    ignored, nothing quarantines, and the poisoned request terminates
    'ok' — the mode exists to demonstrate exactly the failure the
    default 'count' mode prevents."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, faults=_nan_plan(), numeric_sentinel="off")
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    done = {r.uid: r for r in eng.run()}
    assert eng.counters["quarantined"] == 0
    assert done[0].status == done[1].status == "ok"
    assert len(done[0].out_tokens) == NEW


def test_quarantine_releases_paged_blocks(setup):
    """In the paged layout a quarantined slot's whole reservation returns
    to the pool — a numeric fault must not leak KV blocks."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, faults=_nan_plan(), kv_layout="paged",
                  kv_block=16)
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    done = {r.uid: r for r in eng.run()}
    assert done[0].status == "failed_numeric"
    assert eng._alloc.free_blocks == eng._alloc.nb   # nothing leaked
    eng._alloc.check_invariants()


# ---------------------------------------------------------------------------
# transient device failures: bounded retry
# ---------------------------------------------------------------------------

def test_transient_failures_retried_within_budget(setup, clean):
    """Injected transient decode failures within the retry budget are
    absorbed: the retries are counted and the output is bitwise identical
    to the fault-free run (a retry re-issues the same pure call)."""
    cfg, params, prompts = setup
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="decode", kind="transient", steps=(8,), fails=2),))
    eng = _engine(cfg, params, faults=plan, device_retries=2)
    for p in prompts:
        eng.submit(p, max_new_tokens=NEW)
    done = {r.uid: r for r in eng.run()}
    assert eng.counters["transient_retries"] == 2
    assert {u: list(r.out_tokens) for u, r in done.items()} == clean
    assert all(r.status == "ok" for r in done.values())


def test_transient_failures_beyond_budget_raise(setup):
    cfg, params, prompts = setup
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="decode", kind="transient", steps=(0,), fails=3),))
    eng = _engine(cfg, params, faults=plan, device_retries=2)
    eng.submit(prompts[0], max_new_tokens=NEW)
    with pytest.raises(ERR.DeviceStepError, match="device_retries=2"):
        eng.run()
    assert eng.counters["transient_retries"] == 2  # budget fully consumed


# ---------------------------------------------------------------------------
# deadlines, injected pool exhaustion, backpressure
# ---------------------------------------------------------------------------

def test_injected_exhaustion_defers_then_sheds_pool_pressure(setup):
    """Injected allocator exhaustion defers the FIFO head; when the
    deferral outlives its deadline the request sheds with reason
    'pool_pressure' — the §12 deferral-aware expiry, exercised without
    needing a real pool squeeze."""
    cfg, params, prompts = setup
    plan = FaultPlan(seed=0, specs=(
        FaultSpec(site="alloc", kind="exhaust", steps=tuple(range(0, 9))),))
    eng = _engine(cfg, params, faults=plan, n_slots=1)
    req = eng.submit(prompts[0], max_new_tokens=4, ttl=6.0)
    done = eng.run()
    assert [r.uid for r in done] == [req.uid]
    assert req.status == "shed"
    assert req.shed_reason == "pool_pressure"
    assert req.deferred and req.finish_reason == "shed"
    assert req.out_tokens == []
    assert eng.counters["shed"] == 1
    assert "exhaust" in eng._faults.counts()


def test_deadline_expiry_sheds_with_deadline_reason(setup):
    """A request that expires waiting behind a busy slot (never deferred
    by the allocator) sheds with the plain 'deadline' reason."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, n_slots=1)
    r0 = eng.submit(prompts[0], max_new_tokens=16)     # occupies the slot
    r1 = eng.submit(prompts[1], max_new_tokens=4, ttl=4.0)
    done = {r.uid: r for r in eng.run()}
    assert done[r0.uid].status == "ok"
    assert done[r1.uid].status == "shed"
    assert done[r1.uid].shed_reason == "deadline"
    assert eng.counters["shed"] == 1


def test_backpressure_reject_new(setup):
    cfg, params, prompts = setup
    eng = _engine(cfg, params, max_pending=1)
    eng.submit(prompts[0], max_new_tokens=2, arrival_time=100.0)
    with pytest.raises(ERR.QueueFullError, match="reject_new"):
        eng.submit(prompts[1], max_new_tokens=2, arrival_time=100.0)


def test_backpressure_shed_expired_makes_room(setup):
    """shed_expired: a full queue first sheds already-expired pending
    requests (they could never be admitted), admits the newcomer, and
    run() still reports the shed request exactly once."""
    cfg, params, prompts = setup
    eng = _engine(cfg, params, max_pending=1, backpressure="shed_expired")
    stale = eng.submit(prompts[0], max_new_tokens=2, deadline=-1.0)
    live = eng.submit(prompts[1], max_new_tokens=2)
    assert stale.status == "shed" and stale.shed_reason == "deadline"
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {stale.uid, live.uid}
    assert done[live.uid].status == "ok"
    # still full of LIVE work -> reject
    eng.submit(prompts[0], max_new_tokens=2, arrival_time=100.0)
    with pytest.raises(ERR.QueueFullError):
        eng.submit(prompts[1], max_new_tokens=2, arrival_time=100.0)
