"""Uniform vs budget-planned per-layer compression at MATCHED ratios.

For each uniform budget M in the sweep, the budget planner is asked to hit
the same live-byte compression ratio but may spread the expert budget
unevenly across the suffix layers (squeezing low-routing-entropy layers
harder, per the calibration stats). Both plans execute against the SAME
calibration stream and the same held-out eval batches; the report seeds the
perf trajectory for per-layer allocation:

    PYTHONPATH=src python benchmarks/compress_bench.py --layers 4

Writes ``BENCH_compress.json``: per matched ratio, the loss delta, live /
padded bytes, and merge wall-time of each strategy. (At smoke scale a
random-init model routes near-uniformly, so the planner may legitimately
reproduce the uniform allocation; on trained checkpoints with skewed routing
the per-layer budgets diverge — ``test_planner_respects_importance_stats``
pins that behavior.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro import configs
from repro.core import calibration as CAL
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.launch.compress import eval_loss, make_batches
from repro.models import model as MD


def _record(cfg, params, plan, stream, evalb, base_loss, label):
    ncfg, nparams, info = CMP.compress_with_plan(cfg, params, plan,
                                                 stream=stream)
    loss = eval_loss(ncfg, nparams, evalb)
    rec = {
        "label": label,
        "merged_per_layer": list(plan.merged_per_layer),
        "compression_ratio": round(info["compression_ratio"], 4),
        "bytes_compressed": info["bytes_compressed"],
        "bytes_padded": info["bytes_padded"],
        "t_merge_s": round(info["t_merge_s"], 3),
        "loss": round(loss, 4),
        "loss_delta": round(loss - base_loss, 4),
    }
    print(f"  [{label:>8}] M={rec['merged_per_layer']} "
          f"ratio={rec['compression_ratio']:.3f} "
          f"Δloss={rec['loss_delta']:+.4f} merge={rec['t_merge_s']}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--layers", type=int, default=4,
                    help="stack depth (reduced config is rebuilt at this "
                         "depth so per-layer allocation has room to differ)")
    ap.add_argument("--split", type=int, default=1)
    ap.add_argument("--uniform-m", type=int, nargs="+", default=[6, 4, 2])
    ap.add_argument("--calib-batches", type=int, default=2)
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(Path(__file__).with_name(
        "BENCH_compress.json")))
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced().replace(n_layers=args.layers)
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))
    calib = make_batches(cfg, args.calib_batches, seed=args.seed + 100)
    evalb = make_batches(cfg, args.eval_batches, seed=args.seed + 200)

    stream = CAL.CalibrationStream(cfg, params, seed=args.seed).consume(calib)
    base_loss = eval_loss(cfg, params, evalb)
    print(f"== compress_bench: {cfg.name} L={args.layers} "
          f"split={args.split} base loss {base_loss:.4f} ==")

    rows = []
    for m in args.uniform_m:
        uni = PLAN.uniform(cfg, merged_experts=m, split=args.split)
        # matched live-byte target under the planner's own byte model
        target = PLAN.plan_live_ratio(cfg, uni)
        print(f"-- matched ratio {target:.3f} (uniform M={m}) --")
        u = _record(cfg, params, uni, stream, evalb, base_loss, "uniform")
        planned = PLAN.for_target_ratio(cfg, target_ratio=target,
                                        stats=stream.stats(),
                                        split=args.split)
        p = _record(cfg, params, planned, stream, evalb, base_loss, "planned")
        rows.append({"uniform_m": m, "target_ratio": round(target, 4),
                     "uniform": u, "planned": p})

    out = {
        "arch": args.arch, "n_layers": args.layers, "split": args.split,
        "n_experts": cfg.moe.n_experts,
        "calib_tokens": stream.n_tokens,
        "loss_full": round(base_loss, 4),
        "sweep": rows,
    }
    Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
