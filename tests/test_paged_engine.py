"""Paged + int8 KV cache through the serving engine (DESIGN.md §11).

The tentpole contract: with ``kv_layout='paged'`` the engine holds KV in a
flat block pool addressed through the host allocator's table, and in bf16 it
is TOKEN-FOR-TOKEN identical to the dense slot cache on a staggered Poisson
trace — for plain (K=1), fused-block (K=8), and speculative (K=4) decode.
Paged bf16 attention sums exact fp zeros over masked rows, so there is no
tolerance to hide behind. Int8 pools are tolerance territory (the bench
gates teacher-forced top-1); here the int8 engine's own bitwise
self-consistency across decode modes is asserted instead, plus prefix
sharing, eviction reclaim, deferral under pool pressure, and config
validation.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import compress as CMP
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace

ARCH = "qwen3-moe-30b-a3b"
N_SLOTS, P, NEW = 4, 16, 8
S_MAX = P + NEW + 8
KV_BLOCK = 8


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get(ARCH).reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(3, P + 1))).astype(np.int32)
               for _ in range(8)]
    arrivals = poisson_trace(len(prompts), rate=0.5, seed=1)
    return cfg, params, ncfg, nparams, prompts, arrivals


def _run(setup, draft=False, **ec_kw):
    cfg, params, ncfg, nparams, prompts, arrivals = setup
    kw = dict(draft_cfg=ncfg, draft_params=nparams) if draft else {}
    eng = Engine(EngineConfig(arch=ARCH, n_slots=N_SLOTS, s_max=S_MAX,
                              prefill_buckets=(P,), **ec_kw),
                 cfg=cfg, params=params, **kw)
    for p, a in zip(prompts, arrivals):
        eng.submit(p, max_new_tokens=NEW, arrival_time=float(a))
    done = eng.run()
    return {r.uid: r.out_tokens for r in done}, eng


@pytest.mark.parametrize("mode", ["plain", "block", "spec"])
def test_paged_bf16_matches_dense_bitwise(setup, mode):
    """bf16 paged == dense, token for token, in every decode mode — and the
    trace guard stays clean (no retraces, no implicit transfers)."""
    ec = {"plain": dict(decode_block=1),
          "block": dict(decode_block=8),
          "spec": dict(spec_k=4)}[mode]
    draft = mode == "spec"
    ref, _ = _run(setup, draft=draft, **ec)
    out, eng = _run(setup, draft=draft, kv_layout="paged",
                    kv_block=KV_BLOCK, **ec)
    assert out == ref
    assert eng.counters["retraces"] == 0
    assert eng.counters["implicit_transfers"] == 0
    assert eng.kv_dtype_served == "bf16"


def test_paged_int8_selfconsistent_across_decode_modes(setup):
    """The int8 pool is one KV representation (decode and verify both
    dequantize through quant.dequantize_kv), so the int8-paged engine must
    agree with ITSELF bitwise across plain and fused-block decode — the
    quantization error moves the tokens, never the cross-mode contract.
    Quality vs bf16 is the bench's teacher-forced top-1 gate, not a test."""
    a, ea = _run(setup, decode_block=1, kv_layout="paged",
                 kv_block=KV_BLOCK, kv_dtype="int8")
    b, eb = _run(setup, decode_block=8, kv_layout="paged",
                 kv_block=KV_BLOCK, kv_dtype="int8")
    assert a == b
    assert ea.kv_dtype_served == "int8"
    assert eb.counters["retraces"] == 0
    # the served-config traffic model reflects the thinner KV stream
    t8 = ea.modeled_decode_traffic()
    tref = Engine(EngineConfig(arch=ARCH, n_slots=N_SLOTS, s_max=S_MAX,
                               prefill_buckets=(P,)),
                  cfg=setup[0], params=setup[1]).modeled_decode_traffic()
    assert t8["kv_bytes_per_token"] < tref["kv_bytes_per_token"]


def test_prefix_sharing_hits_and_outputs_identical(setup):
    """Identical prompts admitted one after another adopt the first copy's
    registered blocks (hits counted, rows shared) and decode identical
    tokens — shared rows are read-identical by construction."""
    cfg, params = setup[0], setup[1]
    prompt = np.random.default_rng(9).integers(
        1, cfg.vocab_size, size=P).astype(np.int32)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=N_SLOTS, s_max=S_MAX,
                              prefill_buckets=(P,), decode_block=8,
                              kv_layout="paged", kv_block=KV_BLOCK),
                 cfg=cfg, params=params)
    for i in range(6):
        eng.submit(prompt, max_new_tokens=NEW, arrival_time=float(i * 4))
    done = eng.run()
    outs = [r.out_tokens for r in done]
    assert all(o == outs[0] for o in outs)
    stats = eng.paging_stats
    assert stats["prefix_hits"] >= 4
    # full blocks strictly below the last prompt token, per hit
    assert stats["prefix_rows_shared"] == \
        stats["prefix_hits"] * ((P - 1) // KV_BLOCK) * KV_BLOCK


def test_prefix_sharing_disabled_never_hits(setup):
    cfg, params = setup[0], setup[1]
    prompt = np.random.default_rng(10).integers(
        1, cfg.vocab_size, size=P).astype(np.int32)
    eng = Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=S_MAX,
                              prefill_buckets=(P,), kv_layout="paged",
                              kv_block=KV_BLOCK, prefix_sharing=False),
                 cfg=cfg, params=params)
    for i in range(3):
        eng.submit(prompt, max_new_tokens=4, arrival_time=float(i * 6))
    eng.run()
    assert eng.paging_stats["prefix_hits"] == 0


def test_eviction_returns_blocks_to_pool(setup):
    """After every request finishes, the pool is fully reclaimed up to the
    blocks the prefix registry deliberately pins."""
    out, eng = _run(setup, decode_block=8, kv_layout="paged",
                    kv_block=KV_BLOCK, prefix_sharing=False)
    assert len(out) == len(setup[4])
    assert eng.paging_stats["free_blocks"] == eng._alloc.nb
    eng._alloc.check_invariants()


def test_deferral_under_pool_pressure_preserves_outputs(setup):
    """A pool too small for all slots at once forces admission deferrals;
    every request must still finish with tokens bitwise equal to the dense
    engine's (deferral delays admission, never corrupts it)."""
    cfg, params, _, _, prompts, arrivals = setup
    ref, _ = _run(setup, decode_block=8)
    # enough blocks for ~2 full requests: ceil((P+NEW-1)/KV_BLOCK) = 3 each
    eng = Engine(EngineConfig(arch=ARCH, n_slots=N_SLOTS, s_max=S_MAX,
                              prefill_buckets=(P,), decode_block=8,
                              kv_layout="paged", kv_block=KV_BLOCK,
                              kv_blocks=7, prefix_sharing=False),
                 cfg=cfg, params=params)
    for p, a in zip(prompts, arrivals):
        eng.submit(p, max_new_tokens=NEW, arrival_time=float(a))
    done = eng.run()
    assert {r.uid: r.out_tokens for r in done} == ref
    assert eng.paging_stats["deferrals"] > 0
    assert eng.paging_stats["free_blocks"] == 7


def test_paged_config_validation(setup):
    cfg, params = setup[0], setup[1]
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                            prefill_buckets=(8,), kv_dtype="int8"),
               cfg=cfg, params=params)          # int8 needs the paged pool
    with pytest.raises(ValueError, match="kv_layout"):
        Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=32,
                            prefill_buckets=(8,), kv_layout="ring"),
               cfg=cfg, params=params)
    with pytest.raises(ValueError, match="multiple of"):
        Engine(EngineConfig(arch=ARCH, n_slots=2, s_max=30,
                            prefill_buckets=(8,), kv_layout="paged",
                            kv_block=16),
               cfg=cfg, params=params)          # s_max % kv_block != 0
