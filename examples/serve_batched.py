"""End-to-end serving driver: batched requests through prefill + jitted
single-token decode, full-vs-compressed throughput comparison.

    PYTHONPATH=src python examples/serve_batched.py --requests 8
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.core import compress as CMP
from repro.launch.serve import ServeConfig, Server
from repro.models import model as MD
from repro import configs


def throughput(srv, requests, sc):
    rng = np.random.default_rng(0)
    n_batches = -(-requests // sc.batch_size)
    # warmup (compile)
    srv.generate(rng.integers(0, srv.cfg.vocab_size,
                              size=(sc.batch_size, sc.prompt_len),
                              dtype=np.int32))
    t0 = time.perf_counter()
    tokens = 0
    for _ in range(n_batches):
        prompts = rng.integers(0, srv.cfg.vocab_size,
                               size=(sc.batch_size, sc.prompt_len),
                               dtype=np.int32)
        tokens += srv.generate(prompts).size
    dt = time.perf_counter() - t0
    return tokens / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    args = ap.parse_args()

    sc = ServeConfig(arch="qwen3-moe-30b-a3b", batch_size=args.batch_size,
                     prompt_len=32, max_new_tokens=16)
    cfg = configs.get(sc.arch).reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))

    full = Server(sc, cfg=cfg, params=params)
    tput_full = throughput(full, args.requests, sc)
    print(f"[full      ] {tput_full:8.1f} tok/s "
          f"({cfg.moe.n_experts} experts)")

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=0,
        batches=calib)
    comp = Server(sc, cfg=ncfg, params=nparams)
    tput_comp = throughput(comp, args.requests, sc)
    print(f"[mergemoe  ] {tput_comp:8.1f} tok/s "
          f"({info['merged_experts']} experts, "
          f"{info['compression_ratio']:.2f}x smaller)")


if __name__ == "__main__":
    main()
