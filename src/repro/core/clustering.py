"""Expert clustering (paper §4, step 1).

Centers = the M most-used experts. Every remaining expert joins the center
with the highest cosine similarity of its concat(W_U, W_G) weight features
(MergeMoE / Average / ZipIt) or of its router column (M-SMoE's
routing-policy view).
"""
from __future__ import annotations

import numpy as np


def _cosine_to_centers(feats: np.ndarray, center_ids: np.ndarray) -> np.ndarray:
    """feats: [N, D] fp32; returns [N, M] cosine similarity to each center."""
    f = feats / (np.linalg.norm(feats, axis=1, keepdims=True) + 1e-8)
    c = f[center_ids]                                   # [M, D]
    return f @ c.T                                      # [N, M]


def cluster_experts(wg: np.ndarray, wu: np.ndarray, counts: np.ndarray,
                    M: int, *, router: np.ndarray | None = None,
                    metric: str = "weights") -> np.ndarray:
    """Returns ``assign`` [N] int32 — cluster id in [0, M) per original expert.

    wg/wu: [N, d, f]; counts: [N] usage frequencies; router: [d, N] (only for
    metric='router'). Cluster ids are ordered by the center ranking (cluster 0
    = most-used expert's cluster).
    """
    N = wg.shape[0]
    if M >= N:
        return np.arange(N, dtype=np.int32)
    counts = np.asarray(counts, np.float64)
    center_ids = np.argsort(-counts, kind="stable")[:M]

    if metric == "router":
        assert router is not None
        feats = np.asarray(router, np.float32).T.reshape(N, -1)
    else:
        feats = np.concatenate(
            [np.asarray(wu, np.float32).reshape(N, -1),
             np.asarray(wg, np.float32).reshape(N, -1)], axis=1)

    sim = _cosine_to_centers(feats, center_ids)         # [N, M]
    assign = np.argmax(sim, axis=1).astype(np.int32)
    assign[center_ids] = np.arange(M, dtype=np.int32)   # centers stay put
    return assign


def merge_weights(assign: np.ndarray, counts: np.ndarray, M: int) -> np.ndarray:
    """Frequency-weighted B matrix entries (Theorem 1 optimum).

    Returns [N] float32: w_j = f_j / sum_{k in cluster(j)} f_k (uniform if the
    cluster saw zero traffic).
    """
    counts = np.asarray(counts, np.float64)
    w = np.zeros_like(counts)
    for c in range(M):
        members = np.where(assign == c)[0]
        tot = counts[members].sum()
        if tot > 0:
            w[members] = counts[members] / tot
        else:
            w[members] = 1.0 / max(len(members), 1)
    return w.astype(np.float32)


def summation_matrix(assign: np.ndarray, M: int) -> np.ndarray:
    """The paper's matrix A (Eq. 2): [M, N] one-hot cluster membership."""
    N = assign.shape[0]
    A = np.zeros((M, N), np.float32)
    A[assign, np.arange(N)] = 1.0
    return A


def mixing_matrix(assign: np.ndarray, counts: np.ndarray, M: int) -> np.ndarray:
    """The paper's matrix B: [N, M], column i supported on cluster C_i."""
    N = assign.shape[0]
    w = merge_weights(assign, counts, M)
    B = np.zeros((N, M), np.float32)
    B[np.arange(N), assign] = w
    return B
