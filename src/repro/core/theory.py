"""Theorem 1 machinery (paper §4 + Appendix A).

Objective (after the paper's simplification):
    J(B) = Σ_i f_i (u_i - e_i)ᵀ W (u_i - e_i),   W = Y0ᵀ Y0,  u_i = B a_i
where a_i is column i of A. Theorem 1: the frequency-weighted B
(B_ji = f_j / Σ_{k∈C_i} f_k) is a global minimum.

``tests/test_theory.py`` verifies this numerically (hypothesis sweeps random
perturbations of B and asserts J never decreases).
"""
from __future__ import annotations

import numpy as np


def objective(B: np.ndarray, A: np.ndarray, W: np.ndarray,
              f: np.ndarray) -> float:
    """J(B) as above. B: [N, M]; A: [M, N]; W: [N, N] PSD; f: [N] >= 0."""
    N = A.shape[1]
    U = B @ A                                    # [N, N]; column i = u_i
    J = 0.0
    for i in range(N):
        v = U[:, i].copy()
        v[i] -= 1.0
        J += float(f[i]) * float(v @ W @ v)
    return J


def optimal_B(assign: np.ndarray, f: np.ndarray, M: int) -> np.ndarray:
    """Theorem 1's minimizer."""
    from repro.core.clustering import mixing_matrix
    return mixing_matrix(assign, f, M)


def quasi_frobenius(Y: np.ndarray) -> np.ndarray:
    """QF(Y): per-expert squared Frobenius norms. Y: [d, N] stacked expert
    outputs (columns). Returns [N]."""
    return np.sum(np.asarray(Y, np.float64) ** 2, axis=0)


def output_error(Y: np.ndarray, B: np.ndarray, A: np.ndarray,
                 r: np.ndarray) -> float:
    """||(Y B A - Y) diag-mask routing||_F for a single sample: Y [d, N],
    r [N] masked routing weights. Measures the compressed-vs-original output
    gap that MergeMoE minimizes in expectation."""
    delta = (Y @ B @ A - Y) * r[None, :]
    return float(np.linalg.norm(delta))
