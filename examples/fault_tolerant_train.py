"""Fault-tolerant training demo: checkpoint -> simulated crash -> resume,
with straggler monitoring and async checkpointing.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import TrainConfig, train


def main():
    ckpt = tempfile.mkdtemp(prefix="ft_demo_")
    common = dict(arch="granite-8b", reduced=True, global_batch=4,
                  seq_len=64, lr=1e-3, ckpt_dir=ckpt, ckpt_every=10,
                  async_ckpt=True, log_every=10)

    print("== phase 1: train to step 20, then 'crash' ==")
    train(TrainConfig(steps=20, **common))

    print("\n== phase 2: relaunch — resumes from the last committed "
          "checkpoint (data cursor + optimizer state restored) ==")
    out = train(TrainConfig(steps=40, **common))
    print(f"\nfinal loss after resume: {out['final_loss']:.4f}")
    print(f"checkpoints in {ckpt}: "
          f"{sorted(p.name for p in Path(ckpt).glob('step_*'))}")


if __name__ == "__main__":
    main()
