"""Static Pallas kernel contract checker (DESIGN.md §9).

For every ``pallas_dispatch``-registered kernel, validate — against the
shapes induced by **every** entry in ``repro/configs/`` — the structural
invariants the kernels rely on, WITHOUT executing a single kernel:

* **BlockSpec divisibility**: every operand dimension is divisible by its
  block dimension (Pallas pads silently in interpret mode; on TPU a
  non-dividing block is a launch failure or worse, garbage reads).
* **Grid coverage**: the output index map, enumerated over the full grid,
  writes every output block (a grid that under-covers returns
  uninitialized HBM).
* **Index-map bounds**: every (grid point, spec) pair lands fully
  in-bounds, *including* scalar-prefetch tables evaluated at their extreme
  legal values 0 and E-1 — the §7 contract that OOB-clipped expert ids and
  dropped admission-pad rows keep every gather in-bounds by construction.
  (Scalar tables in this tree always select dim 0 — expert/slot ids — so
  E is the operand's dim-0 block count.)
* **VMEM footprint**: the single-buffered sum of all VMEM-resident blocks
  plus scratch against a per-kernel budget (default 16 MiB, the per-core
  VMEM size). Known exceedances at full-size configs are *waived* with a
  one-line reason in :data:`VMEM_WAIVERS` — the kernels' default
  ``block_t``/``block_f`` target test-scale shapes, and a real TPU launch
  at those configs must pass smaller blocks; the waiver records exactly
  where that cliff is instead of letting the check rot.
* **§8 dtype contract**: quantized kernels take int8 tables + fp32 scale
  rows in; all scratch accumulators are fp32; the kernel body downcasts to
  the output dtype EXACTLY once (checked on the kernel's AST — the
  bitwise kernel==oracle story dies the moment a second rounding appears).

Mechanism: ``pl.pallas_call`` is monkeypatched to a recorder while the
kernel-module implementation (unwrapped from ``jax.jit`` via
``__wrapped__`` so no jit cache is touched) is traced with
``jax.eval_shape``. The recorder captures grid/specs/operand avals and
returns abstract zeros, so nothing ever executes.
"""
from __future__ import annotations

import ast
import contextlib
import dataclasses
import functools
import importlib
import inspect
import itertools
import textwrap
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ContractFinding", "ContractReport", "check_kernel_contracts",
           "VMEM_WAIVERS"]

VMEM_BUDGET_BYTES = 16 * 1024 * 1024      # per-core VMEM (pallas guide)

# (kernel, arch) -> one-line reason. These are REAL exceedances of the
# 16 MiB budget at the kernels' default block sizes; a TPU launch at these
# configs must pass smaller block_t/block_f (the gather kernel additionally
# needs an f-blocked variant for kimi-scale experts — ROADMAP int4 work).
VMEM_WAIVERS: Dict[Tuple[str, str], str] = {
    ("swiglu_mlp", "yi_34b"):
        "d=7168 rows at default bf=512 blocks: ~28 MiB; TPU launch shrinks "
        "block_t/block_f",
    ("swiglu_mlp", "qwen1_5_110b"):
        "d=8192/f=49152 at default blocks: ~32 MiB; TPU launch shrinks "
        "block_t/block_f",
    ("swiglu_mlp", "phi3_medium_14b"):
        "d=5120/f=17920 at default blocks: ~20 MiB; TPU launch shrinks "
        "block_t/block_f",
    ("grouped_swiglu", "kimi_k2_1t_a32b"):
        "d=7168 expert blocks at default bf=512: ~28 MiB; TPU launch "
        "shrinks block_t/block_f",
    ("grouped_swiglu_q", "kimi_k2_1t_a32b"):
        "int8 halves weight blocks but d=7168 x/acc rows still ~18 MiB; "
        "TPU launch shrinks block_t",
    ("gather_swiglu", "kimi_k2_1t_a32b"):
        "gather streams UNBLOCKED [d=7168, f=2048] expert tables (~84 MiB); "
        "needs the f-blocked gather variant before kimi decode on TPU",
    ("gather_swiglu_q", "kimi_k2_1t_a32b"):
        "int8 gather still streams unblocked expert tables (~42 MiB); "
        "needs the f-blocked gather variant before kimi decode on TPU",
}


@dataclasses.dataclass(frozen=True)
class ContractFinding:
    kernel: str
    arch: str
    check: str          # divisibility | coverage | bounds | vmem | dtype
    msg: str

    def format(self) -> str:
        return f"{self.kernel} @ {self.arch}: [{self.check}] {self.msg}"


@dataclasses.dataclass
class ContractReport:
    findings: List[ContractFinding]
    waived: List[ContractFinding]
    checked: List[Tuple[str, str]]          # (kernel, arch) pairs validated

    @property
    def ok(self) -> bool:
        return not self.findings


# ---------------------------------------------------------------------------
# pallas_call capture
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Capture:
    kernel_fn: Any
    grid: Tuple[int, ...]
    in_specs: Sequence[Any]
    out_spec: Any
    out_shape: Any
    scratch: Sequence[Any]
    num_prefetch: int
    operands: Tuple[jax.ShapeDtypeStruct, ...]


@contextlib.contextmanager
def _capture_pallas(records: List[_Capture]):
    """Monkeypatch ``pl.pallas_call`` to record its configuration and
    return abstract zeros. Kernel modules import ``pallas as pl`` and call
    ``pl.pallas_call`` at call time, so patching the module attribute
    covers them all."""
    orig = pl.pallas_call

    def fake(kernel, *, out_shape, grid=None, grid_spec=None, in_specs=None,
             out_specs=None, scratch_shapes=None, interpret=False, **kw):
        if grid_spec is not None:
            g = getattr(grid_spec, "grid", None)
            ins = getattr(grid_spec, "in_specs", None)
            outs = getattr(grid_spec, "out_specs", None)
            scratch = getattr(grid_spec, "scratch_shapes", None) or ()
            npf = getattr(grid_spec, "num_scalar_prefetch", 0)
        else:
            g, ins, outs = grid, in_specs, out_specs
            scratch = scratch_shapes or ()
            npf = 0
        if isinstance(g, int):
            g = (g,)
        out_spec = outs[0] if isinstance(outs, (list, tuple)) else outs

        def runner(*operands):
            records.append(_Capture(
                kernel_fn=kernel, grid=tuple(int(d) for d in g),
                in_specs=tuple(ins), out_spec=out_spec, out_shape=out_shape,
                scratch=tuple(scratch), num_prefetch=int(npf),
                operands=tuple(jax.ShapeDtypeStruct(tuple(o.shape), o.dtype)
                               for o in operands)))
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                out_shape)
        return runner

    pl.pallas_call = fake
    try:
        yield
    finally:
        pl.pallas_call = orig


# ---------------------------------------------------------------------------
# per-capture checks
# ---------------------------------------------------------------------------

def _is_smem(spec) -> bool:
    return "smem" in str(getattr(spec, "memory_space", "")).lower()


def _block_shape(spec, op_shape) -> Tuple[int, ...]:
    bs = tuple(getattr(spec, "block_shape", None) or op_shape)
    return tuple(op_shape[i] if b is None else int(b)
                 for i, b in enumerate(bs))


def _grid_points(grid: Tuple[int, ...], cap: int = 500_000):
    total = int(np.prod(grid)) if grid else 0
    if total > cap:
        return None
    return itertools.product(*(range(g) for g in grid))


def _table_fills(cap: _Capture) -> List[List[np.ndarray]]:
    """Synthetic scalar-prefetch tables at extreme legal values.

    Tables in this tree hold dim-0 block indices (expert/slot ids) for the
    operands their index maps gather; the §5/§7 clip contract bounds them
    to [0, E-1]. E differs per operand, so fills use the MINIMUM dim-0
    block count over the non-prefetch operands — the tightest legal
    extreme any spec could be asked to honor."""
    tables = cap.operands[:cap.num_prefetch]
    if not tables:
        return [[]]
    emin = None
    for op, spec in zip(cap.operands[cap.num_prefetch:], cap.in_specs):
        bs = _block_shape(spec, op.shape)
        if bs and bs[0] and op.shape:
            n0 = op.shape[0] // bs[0]
            emin = n0 if emin is None else min(emin, n0)
    hi = max((emin or 1) - 1, 0)
    fills = []
    for v in (0, hi):
        fills.append([np.full(t.shape, v, np.dtype(t.dtype))
                      for t in tables])
    return fills


def _check_capture(cap: _Capture, kernel: str, arch: str,
                   contract: Dict[str, Any]) -> Iterable[ContractFinding]:
    quantized = contract.get("quantized", False)
    ops_for_specs = cap.operands[cap.num_prefetch:]
    if len(ops_for_specs) != len(cap.in_specs):
        yield ContractFinding(kernel, arch, "divisibility",
                              f"{len(ops_for_specs)} operands vs "
                              f"{len(cap.in_specs)} in_specs")
        return
    out_sds = jax.tree.leaves(cap.out_shape)[0]
    pairs = list(zip(ops_for_specs, cap.in_specs)) + [(out_sds, cap.out_spec)]

    # ---- divisibility
    for i, (op, spec) in enumerate(pairs):
        bs = _block_shape(spec, op.shape)
        if len(bs) != len(op.shape):
            yield ContractFinding(
                kernel, arch, "divisibility",
                f"operand {i}: block rank {len(bs)} vs shape {op.shape}")
            continue
        for d, (o, b) in enumerate(zip(op.shape, bs)):
            if b <= 0 or o % b:
                yield ContractFinding(
                    kernel, arch, "divisibility",
                    f"operand {i} dim {d}: {o} not divisible by block {b}")

    pts = _grid_points(cap.grid)
    if pts is None:
        yield ContractFinding(kernel, arch, "coverage",
                              f"grid {cap.grid} too large to enumerate")
        return
    pts = list(pts)
    fills = _table_fills(cap)

    # ---- index-map bounds (all specs, both table extremes)
    for i, (op, spec) in enumerate(pairs):
        imap = getattr(spec, "index_map", None)
        if imap is None:
            continue
        bs = _block_shape(spec, op.shape)
        nblocks = [max(o // b, 1) for o, b in zip(op.shape, bs)]
        bad = None
        for tables in fills:
            for pt in pts:
                idx = imap(*pt, *tables)
                idx = idx if isinstance(idx, tuple) else (idx,)
                for d, v in enumerate(idx):
                    v = int(v)
                    if v < 0 or v >= nblocks[d]:
                        bad = (pt, d, v, nblocks[d])
                        break
                if bad:
                    break
            if bad:
                break
        if bad:
            pt, d, v, nb = bad
            yield ContractFinding(
                kernel, arch, "bounds",
                f"operand {i} index map at grid {pt}: block index {v} on "
                f"dim {d} outside [0, {nb})")

    # ---- output grid coverage
    out_spec = cap.out_spec
    imap = getattr(out_spec, "index_map", None)
    if imap is not None:
        bs = _block_shape(out_spec, out_sds.shape)
        required = set(itertools.product(
            *(range(max(o // b, 1)) for o, b in zip(out_sds.shape, bs))))
        got = set()
        for pt in pts:
            idx = imap(*pt, *fills[0])
            got.add(tuple(int(v) for v in
                          (idx if isinstance(idx, tuple) else (idx,))))
        missing = required - got
        if missing:
            yield ContractFinding(
                kernel, arch, "coverage",
                f"{len(missing)}/{len(required)} output blocks never "
                f"written (e.g. {sorted(missing)[0]})")

    # ---- VMEM footprint (single-buffered blocks + scratch)
    vmem = 0
    for op, spec in pairs:
        if _is_smem(spec):
            continue
        bs = _block_shape(spec, op.shape)
        vmem += int(np.prod(bs)) * np.dtype(op.dtype).itemsize
    for s in cap.scratch:
        shape = tuple(getattr(s, "shape", ()))
        dt = getattr(s, "dtype", np.float32)
        if "smem" not in type(s).__name__.lower():
            vmem += int(np.prod(shape) if shape else 1) * \
                np.dtype(dt).itemsize
    if vmem > VMEM_BUDGET_BYTES:
        yield ContractFinding(
            kernel, arch, "vmem",
            f"estimated VMEM {vmem / 2**20:.1f} MiB exceeds "
            f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget")

    # ---- §8 dtype contract
    x = ops_for_specs[0]
    if np.dtype(out_sds.dtype) != np.dtype(x.dtype):
        yield ContractFinding(
            kernel, arch, "dtype",
            f"output dtype {out_sds.dtype} != input dtype {x.dtype} "
            f"(the one downcast must land AT the model dtype)")
    for s in cap.scratch:
        dt = getattr(s, "dtype", None)
        if dt is not None and np.dtype(dt) != np.float32:
            yield ContractFinding(
                kernel, arch, "dtype",
                f"scratch accumulator dtype {dt} is not float32")
    if quantized:
        # operand-count expectations live in the contract metadata so kernel
        # families with different quantized layouts (3 expert tables vs 2 KV
        # pools) share one check; defaults are the expert-table family's.
        want_i8 = int(contract.get("int8_operands", 3))
        want_f32 = int(contract.get("f32_min_operands", 3))
        n_i8 = sum(np.dtype(o.dtype) == np.int8 for o in ops_for_specs)
        n_f32 = sum(np.dtype(o.dtype) == np.float32 for o in ops_for_specs)
        if n_i8 != want_i8 or n_f32 < want_f32:
            yield ContractFinding(
                kernel, arch, "dtype",
                f"quantized kernel expects {want_i8} int8 tables + "
                f">={want_f32} fp32 scale rows, saw {n_i8} int8 / "
                f"{n_f32} fp32 operands")
    yield from _check_kernel_body(cap, kernel, arch, quantized)


def _check_kernel_body(cap: _Capture, kernel: str, arch: str,
                       quantized: bool) -> Iterable[ContractFinding]:
    """AST checks on the kernel body: exactly one `.astype(o_ref.dtype)`
    downcast; fp32-internal arithmetic (preferred_element_type=F32 on every
    dot, or operands pre-cast to F32 in the quantized kernels)."""
    fn = cap.kernel_fn
    while isinstance(fn, functools.partial):
        fn = fn.func
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        return
    tree = ast.parse(src)
    downcasts = 0
    dots = 0
    dots_f32 = 0
    casts_f32 = 0
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "astype":
            arg = node.args[0] if node.args else None
            if (isinstance(arg, ast.Attribute) and arg.attr == "dtype"
                    and isinstance(arg.value, ast.Name)
                    and arg.value.id == "o_ref"):
                downcasts += 1
            elif isinstance(arg, ast.Name) and arg.id in ("F32", "f32"):
                casts_f32 += 1
        if isinstance(f, ast.Attribute) and f.attr == "dot":
            dots += 1
            if any(kw.arg == "preferred_element_type"
                   for kw in node.keywords):
                dots_f32 += 1
    if downcasts != 1:
        yield ContractFinding(
            kernel, arch, "dtype",
            f"kernel body `{getattr(fn, '__name__', '?')}` has {downcasts} "
            f"`.astype(o_ref.dtype)` downcasts; the §8 contract requires "
            f"exactly one")
    if dots and dots_f32 < dots and not casts_f32:
        yield ContractFinding(
            kernel, arch, "dtype",
            f"kernel body `{getattr(fn, '__name__', '?')}`: {dots - dots_f32}"
            f"/{dots} jnp.dot calls neither request "
            f"preferred_element_type=F32 nor operate on pre-cast fp32 "
            f"operands")


# ---------------------------------------------------------------------------
# config -> induced shapes
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _qexp(E: int, d: int, f: int):
    from repro.core.quant import QuantizedExpertTables
    i8, f32 = jnp.int8, jnp.float32
    return QuantizedExpertTables(
        wg=_sds((E, d, f), i8), wg_scale=_sds((E, 1, f), f32),
        wu=_sds((E, d, f), i8), wu_scale=_sds((E, 1, f), f32),
        wd=_sds((E, f, d), i8), wd_scale=_sds((E, 1, d), f32))


def _induced_cases(kind: str, cfg) -> List[Tuple[str, tuple]]:
    """(case label, eval_shape args) pairs a config induces for a kernel
    kind; empty when the config has no such layer."""
    dt = cfg.param_dtype
    d = cfg.d_model
    if kind == "swiglu":
        if not cfg.d_ff:
            return []
        f = cfg.d_ff
        return [("T128", (_sds((128, d), dt), _sds((d, f), dt),
                          _sds((d, f), dt), _sds((f, d), dt)))]
    if kind in ("grouped", "grouped_q"):
        if cfg.moe is None:
            return []
        E, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        gs = _sds((E,), jnp.int32)
        cases = []
        for T in (16, 64):
            x = _sds((T, d), dt)
            if kind == "grouped":
                w = dt
                cases.append((f"T{T}", (x, _sds((E, d, f), w),
                                        _sds((E, d, f), w),
                                        _sds((E, f, d), w), gs)))
            else:
                cases.append((f"T{T}", (x, _qexp(E, d, f), gs)))
        return cases
    if kind in ("gather", "gather_q"):
        if cfg.moe is None:
            return []
        E, f, k = cfg.moe.n_experts, cfg.moe.d_ff_expert, cfg.moe.top_k
        cases = []
        for T in (1, 4):
            x = _sds((T, d), dt)
            idx = _sds((T, k), jnp.int32)
            w = _sds((T, k), jnp.float32)
            if kind == "gather":
                cases.append((f"T{T}", (x, _sds((E, d, f), dt),
                                        _sds((E, d, f), dt),
                                        _sds((E, f, d), dt), idx, w)))
            else:
                cases.append((f"T{T}", (x, _qexp(E, d, f), idx, w)))
        return cases
    if kind == "flash":
        if cfg.is_attention_free:
            return []
        H, hd, S = cfg.n_heads, cfg.hd, 256
        qkv = [_sds((1, H, S, hd), dt)] * 3
        return [("S256", tuple(qkv))]
    if kind in ("paged", "paged_q"):
        if cfg.is_attention_free:
            return []
        nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        B, bs, mb, nb = 4, 16, 8, 32         # pool shape is arch-independent
        q = _sds((B, nq, hd), dt)
        tab = _sds((B, mb), jnp.int32)
        lens = _sds((B,), jnp.int32)
        if kind == "paged":
            kv = _sds((nb, bs, nkv, hd), dt)
            return [("B4", (q, kv, kv, tab, lens))]
        kv = _sds((nb, bs, nkv, hd), jnp.int8)
        sc = _sds((nb, bs, nkv), jnp.float32)
        return [("B4", (q, kv, kv, sc, sc, tab, lens))]
    raise ValueError(f"unknown kernel kind {kind!r}")


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def check_kernel_contracts(arch_ids: Optional[Sequence[str]] = None
                           ) -> ContractReport:
    """Validate every registered kernel against every config (or the given
    arch ids). Pure abstract evaluation — no kernel executes."""
    from repro import configs
    from repro.kernels import ops as kops

    findings: List[ContractFinding] = []
    waived: List[ContractFinding] = []
    checked: List[Tuple[str, str]] = []
    archs = list(arch_ids) if arch_ids is not None else list(configs.ARCH_IDS)

    for name, info in sorted(kops.KERNEL_REGISTRY.items()):
        contract = info.contract
        if contract is None:
            continue
        mod = importlib.import_module(f"repro.kernels.{info.module}")
        impl = getattr(mod, name)
        impl = getattr(impl, "__wrapped__", impl)   # bypass jit + its cache
        for arch in archs:
            cfg = configs.get(arch)
            cases = _induced_cases(contract["kind"], cfg)
            if not cases:
                continue
            for label, args in cases:
                records: List[_Capture] = []
                # a fresh wrapper per trace: eval_shape caches on function
                # identity, and a cache hit would skip tracing entirely —
                # the recorder would see nothing on a second checker run
                with _capture_pallas(records):
                    if contract["kind"] == "flash":
                        for causal in (True, False):
                            jax.eval_shape(
                                lambda *a, _c=causal: impl(*a, causal=_c),
                                *args)
                    else:
                        jax.eval_shape(lambda *a: impl(*a), *args)
                if not records:
                    findings.append(ContractFinding(
                        name, arch, "coverage",
                        f"no pallas_call reached tracing `{name}` "
                        f"({label}) — dispatch policy regression?"))
                    continue
                for cap in records:
                    for f in _check_capture(cap, name, arch, contract):
                        reason = VMEM_WAIVERS.get((name, arch))
                        if f.check == "vmem" and reason:
                            waived.append(dataclasses.replace(
                                f, msg=f"{f.msg} — waived: {reason}"))
                        else:
                            findings.append(f)
            checked.append((name, arch))
    # dedupe (multiple cases / captures can repeat a finding verbatim)
    findings = sorted(set(findings),
                      key=lambda f: (f.kernel, f.arch, f.check, f.msg))
    waived = sorted(set(waived),
                    key=lambda f: (f.kernel, f.arch, f.check, f.msg))
    return ContractReport(findings, waived, checked)
