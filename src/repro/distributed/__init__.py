from repro.distributed.compression import (  # noqa: F401
    ef_compressed, compressed_psum, quantize, dequantize, shard_layer_solves)
from repro.distributed.straggler import StragglerMonitor, StragglerReport  # noqa: F401
