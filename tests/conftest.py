"""Tests run with the DEFAULT single CPU device (the dry-run's 512-device
XLA flag must never leak here)."""
import os

assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""), "tests must not inherit the dry-run device flag"

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
