"""Pure-host property tests for the paged-KV block allocator
(repro.serving.paging, DESIGN.md §11): no device, no jax — random
admission / release / trim / CoW traces with the refcount, free-list, and
table invariants re-checked after every operation, plus directed tests for
the prefix registry, LRU eviction, deferral, and copy-on-write semantics.

Runs under real hypothesis when installed, else the deterministic
_hypothesis_compat fallback (same API, seeded examples).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serving.paging import PagedAllocator


def _alloc(n_slots=4, n_blocks=16, block_size=4, s_max=32):
    return PagedAllocator(n_slots=n_slots, n_blocks=n_blocks,
                          block_size=block_size, s_max=s_max)


def _prompt(rng, n):
    return rng.integers(0, 997, size=n, dtype=np.int32)


# ---------------------------------------------------------------------------
# directed semantics
# ---------------------------------------------------------------------------

def test_block_size_must_divide_s_max():
    with pytest.raises(ValueError, match="multiple of"):
        _alloc(block_size=5, s_max=32)


def test_admit_reserves_ceil_blocks_and_release_returns_them():
    a = _alloc()
    rng = np.random.default_rng(0)
    assert a.admit(0, _prompt(rng, 6), n_rows=9) == 0   # ceil(9/4) = 3
    assert a.free_blocks == 16 - 3
    a.check_invariants()
    a.release(0)
    assert a.free_blocks == 16
    assert (a.tab[0] == a.nb).all()                     # sentinel everywhere
    a.check_invariants()


def test_double_admit_same_slot_raises():
    a = _alloc()
    rng = np.random.default_rng(1)
    a.admit(0, _prompt(rng, 4), n_rows=4)
    with pytest.raises(RuntimeError, match="already owns"):
        a.admit(0, _prompt(rng, 4), n_rows=4)


def test_prefix_sharing_adopts_full_blocks_only():
    """A sharer adopts every FULL prompt block strictly below the last
    prompt token — never the block holding that token — and allocates only
    its suffix blocks."""
    a = _alloc()
    rng = np.random.default_rng(2)
    p = _prompt(rng, 10)                   # blocks 0-1 full, row 8-9 partial
    a.admit(0, p, n_rows=12)
    a.register_prefix(0, p)
    free0 = a.free_blocks
    shared = a.admit(1, p, n_rows=12)
    assert shared == 8                     # 2 full blocks of 4 rows
    # sharer allocates ceil(12/4) - 2 = 1 new block
    assert a.free_blocks == free0 - 1
    assert list(a.tab[1, :2]) == list(a.tab[0, :2])     # same block ids
    assert a.tab[1, 2] != a.tab[0, 2]                   # private suffix
    a.check_invariants()


def test_shared_rows_capped_below_prompt_length():
    """A prompt that is ENTIRELY a registered chain still leaves >= 1 suffix
    token, so the admission forward has logits to sample from."""
    a = _alloc()
    rng = np.random.default_rng(3)
    p = _prompt(rng, 8)                    # exactly 2 full blocks
    a.admit(0, np.concatenate([p, _prompt(rng, 4)]), n_rows=16)
    a.register_prefix(0, np.concatenate([p, _prompt(rng, 4)]))
    shared, chain = a.lookup_prefix(p)
    assert shared == 4 and len(chain) == 1  # only the first block: 8 rows
    # would cover the whole prompt, and (8-1)//4 == 1 caps it at one block


def test_registry_pins_blocks_past_owner_release():
    """Registered chains survive the owner's eviction: the registry holds
    its own refcount, so a later duplicate still shares."""
    a = _alloc()
    rng = np.random.default_rng(4)
    p = _prompt(rng, 9)
    a.admit(0, p, n_rows=9)
    a.register_prefix(0, p)
    a.release(0)
    a.check_invariants()
    assert a.free_blocks < a.nb            # chain blocks stayed pinned
    assert a.admit(1, p, n_rows=9) == 8
    a.check_invariants()


def test_registry_lru_eviction_frees_blocks_under_pressure():
    a = _alloc(n_slots=8, n_blocks=8, block_size=4, s_max=32)
    rng = np.random.default_rng(5)
    # each admission takes 3 blocks and leaves 2 pinned in the registry
    # ((9-1)//4 full blocks), so the 4th admission finds only 2 free and
    # must LRU-evict the oldest chain rather than defer
    prompts = [_prompt(rng, 9) for _ in range(4)]
    for i, p in enumerate(prompts):
        assert a.admit(i, p, n_rows=9) == 0
        a.register_prefix(i, p)
        a.release(i)
        a.check_invariants()
    assert a.stats["registry_evictions"] >= 1
    assert a.stats["deferrals"] == 0
    # the OLDEST chain went first: it no longer shares, the newest one does
    assert a.lookup_prefix(prompts[0]) == (0, ())
    assert a.lookup_prefix(prompts[-1])[0] == 8


def test_admit_defers_when_pool_truly_exhausted():
    a = _alloc(n_slots=4, n_blocks=4, block_size=4, s_max=32)
    rng = np.random.default_rng(6)
    assert a.admit(0, _prompt(rng, 8), n_rows=16) == 0  # all 4 blocks
    assert a.admit(1, _prompt(rng, 4), n_rows=4) is None
    assert a.stats["deferrals"] == 1
    a.check_invariants()
    a.release(0)
    assert a.admit(1, _prompt(rng, 4), n_rows=4) == 0   # retry succeeds
    a.check_invariants()


def test_eviction_under_pressure_never_frees_the_adopted_chain():
    """Regression: admit must take the adoption refcounts on the matched
    chain BEFORE the eviction loop. Pre-fix, draining the registry under
    pool pressure evicted the very entries pinning the adopted chain,
    dropped its blocks into the free list, and the need_new loop handed
    them back out — slot table [0, 1, 1, 0, ...] with duplicate block
    ids, i.e. decode overwriting its own shared prompt KV. The uniquely
    correct outcome here is a deferral: the pool genuinely cannot hold
    need_new blocks DISTINCT from the pinned chain."""
    a = _alloc(n_slots=4, n_blocks=5, block_size=4, s_max=32)
    rng = np.random.default_rng(11)
    p = _prompt(rng, 9)
    a.admit(0, p, n_rows=9)                 # blocks 0,1,2
    a.register_prefix(0, p)                 # pins chains (0,) and (0,1)
    a.release(0)                            # block 2 free; 0,1 registry-only
    a.admit(2, _prompt(rng, 4), n_rows=4)   # takes block 2 -> free = {3,4}
    # same prompt, 5-block budget: chain (0,1) matches, need_new=3 > 2 free,
    # so the eviction loop drains the whole registry including the matched
    # chain's own entries
    assert a.admit(1, p, n_rows=20) is None
    assert a.stats["deferrals"] == 1
    assert a.stats["registry_evictions"] == 2
    a.check_invariants()
    # deferral unwound the adoption pins: blocks 0,1 are free again, and
    # once slot 2 releases, the retry succeeds with 5 DISTINCT blocks
    # (registry was drained, so nothing shares)
    a.release(2)
    assert a.admit(1, p, n_rows=20) == 0
    assert len(set(a._owned[1])) == 5
    a.check_invariants()


def test_cow_divorces_shared_block_and_never_mutates_the_chain():
    a = _alloc()
    rng = np.random.default_rng(7)
    p = _prompt(rng, 10)
    a.admit(0, p, n_rows=12)
    a.register_prefix(0, p)
    a.admit(1, p, n_rows=12)
    chain_before = list(a.tab[0, :3])
    old, new = a.ensure_writable(1, 0)     # shared block -> divorce
    assert old != new and a.stats["cow_copies"] == 1
    assert a.tab[1, 0] == new
    assert list(a.tab[0, :3]) == chain_before   # owner 0's chain untouched
    assert a.ref[old] >= 1                      # still pinned by 0+registry
    a.check_invariants()
    # exclusively-owned block: no divorce
    old2, new2 = a.ensure_writable(1, 2)
    assert old2 == new2 and a.stats["cow_copies"] == 1


def test_trim_releases_tail_blocks_only():
    a = _alloc()
    rng = np.random.default_rng(8)
    a.admit(0, _prompt(rng, 6), n_rows=16)      # 4 blocks
    head = a.tab[0, 0]
    assert a.trim(0, n_rows=5) == 2             # keep ceil(5/4) = 2
    assert a.tab[0, 0] == head
    assert (a.tab[0, 2:] == a.nb).all()
    a.check_invariants()
    assert a.trim(99, n_rows=1) == 0            # unknown slot: no-op


def test_reset_reclaims_everything():
    a = _alloc()
    rng = np.random.default_rng(9)
    for i in range(3):
        p = _prompt(rng, 8)
        a.admit(i, p, n_rows=10)
        a.register_prefix(i, p)
    a.reset()
    assert a.free_blocks == a.nb
    assert (a.tab == a.nb).all()
    a.check_invariants()


# ---------------------------------------------------------------------------
# property tests: random operation traces hold every invariant
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.lists(st.integers(min_value=0, max_value=5), min_size=5,
                max_size=60))
def test_random_traces_hold_invariants(seed, ops):
    """Random admit/release/trim/CoW/register traces on a small pool: after
    EVERY operation the refcounts equal the owner+registry pins exactly, the
    free list is duplicate-free and complements ref>0, the table mirrors
    ownership, and the sentinel row stays intact. Duplicate prompts are
    drawn from a tiny space so prefix sharing and LRU eviction fire often."""
    rng = np.random.default_rng(seed)
    a = _alloc(n_slots=4, n_blocks=10, block_size=4, s_max=32)
    # tiny prompt space -> frequent registry hits
    vocab = [_prompt(rng, int(n)) for n in (4, 5, 8, 9, 12)]
    live = {}
    for op in ops:
        if op == 0 or not live:                          # admit
            free_slots = [s for s in range(a.n_slots) if s not in live]
            if not free_slots:
                continue
            slot = int(rng.choice(free_slots))
            p = vocab[int(rng.integers(len(vocab)))]
            n_rows = int(len(p) + rng.integers(0, 9))
            if a.admit(slot, p, n_rows) is not None:
                live[slot] = p
        elif op == 1:                                    # release
            slot = int(rng.choice(list(live)))
            a.release(slot)
            del live[slot]
        elif op == 2:                                    # register
            slot = int(rng.choice(list(live)))
            a.register_prefix(slot, live[slot])
        elif op == 3:                                    # trim
            slot = int(rng.choice(list(live)))
            a.trim(slot, int(rng.integers(1, 12)))
        elif op == 4:                                    # CoW
            slot = int(rng.choice(list(live)))
            blocks = a._owned[slot]
            if blocks:
                try:
                    a.ensure_writable(slot, int(rng.integers(len(blocks))))
                except RuntimeError:
                    pass                                 # pool exhausted: ok
        else:                                            # full reclaim
            a.reset()
            live.clear()
        a.check_invariants()
    a.reset()
    a.check_invariants()
    assert a.free_blocks == a.nb                         # no leaks, ever


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=12))
def test_admit_release_roundtrip_never_leaks(prompt_len, extra_rows):
    a = _alloc(n_slots=2, n_blocks=32, block_size=4, s_max=64)
    rng = np.random.default_rng(prompt_len * 41 + extra_rows)
    p = _prompt(rng, prompt_len)
    n_rows = prompt_len + extra_rows
    assert a.admit(0, p, n_rows) == 0
    assert a.nb - a.free_blocks == a.blocks_for_rows(n_rows)
    a.check_invariants()
    a.release(0)
    assert a.free_blocks == a.nb
    a.check_invariants()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=5, max_value=40))
def test_sharer_never_allocates_shared_blocks_twice(prompt_len):
    """After register + re-admit of the same prompt, total blocks consumed
    = one private copy + shared chain, never two full copies."""
    a = _alloc(n_slots=2, n_blocks=64, block_size=4, s_max=64)
    rng = np.random.default_rng(prompt_len)
    p = _prompt(rng, prompt_len)
    n_rows = prompt_len + 4
    a.admit(0, p, n_rows)
    a.register_prefix(0, p)
    used0 = a.nb - a.free_blocks
    shared = a.admit(1, p, n_rows)
    full_blocks = (prompt_len - 1) // a.bs
    assert shared == full_blocks * a.bs
    assert (a.nb - a.free_blocks) - used0 == \
        a.blocks_for_rows(n_rows) - full_blocks
    a.check_invariants()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.lists(st.integers(min_value=1, max_value=36),
                min_size=4, max_size=24))
def test_fifo_head_never_starves_under_pool_pressure(seed, row_budgets):
    """No-starvation property (DESIGN.md §12): with FIFO peek-don't-pop
    admission (the engine's policy — a deferred head blocks everything
    behind it), the queue head is admitted after at most ``n_slots``
    completions, for ANY sequence of request sizes that individually fit
    the pool. Deferral must fully unwind its reservation (adopted prefix
    refcounts included), or the head's retry finds a shrinking pool and
    starves behind its own leak."""
    a = _alloc(n_slots=3, n_blocks=9, block_size=4, s_max=36)
    rng = np.random.default_rng(seed)
    live = {}                               # slot -> admission order
    order = 0
    admitted = []
    queue = list(enumerate(row_budgets))    # FIFO, sizes in KV rows
    stalls = 0
    while queue:
        uid, n_rows = queue[0]
        free = [s for s in range(a.n_slots) if s not in live]
        if free:
            prompt = _prompt(rng, int(min(n_rows, 12)))
            if a.admit(free[0], prompt, n_rows) is not None:
                if rng.random() < 0.5:      # random registry pins in play
                    a.register_prefix(free[0], prompt)
                live[free[0]] = order
                order += 1
                queue.pop(0)
                admitted.append(uid)
                stalls = 0
                a.check_invariants()
                continue
        # head deferred (or all slots busy): oldest live request completes
        assert live, "head deferred against an EMPTY pool: unwind leak"
        oldest = min(live, key=live.get)
        a.release(oldest)
        del live[oldest]
        a.check_invariants()
        stalls += 1
        assert stalls <= a.n_slots, (
            f"request {uid} ({n_rows} rows) starved: still deferred after "
            f"{stalls} completions freed the whole pool")
    assert admitted == list(range(len(row_budgets)))    # FIFO order held
