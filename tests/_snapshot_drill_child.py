"""Subprocess worker for the crash-recovery drill (DESIGN.md §12).

Boots a paged engine with periodic background snapshots
(``snapshot_every_steps``), submits the full request trace up front, then
dies hard (``os._exit``) mid-trace — after at least one periodic snapshot
has committed, before the trace drains. The parent test restores from the
snapshot directory and finishes the trace; the combined token streams must
be token-for-token identical to an uninterrupted run.

Not a test module (no ``test_`` prefix); invoked by
``tests/test_ep_serving.py``.
"""
import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot-dir", required=True)
    ap.add_argument("--kill-after-steps", type=int, default=12)
    args = ap.parse_args()

    import jax  # noqa: F401  (imported for side effects before repro)
    from repro import configs
    from repro.serving.engine import Engine, EngineConfig
    from _ep_child import build_trace

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    ec = EngineConfig(n_slots=4, s_max=64, prefill_buckets=(16, 32),
                      seed=0, decode_block=4, kv_layout="paged", kv_block=8,
                      snapshot_every_steps=4,
                      snapshot_dir=args.snapshot_dir)
    eng = Engine(ec, cfg=cfg)     # params = seeded MD.init default
    for t in build_trace(cfg):
        eng.submit(t["prompt"], t["max_new_tokens"],
                   arrival_time=t["arrival_time"])
    while not eng.idle:
        # report each finished request the moment it completes (flushed),
        # so the parent knows which token streams terminated PRE-crash —
        # terminal requests are the caller's to keep, not snapshot state
        for r in eng.step_block():
            sys.stdout.write(json.dumps(
                {"uid": int(r.uid), "status": r.status,
                 "tokens": [int(t) for t in r.out_tokens]}) + "\n")
            sys.stdout.flush()
        if eng.steps >= args.kill_after_steps:
            # SIGKILL-grade exit: no atexit, no cleanup, no farewell — the
            # only survivors are the committed snapshot directories and the
            # finished-request lines already flushed above
            os._exit(17)
    # reaching here means the trace drained before the kill point — the
    # drill proved nothing; fail loudly so the parent knows
    sys.stdout.write("TRACE DRAINED before kill point\n")
    sys.exit(3)


if __name__ == "__main__":
    main()
