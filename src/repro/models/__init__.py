from repro.models.config import ModelConfig, MoEConfig, SSMConfig  # noqa: F401
from repro.models import model  # noqa: F401
