from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, sgd, apply_updates, global_norm,
    cosine_schedule, make_optimizer, default_optimizer_for)
