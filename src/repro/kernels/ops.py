"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the Pallas implementations run natively; on
CPU (this container) they run through the jnp oracle by default, while tests
exercise the kernel bodies via ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def swiglu_mlp(x, wg, wu, wd, interpret: bool = False):
    if _on_tpu() or interpret:
        from repro.kernels import swiglu as _k
        return _k.swiglu_mlp(x, wg, wu, wd, interpret=not _on_tpu())
    return ref.swiglu_mlp(x, wg, wu, wd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def grouped_swiglu(x, wg, wu, wd, group_sizes, interpret: bool = False):
    if _on_tpu() or interpret:
        from repro.kernels import grouped_mlp as _k
        return _k.grouped_swiglu(x, wg, wu, wd, group_sizes,
                                 interpret=not _on_tpu())
    return ref.grouped_swiglu(x, wg, wu, wd, group_sizes)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_swiglu(x, wg, wu, wd, idx, w, interpret: bool = False):
    if _on_tpu() or interpret:
        from repro.kernels import decode_moe as _k
        return _k.gather_swiglu(x, wg, wu, wd, idx, w,
                                interpret=not _on_tpu())
    return ref.gather_swiglu(x, wg, wu, wd, idx, w)


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, causal: bool = True, interpret: bool = False):
    if _on_tpu() or interpret:
        from repro.kernels import flash_attention as _k
        return _k.flash_attention(q, k, v, causal=causal,
                                  interpret=not _on_tpu())
    return ref.flash_attention(q, k, v, causal=causal)
