"""End-to-end system behaviour: the full MergeMoE pipeline (train ->
calibrate -> merge -> serve), checkpoint/restart mid-training, and the
paper's qualitative claims at miniature scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compress as CMP
from repro.core import merge as MG
from repro.launch.train import TrainConfig, train
from repro.launch.serve import ServeConfig, Server
from repro.models import model as MD


@pytest.fixture(scope="module")
def trained():
    """A briefly-trained tiny MoE (loss visibly below init)."""
    tc = TrainConfig(arch="qwen3-moe-30b-a3b", reduced=True, steps=60,
                     global_batch=4, seq_len=64, lr=3e-3, ckpt_dir="",
                     log_every=1000)
    out = train(tc)
    assert out["losses"][-1] < out["losses"][0]
    return out["cfg"], out["params"]


def _batches(cfg, n, seed=500, batch=4, seq=64):
    return [{"tokens": jax.random.randint(jax.random.PRNGKey(seed + i),
                                          (batch, seq), 0, cfg.vocab_size)}
            for i in range(n)]


def test_full_pipeline_all_methods(trained):
    """All 4 merging strategies compress the SAME trained model at the SAME
    ratio; all stay finite and within a sane band of the uncompressed loss
    (paper Tables 1-3 mechanism)."""
    cfg, params = trained
    calib = _batches(cfg, 2)
    evalb = _batches(cfg, 3, seed=900)
    base = float(np.mean([float(MD.loss(cfg, params, b)[0]) for b in evalb]))
    results = {}
    for method in ("mergemoe", "msmoe", "average", "zipit"):
        ncfg, nparams, info = CMP.compress_model(
            cfg, params, method=method, merged_experts=4, split=1,
            batches=calib)
        loss = float(np.mean([float(MD.loss(ncfg, nparams, b)[0])
                              for b in evalb]))
        results[method] = loss
        assert np.isfinite(loss)
        assert info["compression_ratio"] > 1.05
    for m, l in results.items():
        assert l < base + 2.0, (m, l, base)


def test_mergemoe_calibration_error_beats_baselines(trained):
    """In-sample residual ordering (least-squares optimality) on REAL
    trained experts + REAL calibration activations."""
    cfg, params = trained
    from repro.core import calibration as CAL
    calib = CAL.collect(cfg, params, _batches(cfg, 2))
    layer = cfg.n_layers - 1
    moe = params["stack"]["moe"]
    wg = np.asarray(moe["wg"][layer], np.float32)
    wu = np.asarray(moe["wu"][layer], np.float32)
    wd = np.asarray(moe["wd"][layer], np.float32)
    X, counts = calib[layer].x, calib[layer].counts

    def err(method):
        res = MG.merge_layer(method, wg, wu, wd, counts, X, 4)
        total = 0.0
        for c in range(4):
            members = np.where(res.assign == c)[0]
            Z = sum(res.weights[j] * MG.expert_forward(
                X.astype(np.float64), wg[j].astype(np.float64),
                wu[j].astype(np.float64), wd[j].astype(np.float64))
                for j in members)
            Y = MG.expert_forward(X.astype(np.float64), res.wg[c],
                                  res.wu[c], res.wd[c])
            total += float(np.linalg.norm(Y - Z))
        return total

    assert err("mergemoe") <= err("msmoe") + 1e-9


def test_compressed_model_generates(trained):
    cfg, params = trained
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=1,
        batches=_batches(cfg, 1))
    sc = ServeConfig(reduced=True, batch_size=2, prompt_len=16,
                     max_new_tokens=8)
    srv = Server(sc, cfg=ncfg, params=nparams)
    prompts = np.random.default_rng(0).integers(
        0, ncfg.vocab_size, size=(2, 16), dtype=np.int32)
    out = srv.generate(prompts)
    assert out.shape == (2, 8)
    # greedy decoding is deterministic
    np.testing.assert_array_equal(out, srv.generate(prompts))


def test_checkpoint_restart_equivalence(tmp_path):
    """Fault tolerance: train 20 steps straight == train 10, 'crash',
    resume 10 (same data cursor, same step counter) to the same loss."""
    common = dict(arch="granite-8b", reduced=True, global_batch=2,
                  seq_len=32, lr=1e-3, log_every=1000, async_ckpt=False)
    straight = train(TrainConfig(steps=20, ckpt_dir="", **common))
    d = str(tmp_path / "ck")
    train(TrainConfig(steps=10, ckpt_dir=d, ckpt_every=10, **common))
    resumed = train(TrainConfig(steps=20, ckpt_dir=d, ckpt_every=10, **common))
    assert abs(straight["losses"][-1] - resumed["losses"][-1]) < 5e-2


def test_oracle_upper_bounds_merged(trained):
    """Paper Table 5: keeping clustering but merging outputs EXACTLY
    (w/o merging errors) is at least as good as the compressed model."""
    cfg, params = trained
    from repro.core import calibration as CAL
    from repro.core import clustering as CL
    from repro.core import oracle as ORC
    batches = _batches(cfg, 2)
    calib = CAL.collect(cfg, params, batches)
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=4, split=0,
        batches=batches)
    remaps = np.asarray(nparams["stack_c"]["moe"]["remap"])
    assigns, bweights = {}, {}
    for l in range(cfg.n_layers):
        assigns[l] = remaps[l]
        bweights[l] = CL.merge_weights(remaps[l], calib[l].counts, 4)
    batch = batches[0]
    logits_full, _, _ = MD.forward(cfg, params, batch)
    logits_oracle = ORC.oracle_forward(cfg, params, batch, assigns, bweights)
    logits_merged, _, _ = MD.forward(ncfg, nparams, batch)
    e_oracle = float(jnp.mean((logits_oracle.astype(jnp.float32)
                               - logits_full.astype(jnp.float32)) ** 2))
    e_merged = float(jnp.mean((logits_merged.astype(jnp.float32)
                               - logits_full.astype(jnp.float32)) ** 2))
    assert np.isfinite(e_oracle) and np.isfinite(e_merged)
    assert e_oracle <= e_merged * 1.25 + 1e-6
