"""Per-kernel interpret-mode validation against the pure-jnp oracles in
repro.kernels.ref — shape/dtype sweeps + hypothesis property tests. The
int8 sweeps assert BITWISE equality with the jnp dequant oracles
(DESIGN.md §8): the kernels keep the dequantized weights at fp32 with a
single output-side downcast, so there is no rounding XLA can cancel or
contract out from under the comparison."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quant as Q
from repro.kernels import ref
from repro.kernels import swiglu as K_swiglu
from repro.kernels import flash_attention as K_fa
from repro.kernels import grouped_mlp as K_gm
from repro.kernels import decode_moe as K_dm

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


def _randn(shape, dtype, scale=0.5):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# fused SwiGLU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d,f,bt,bf", [
    (32, 16, 32, 8, 8),
    (64, 32, 48, 16, 16),
    (128, 64, 64, 128, 64),   # single block each way
    (48, 24, 96, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swiglu_shapes(T, d, f, bt, bf, dtype):
    x = _randn((T, d), dtype)
    wg, wu = _randn((d, f), dtype, 0.2), _randn((d, f), dtype, 0.2)
    wd = _randn((f, d), dtype, 0.2)
    y = K_swiglu.swiglu_mlp(x, wg, wu, wd, block_t=bt, block_f=bf,
                            interpret=True)
    yr = ref.swiglu_mlp(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([16, 40, 64]), d=st.sampled_from([8, 24]),
       f=st.sampled_from([16, 48]), bt=st.sampled_from([8, 16]))
def test_swiglu_property(T, d, f, bt):
    x = _randn((T, d), jnp.float32)
    wg, wu = _randn((d, f), jnp.float32, 0.2), _randn((d, f), jnp.float32, 0.2)
    wd = _randn((f, d), jnp.float32, 0.2)
    y = K_swiglu.swiglu_mlp(x, wg, wu, wd, block_t=bt, block_f=16,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(
        ref.swiglu_mlp(x, wg, wu, wd)), atol=1e-4, rtol=1e-4)


def test_swiglu_zero_weights_give_zero():
    x = _randn((16, 8), jnp.float32)
    z = jnp.zeros((8, 16), jnp.float32)
    zd = jnp.zeros((16, 8), jnp.float32)
    y = K_swiglu.swiglu_mlp(x, z, z, zd, block_t=8, block_f=8, interpret=True)
    assert float(jnp.abs(y).max()) == 0.0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,hd,bq,bk", [
    (1, 1, 32, 8, 8, 8),
    (2, 3, 64, 16, 16, 16),
    (1, 2, 128, 32, 64, 32),
    (2, 1, 96, 16, 32, 16),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, hd, bq, bk, causal, dtype):
    q, k, v = (_randn((B, H, S, hd), dtype) for _ in range(3))
    o = K_fa.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                             interpret=True)
    orf = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


def test_flash_cross_attention_rect():
    """Sq != Skv (non-causal cross attention)."""
    q = _randn((1, 2, 32, 16), jnp.float32)
    k = _randn((1, 2, 64, 16), jnp.float32)
    v = _randn((1, 2, 64, 16), jnp.float32)
    o = K_fa.flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref.flash_attention(q, k, v, causal=False)),
        atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(S=st.sampled_from([16, 48, 80]), hd=st.sampled_from([8, 16]),
       causal=st.booleans())
def test_flash_property(S, hd, causal):
    q, k, v = (_randn((1, 2, S, hd), jnp.float32) for _ in range(3))
    o = K_fa.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16,
                             interpret=True)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(ref.flash_attention(q, k, v, causal=causal)),
        atol=1e-4, rtol=1e-4)


def test_flash_softmax_invariance():
    """Attention output is invariant to adding a constant to all logits —
    equivalently to scaling q by 0: output becomes mean of v rows (causal
    prefix mean). Checks the online-softmax normalizer."""
    B, H, S, hd = 1, 1, 32, 8
    q = jnp.zeros((B, H, S, hd), jnp.float32)
    k = _randn((B, H, S, hd), jnp.float32)
    v = _randn((B, H, S, hd), jnp.float32)
    o = K_fa.flash_attention(q, k, v, causal=True, block_q=8, block_k=8,
                             interpret=True)
    expect = jnp.cumsum(v[0, 0], axis=0) / jnp.arange(1, S + 1)[:, None]
    np.testing.assert_allclose(np.asarray(o[0, 0]), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# grouped (MoE) SwiGLU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [
    [10, 0, 37, 17],        # empty group
    [64],                   # single expert
    [1, 1, 1, 1, 60],       # tiny + dominant groups
    [16, 16, 16, 16],       # block-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_swiglu(sizes, dtype):
    d, f = 24, 32
    E = len(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    T = int(gs.sum())
    x = _randn((T, d), dtype)
    wg, wu = _randn((E, d, f), dtype, 0.2), _randn((E, d, f), dtype, 0.2)
    wd = _randn((E, f, d), dtype, 0.2)
    y = K_gm.grouped_swiglu(x, wg, wu, wd, gs, block_t=16, block_f=16,
                            interpret=True)
    yr = ref.grouped_swiglu(x, wg, wu, wd, gs)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), min_size=2,
                max_size=5).filter(lambda s: sum(s) > 0))
def test_grouped_property(sizes):
    d, f = 16, 16
    E = len(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    T = int(gs.sum())
    x = _randn((T, d), jnp.float32)
    wg, wu = _randn((E, d, f), jnp.float32, 0.2), _randn((E, d, f), jnp.float32, 0.2)
    wd = _randn((E, f, d), jnp.float32, 0.2)
    y = K_gm.grouped_swiglu(x, wg, wu, wd, gs, block_t=8, block_f=16,
                            interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.grouped_swiglu(x, wg, wu, wd, gs)),
        atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("sizes", [
    [0, 10],                # empty FIRST group (duplicate start at 0)
    [10, 0],                # empty LAST group
    [0, 0, 16],             # consecutive leading empties
    [5, 0, 0, 0],           # consecutive trailing empties
    [3, 0, 0, 3],           # empty run in the middle
    [0, 0, 0, 0, 64],       # all-but-one empty
    [40, 0, 24, 0, 16, 0, 8, 0],   # post-merge pattern: remap emptied every
                                   # absorbed expert's bucket (M = N/2)
    [0, 0, 0, 0],           # fully empty (T == 0)
])
def test_grouped_swiglu_zero_groups_regression(sizes):
    """Zero-sized expert groups — exactly the layout after aggressive
    MergeMoE merging — must neither skip nor misattribute blocks. Guards the
    block->expert mapping against duplicate entries in ``padded_starts``."""
    d, f = 24, 32
    E = len(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    T = int(gs.sum())
    x = _randn((T, d), jnp.float32)
    wg, wu = _randn((E, d, f), jnp.float32, 0.2), _randn((E, d, f), jnp.float32, 0.2)
    wd = _randn((E, f, d), jnp.float32, 0.2)
    y = K_gm.grouped_swiglu(x, wg, wu, wd, gs, block_t=16, block_f=16,
                            interpret=True)
    assert y.shape == (T, d)
    yr = ref.grouped_swiglu(x, wg, wu, wd, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# gather (decode-mode MoE) SwiGLU
# ---------------------------------------------------------------------------

def _gather_inputs(T, d, f, E, k, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, d)) * 0.5, dtype)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, dtype)
    wu = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, dtype)
    wd = jnp.asarray(rng.standard_normal((E, f, d)) * 0.2, dtype)
    idx = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    w = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((T, k)), jnp.float32), axis=-1)
    return x, wg, wu, wd, idx, w


@pytest.mark.parametrize("T,d,f,E,k", [
    (4, 24, 32, 8, 2),      # decode shape: n_slots tokens
    (1, 16, 16, 4, 1),      # single token, single expert
    (8, 32, 48, 8, 3),      # k > 2
    (3, 16, 32, 2, 2),      # tiny expert table
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_swiglu(T, d, f, E, k, dtype):
    x, wg, wu, wd, idx, w = _gather_inputs(T, d, f, E, k, dtype)
    y = K_dm.gather_swiglu(x, wg, wu, wd, idx, w, interpret=True)
    yr = ref.gather_swiglu(x, wg, wu, wd, idx, w)
    assert y.shape == (T, d) and y.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


def test_gather_swiglu_duplicate_expert_sums_weights():
    """A token whose top-k selects the SAME expert twice must weight that
    expert by the sum — exactly the post-merge remap situation where two
    original experts collapse onto one merged row."""
    T, d, f, E = 2, 16, 16, 4
    x, wg, wu, wd, _, _ = _gather_inputs(T, d, f, E, 2, jnp.float32)
    idx = jnp.asarray([[1, 1], [2, 0]], jnp.int32)
    w = jnp.asarray([[0.3, 0.7], [0.5, 0.5]], jnp.float32)
    y = K_dm.gather_swiglu(x, wg, wu, wd, idx, w, interpret=True)
    one = ref.swiglu_mlp(x[:1], wg[1], wu[1], wd[1])
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(one[0]),
                               atol=1e-5, rtol=1e-5)


def test_gather_swiglu_matches_sorted_grouped_composition():
    """gather(x, idx, w) == the ragged pipeline (sort by expert, grouped
    kernel, weighted scatter-add) on the same routing — the moe_apply-level
    dispatch-parity contract at kernel granularity."""
    T, d, f, E, k = 6, 24, 32, 8, 2
    x, wg, wu, wd, idx, w = _gather_inputs(T, d, f, E, k, jnp.float32, seed=3)
    y = K_dm.gather_swiglu(x, wg, wu, wd, idx, w, interpret=True)

    flat = np.asarray(idx).reshape(-1)
    order = np.argsort(flat, kind="stable")
    tok_of = order // k
    xs = x[tok_of]
    gs = jnp.asarray(np.bincount(flat, minlength=E), jnp.int32)
    ys = K_gm.grouped_swiglu(xs, wg, wu, wd, gs, block_t=8, block_f=16,
                             interpret=True)
    wf = np.asarray(w).reshape(-1)[order]
    out = np.zeros((T, d), np.float32)
    np.add.at(out, tok_of, np.asarray(ys, np.float32) * wf[:, None])
    np.testing.assert_allclose(np.asarray(y), out, atol=1e-5, rtol=1e-5)


def test_gather_swiglu_clips_out_of_bounds_idx():
    """Corrupted expert ids must not read out of bounds (routing fails
    closed upstream; the kernel clips as defense-in-depth, same as the
    oracle)."""
    T, d, f, E, k = 2, 16, 16, 4, 2
    x, wg, wu, wd, _, w = _gather_inputs(T, d, f, E, k, jnp.float32)
    idx = jnp.asarray([[E + 3, 0], [1, -7]], jnp.int32)
    y = K_dm.gather_swiglu(x, wg, wu, wd, idx, w, interpret=True)
    yr = ref.gather_swiglu(x, wg, wu, wd, idx, w)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(T=st.sampled_from([1, 3, 8]), E=st.sampled_from([2, 8]),
       k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100))
def test_gather_property(T, E, k, seed):
    x, wg, wu, wd, idx, w = _gather_inputs(T, 16, 16, E, k, jnp.float32,
                                           seed=seed)
    y = K_dm.gather_swiglu(x, wg, wu, wd, idx, w, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.gather_swiglu(x, wg, wu, wd, idx, w)),
        atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 kernels (fused dequant) — bitwise vs the jnp dequant oracles
# ---------------------------------------------------------------------------

def _quant_inputs(T, d, f, E, k, dtype, seed=0, live=None):
    """Random int8-quantized tables (+ optional hetero zero pad rows beyond
    ``live``), routing restricted to live rows."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, d)) * 0.5, dtype)
    wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, dtype)
    wu = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, dtype)
    wd = jnp.asarray(rng.standard_normal((E, f, d)) * 0.2, dtype)
    if live is not None:
        wg, wu, wd = (w.at[live:].set(0) for w in (wg, wu, wd))
    qt = Q.quantize_expert_tables(wg, wu, wd)
    idx = jnp.asarray(rng.integers(0, live or E, (T, k)), jnp.int32)
    w = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((T, k)), jnp.float32), axis=-1)
    return x, qt, idx, w


@pytest.mark.parametrize("T,d,f,E,k", [
    (4, 24, 32, 8, 2),      # decode shape: n_slots tokens
    (1, 16, 16, 4, 1),      # single token, single expert
    (8, 32, 48, 8, 3),      # k > 2
    (6, 16, 16, 8, 4),      # k == 4
    (3, 16, 32, 2, 2),      # tiny expert table
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_swiglu_q_bitwise(T, d, f, E, k, dtype):
    """Int8 gather kernel == jnp dequant oracle, BIT FOR BIT."""
    x, qt, idx, w = _quant_inputs(T, d, f, E, k, dtype, seed=T + k)
    y = K_dm.gather_swiglu_q(x, qt, idx, w, interpret=True)
    yr = ref.gather_swiglu_q(x, qt, idx, w)
    assert y.shape == (T, d) and y.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_swiglu_q_duplicate_topk_bitwise(dtype):
    """Duplicate top-k experts (the post-merge remap collision case) stay
    bitwise: the same expert's contribution enters the fp32 combine once
    per slot with its own weight."""
    x, qt, _, w = _quant_inputs(4, 16, 16, 4, 2, dtype, seed=5)
    idx = jnp.asarray([[1, 1], [2, 0], [3, 3], [0, 0]], jnp.int32)
    y = K_dm.gather_swiglu_q(x, qt, idx, w, interpret=True)
    yr = ref.gather_swiglu_q(x, qt, idx, w)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))
    # weights summing on one expert == that expert's full output
    deq = qt.dequant(dtype)
    one = ref.gather_swiglu(x[:1], *deq, jnp.asarray([[1]], jnp.int32),
                            jnp.ones((1, 1), jnp.float32))
    np.testing.assert_allclose(np.asarray(y[0], np.float32),
                               np.asarray(one[0], np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_swiglu_q_hetero_live_masked_bitwise(dtype):
    """Hetero live-masked tables: pad rows are zeros with zero scales;
    routing stays below ``live``. Kernel == oracle bitwise, and a poisoned
    OOB id clips identically on both sides."""
    x, qt, idx, w = _quant_inputs(5, 16, 16, 8, 2, dtype, seed=9, live=5)
    y = K_dm.gather_swiglu_q(x, qt, idx, w, interpret=True)
    yr = ref.gather_swiglu_q(x, qt, idx, w)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))
    bad = jnp.asarray([[11, 0], [1, -7], [0, 0], [1, 1], [2, 2]], jnp.int32)
    yb = K_dm.gather_swiglu_q(x, qt, bad, w, interpret=True)
    yrb = ref.gather_swiglu_q(x, qt, bad, w)
    assert np.isfinite(np.asarray(yb, np.float32)).all()
    np.testing.assert_array_equal(np.asarray(yb, np.float32),
                                  np.asarray(yrb, np.float32))


@pytest.mark.parametrize("sizes", [
    [10, 0, 37, 17],        # empty group
    [1, 1, 1, 1, 60],       # tiny + dominant groups
    [40, 0, 24, 0, 16, 0, 8, 0],   # post-merge: absorbed buckets empty
    [0, 0, 0, 0],           # fully empty (T == 0)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_swiglu_q_bitwise(sizes, dtype):
    """Int8 grouped kernel == jnp dequant oracle bitwise with the f axis
    unblocked (block_f >= f), including zero-sized groups."""
    d, f = 24, 32
    E = len(sizes)
    gs = jnp.asarray(sizes, jnp.int32)
    T = int(gs.sum())
    x, qt, _, _ = _quant_inputs(max(T, 1), d, f, E, 2, dtype, seed=E)
    x = x[:T]
    y = K_gm.grouped_swiglu_q(x, qt, gs, block_t=16, block_f=f,
                              interpret=True)
    yr = ref.grouped_swiglu_q(x, qt, gs)
    assert y.shape == (T, d)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(yr, np.float32))


def test_grouped_swiglu_q_blocked_f_allclose():
    """Blocking the f axis reassociates the fp32 accumulation across
    f-blocks — allclose, not bitwise (DESIGN.md §8)."""
    d, f = 16, 32
    gs = jnp.asarray([5, 3, 0, 8], jnp.int32)
    x, qt, _, _ = _quant_inputs(16, d, f, 4, 2, jnp.float32, seed=3)
    y = K_gm.grouped_swiglu_q(x, qt, gs, block_t=8, block_f=16,
                              interpret=True)
    yr = ref.grouped_swiglu_q(x, qt, gs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)


def test_gather_q_matches_grouped_q_composition():
    """Int8 gather == the int8 ragged pipeline (sort, grouped_q kernel,
    fp32 weighted scatter-add) on the same routing — the §8 extension of
    the dispatch-parity contract at kernel granularity."""
    T, d, f, E, k = 6, 24, 32, 8, 2
    x, qt, idx, w = _quant_inputs(T, d, f, E, k, jnp.float32, seed=13)
    y = K_dm.gather_swiglu_q(x, qt, idx, w, interpret=True)

    flat = np.asarray(idx).reshape(-1)
    order = np.argsort(flat, kind="stable")
    tok_of = order // k
    xs = x[tok_of]
    gs = jnp.asarray(np.bincount(flat, minlength=E), jnp.int32)
    ys = K_gm.grouped_swiglu_q(xs, qt, gs, block_t=8, block_f=f,
                               interpret=True)
    wf = np.asarray(w).reshape(-1)[order]
    out = np.zeros((T, d), np.float32)
    np.add.at(out, tok_of, np.asarray(ys, np.float32) * wf[:, None])
    np.testing.assert_allclose(np.asarray(y), out, atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(T=st.sampled_from([1, 3, 8]), E=st.sampled_from([2, 8]),
       k=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100))
def test_gather_q_property_bitwise(T, E, k, seed):
    x, qt, idx, w = _quant_inputs(T, 16, 16, E, k, jnp.float32, seed=seed)
    y = K_dm.gather_swiglu_q(x, qt, idx, w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(ref.gather_swiglu_q(x, qt, idx, w)))


def test_grouped_matches_single_expert_swiglu():
    """One expert == plain fused SwiGLU."""
    d, f, T = 16, 32, 48
    x = _randn((T, d), jnp.float32)
    wg, wu = _randn((1, d, f), jnp.float32, 0.2), _randn((1, d, f), jnp.float32, 0.2)
    wd = _randn((1, f, d), jnp.float32, 0.2)
    y = K_gm.grouped_swiglu(x, wg, wu, wd, jnp.asarray([T], jnp.int32),
                            block_t=16, block_f=16, interpret=True)
    y2 = ref.swiglu_mlp(x, wg[0], wu[0], wd[0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention (DESIGN.md §11)
# ---------------------------------------------------------------------------

from repro.kernels import paged_attention as K_pa  # noqa: E402


def _paged_inputs(B, nq, nkv, hd, nb, bs, mb, seed=0, dtype=jnp.float32):
    """Random pool + a valid per-slot table: each slot owns ceil(lens/bs)
    distinct blocks; remaining table entries are the sentinel ``nb``."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, nq, hd)) * 0.5, dtype)
    kp = jnp.asarray(rng.standard_normal((nb, bs, nkv, hd)) * 0.5, dtype)
    vp = jnp.asarray(rng.standard_normal((nb, bs, nkv, hd)) * 0.5, dtype)
    lens = rng.integers(1, mb * bs + 1, size=B).astype(np.int32)
    tab = np.full((B, mb), nb, np.int32)
    perm = rng.permutation(nb)
    used = 0
    for b in range(B):
        need = -(-int(lens[b]) // bs)
        tab[b, :need] = perm[used:used + need]
        used += need
    assert used <= nb, "test pool too small"
    return q, kp, vp, jnp.asarray(tab), jnp.asarray(lens)


@pytest.mark.parametrize("B,nq,nkv,hd,bs,mb", [
    (2, 4, 4, 16, 4, 3),      # MHA (n_rep = 1)
    (3, 8, 2, 16, 8, 2),      # GQA (n_rep = 4)
    (1, 4, 4, 32, 4, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_oracle(B, nq, nkv, hd, bs, mb, dtype):
    nb = B * mb + 2
    q, kp, vp, tab, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb,
                                         seed=B * 7 + mb, dtype=dtype)
    y = K_pa.paged_attention(q, kp, vp, tab, lens, interpret=True)
    yr = ref.paged_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_q_matches_oracle(dtype):
    B, nq, nkv, hd, bs, mb = 3, 8, 2, 16, 4, 3
    nb = B * mb + 1
    q, kp, vp, tab, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb,
                                         seed=5, dtype=dtype)
    kq, ks = Q.quantize_kv(kp)
    vq, vs = Q.quantize_kv(vp)
    y = K_pa.paged_attention_q(q, kq, vq, ks, vs, tab, lens, interpret=True)
    yr = ref.paged_attention_q(q, kq, vq, ks, vs, tab, lens)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


def test_paged_attention_sentinel_blocks_contribute_nothing():
    """Unallocated table entries (sentinel == n_blocks, clipped in range by
    the wrapper) and rows past ``lens`` must contribute exactly zero
    probability: poisoning every block the slot does NOT own with huge
    values cannot change the output."""
    B, nq, nkv, hd, bs, mb = 2, 4, 2, 16, 4, 3
    nb = B * mb + 2
    q, kp, vp, tab, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb, seed=11)
    owned = set(np.asarray(tab).reshape(-1).tolist()) - {nb}
    poison = np.asarray(vp).copy()
    for blk in range(nb):
        if blk not in owned:
            poison[blk] = 1e4
    y0 = K_pa.paged_attention(q, kp, vp, tab, lens, interpret=True)
    y1 = K_pa.paged_attention(q, kp, jnp.asarray(poison), tab, lens,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_paged_attention_zero_lens_row_is_finite():
    """lens == 0 (a slot with nothing admitted yet, e.g. the sentinel pad
    row of a partially-filled admission group) must produce finite output —
    the fully-masked-row normalizer guard, not NaNs from 0/0."""
    B, nq, nkv, hd, bs, mb = 2, 4, 2, 16, 4, 2
    nb = B * mb
    q, kp, vp, tab, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb, seed=3)
    lens = jnp.asarray([0, int(lens[1])], jnp.int32)
    y = K_pa.paged_attention(q, kp, vp, tab, lens, interpret=True)
    assert bool(jnp.isfinite(y).all())
    yr = ref.paged_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(yr[1]),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_matches_dense_sdpa_on_contiguous_table():
    """An identity table (slot b owns blocks [b*mb, b*mb+mb)) makes the pool
    a reshaped dense cache: the paged oracle must then agree with the dense
    decode attention the slot engine uses."""
    B, nq, nkv, hd, bs, mb = 2, 4, 2, 16, 4, 3
    nb = B * mb
    q, kp, vp, _, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb, seed=9)
    tab = jnp.arange(nb, dtype=jnp.int32).reshape(B, mb)
    y = K_pa.paged_attention(q, kp, vp, tab, lens, interpret=True)
    kc = kp.reshape(B, mb * bs, nkv, hd)
    vc = vp.reshape(B, mb * bs, nkv, hd)
    yr = ref._paged_sdpa(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(B=st.sampled_from([1, 2, 4]), nkv=st.sampled_from([1, 2]),
       bs=st.sampled_from([4, 8]), seed=st.integers(0, 100))
def test_paged_attention_property(B, nkv, bs, seed):
    nq, hd, mb = nkv * 2, 16, 2
    nb = B * mb + 1
    q, kp, vp, tab, lens = _paged_inputs(B, nq, nkv, hd, nb, bs, mb,
                                         seed=seed)
    y = K_pa.paged_attention(q, kp, vp, tab, lens, interpret=True)
    yr = ref.paged_attention(q, kp, vp, tab, lens)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)
