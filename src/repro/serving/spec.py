"""Self-speculative decoding: the MergeMoE-compressed model drafts, the
full model verifies — on device (DESIGN.md §10).

MergeMoE solves its merge matrices to minimize the gap between the merged
experts' OUTPUTS and the full model's, which is exactly the property a
speculative-decoding draft needs: cheap forward, output distribution close
to the target. The residual gap is small but real, so drafts are verified,
never trusted — every committed token is a FULL-MODEL sample by
construction, which makes spec decode token-for-token identical to plain
full-model decode at any temperature.

One round, inside one jitted program (``build_slot_decode_spec``):

1. DRAFT — ``k_draft`` fused decode steps of the compressed model over all
   slots (the same scan shape as ``steps.make_slot_decode_multi``),
   sampling each proposal with the position-indexed Gumbel schedule
   (``steps.sample_tokens``).
2. VERIFY — the full model scores the last committed token plus all K
   proposals in ONE multi-position forward (``model.verify_step_slots``;
   prefill-shaped, so MoE dispatch takes the grouped path) and samples a
   full-model token at every position UNDER THE SAME NOISE the draft used.
3. ACCEPT/ROLLBACK — longest matching prefix between proposals and verify
   samples (``accept_drafts``); both caches' ``pos`` move to the committed
   length. Rollback is free: the rows past ``pos`` hold stale draft KV that
   the per-slot causal mask hides and the next round overwrites in place —
   the same mechanism §7 already uses for slot eviction.

Import direction: this module imports models + launch.steps; the engine
imports launch.steps, whose ``make_slot_*_spec`` wrappers lazy-import this
module. Nothing here imports the engine.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import model as MD
from repro.launch import steps as ST


def accept_drafts(drafts: jax.Array, verify: jax.Array, active: jax.Array,
                  remaining: jax.Array, eos: jax.Array, k_draft: int):
    """Longest-matching-prefix acceptance + §7 stop-flag semantics.

    drafts:    [B, K]   draft proposals d_1..d_K
    verify:    [B, K+1] full-model samples v_0..v_K (v_j scored at the
               position AFTER draft prefix d_1..d_j)
    active:    [B] bool — slots participating in this round
    remaining: [B] int32 — generation budget left per slot
    eos:       [B] int32 — stop token per slot (-1 = none)

    Committed tokens are ALWAYS verify samples: v_j is a commit candidate
    when every draft before it matched (d_i == v_{i-1} for all i <= j), so
    v_0 commits even when every draft is rejected — a round always makes
    progress. Candidates are capped at K per round: the (K+1)-th verify
    sample is correct too, but the draft cache holds no KV for d_K (the
    K-step draft scan consumes t0, d_1..d_{K-1}), so committing it would
    advance ``pos`` past a garbage row the draft model attends next round.

    The stop flags compose with acceptance exactly like §7's fused decode:
    a candidate is EMITTED only while the slot is active, within budget,
    and no earlier emitted candidate was eos — an eos inside the accepted
    prefix freezes the slot mid-round and discards everything after it.

    Returns (emitted [B, K] bool, n_commit [B] int32, n_match [B] int32,
    still_active [B] bool). ``n_match`` (accepted drafts, gated on
    ``active``) feeds the engine's drafted/accepted/rolled-back counters.
    """
    K = int(k_draft)
    match = drafts == verify[:, :K]
    # prefix length: number of leading True entries per row
    n_match = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    n_acc = jnp.minimum(n_match + 1, K)
    cand = verify[:, :K]
    idx = jnp.arange(K)[None, :]
    is_eos = (cand == eos[:, None]) & (eos[:, None] >= 0)
    eos_before = jnp.cumsum(is_eos.astype(jnp.int32), axis=1) - is_eos
    emitted = ((idx < n_acc[:, None]) & (idx < remaining[:, None])
               & (eos_before == 0) & active[:, None])
    n_commit = jnp.sum(emitted.astype(jnp.int32), axis=1)
    hit_eos = jnp.any(emitted & is_eos, axis=1)
    still = active & ~hit_eos & (remaining - n_commit > 0)
    return emitted, n_commit, n_match * active.astype(jnp.int32), still


def build_slot_decode_spec(cfg: ModelConfig, draft_cfg: ModelConfig,
                           k_draft: int, temperature: float = 0.0) -> Callable:
    """Build the fused draft/verify round (engine entry:
    ``steps.make_slot_decode_spec``).

    slot_decode_spec(params, draft_params, cache, draft_cache, token [B],
    active [B], remaining [B], eos [B], keys [B, 2], poison [B] bool)
    -> (block [K+1, B, 3] int32, active [B] bool, cache, draft_cache)

    Rows 0..K-1 of ``block`` are ``(token, emitted, finite)`` triples with
    exactly the ``make_slot_decode_multi`` contract — including the
    numeric-health sentinel lane (DESIGN.md §12), computed over the VERIFY
    logits (committed tokens are always verify samples, so that is where a
    numeric fault reaches the output stream) — so the engine's replay loop
    is shared. Row K packs the acceptance stats ``(n_match, n_drafted, 1)``
    per slot into the same array, keeping the whole round at ONE
    device->host readback. ``poison`` is the fault-injection mask
    (``serving.faults``): True rows get their verify logits NaN-poisoned;
    an all-False mask is a bitwise no-op.
    """
    K = int(k_draft)
    if K < 1:
        raise ValueError(f"k_draft must be >= 1, got {k_draft}")

    def slot_decode_spec(params, draft_params, cache, draft_cache, token,
                         active, remaining, eos, keys, poison):
        pos0 = cache["pos"]

        # 1. draft: K fused decode steps of the compressed model. No eos /
        # budget freezing inside the draft — rejected tail tokens are
        # discarded by acceptance anyway, and the stop flags are applied to
        # the COMMITTED stream below, where they are authoritative.
        def dstep(carry, _):
            dcache, tok = carry
            logits, dcache = MD.decode_step_slots(draft_cfg, draft_params,
                                                  dcache, tok, active)
            nxt = ST.sample_tokens(logits, temperature, keys, dcache["pos"])
            return (dcache, nxt), nxt

        (draft_cache, _), drafts = jax.lax.scan(
            dstep, (draft_cache, token), None, length=K)
        drafts = jnp.swapaxes(drafts, 0, 1)                    # [B, K]

        # 2. verify: one full-model forward over [t0, d_1..d_K], sampled
        # under the SAME (key, position) noise the draft used — v_{j-1}
        # and d_j score the same position, so agreement means "the full
        # model would have sampled exactly this token".
        vtokens = jnp.concatenate([token[:, None], drafts], axis=1)
        vlogits, cache = MD.verify_step_slots(cfg, params, cache, vtokens)
        vlogits = jnp.where(poison[:, None, None], jnp.nan, vlogits)
        finite = jnp.all(jnp.isfinite(vlogits), axis=-1)     # [B, K+1]
        B, T, V = vlogits.shape
        vpos = pos0[:, None] + 1 + jnp.arange(T)[None, :]      # [B, K+1]
        vkeys = jnp.broadcast_to(keys[:, None, :],
                                 (B, T) + keys.shape[1:]).reshape((B * T,)
                                                                  + keys.shape[1:])
        verify = ST.sample_tokens(vlogits.reshape(B * T, V), temperature,
                                  vkeys, vpos.reshape(-1)).reshape(B, T)

        # 3. accept / rollback
        emitted, n_commit, n_match, still = accept_drafts(
            drafts, verify, active, remaining, eos, K)

        # rollback is free: pos = committed length. Rows past it hold stale
        # draft (or rejected-verify) KV that the per-slot causal mask hides
        # and the next round overwrites in place — §7's eviction semantics,
        # reused unchanged. The draft cache's pos (advanced K times above)
        # is pulled back to agree with the full cache bitwise.
        new_pos = pos0 + n_commit
        cache = dict(cache, pos=new_pos)
        draft_cache = dict(draft_cache, pos=new_pos)

        cand = verify[:, :K]
        stats = jnp.stack(
            [n_match, jnp.where(active, K, 0).astype(jnp.int32),
             jnp.ones_like(n_match)], axis=-1)
        block = jnp.concatenate(
            [jnp.stack([jnp.swapaxes(cand, 0, 1),
                        jnp.swapaxes(emitted, 0, 1).astype(jnp.int32),
                        jnp.swapaxes(finite[:, :K], 0, 1).astype(jnp.int32)],
                       axis=-1),
             stats[None]], axis=0)                             # [K+1, B, 3]
        return block, still, cache, draft_cache

    return slot_decode_spec


def build_slot_admit_spec(cfg: ModelConfig, draft_cfg: ModelConfig,
                          temperature: float = 0.0) -> Callable:
    """Build fused dual-model admission (engine entry:
    ``steps.make_slot_admit_spec``).

    slot_admit_spec(params, draft_params, cache, draft_cache,
    tokens [B, S_bucket], lengths [B], slots [B], keys [B, 2])
    -> (logits [B, V], first [B] int32, cache, draft_cache)

    Both models prefill the same padded prompt group and insert into their
    own slot caches in ONE dispatch (pad rows carry out-of-bounds slot ids;
    scatter drops them — the single-model ``make_slot_admit`` contract).
    The first token is sampled from the FULL model's prefill logits at
    position ``lengths`` under the position-indexed schedule: the draft
    never decides a committed token, and the sample is bitwise what any
    non-spec engine mode produces for the same request.
    """
    def slot_admit_spec(params, draft_params, cache, draft_cache, tokens,
                        lengths, slots, keys):
        logits, k_new, v_new = MD.prefill_slots(cfg, params, tokens, lengths)
        cache = MD.insert_slots(cache, slots, k_new, v_new, lengths)
        dlogits, dk, dv = MD.prefill_slots(draft_cfg, draft_params, tokens,
                                           lengths)
        del dlogits  # the draft's first-token opinion is never consulted
        draft_cache = MD.insert_slots(draft_cache, slots, dk, dv, lengths)
        first = ST.sample_tokens(logits, temperature, keys, lengths)
        return logits, first, cache, draft_cache

    return slot_admit_spec


def build_slot_admit_spec_paged(cfg: ModelConfig, draft_cfg: ModelConfig,
                                temperature: float = 0.0) -> Callable:
    """Paged-pool dual-model admission (engine entry:
    ``steps.make_slot_admit_spec_paged``, DESIGN.md §11).

    slot_admit_spec_paged(params, draft_params, cache, draft_cache,
    tokens [B, S_bucket], lengths [B], slots [B], pos0 [B], keys [B, 2])
    -> (logits [B, V], first [B] int32, cache, draft_cache)

    Both models run the SAME suffix group (``tokens``/``lengths``/``pos0``
    follow the ``model.admit_slots_paged`` contract) into their own block
    pools; the engine ships ONE allocator table to both caches, so a prefix
    chain shared in the full-model pool is shared in the draft pool at the
    same block ids. The first token is sampled from the FULL model's logits
    at absolute position ``pos0 + lengths`` (= the prompt length), bitwise
    what any non-spec, non-paged mode produces for the same request."""
    def slot_admit_spec_paged(params, draft_params, cache, draft_cache,
                              tokens, lengths, slots, pos0, keys):
        logits, cache = MD.admit_slots_paged(cfg, params, cache, tokens,
                                             lengths, slots, pos0)
        dlogits, draft_cache = MD.admit_slots_paged(
            draft_cfg, draft_params, draft_cache, tokens, lengths, slots,
            pos0)
        del dlogits  # the draft's first-token opinion is never consulted
        first = ST.sample_tokens(logits, temperature, keys, pos0 + lengths)
        return logits, first, cache, draft_cache

    return slot_admit_spec_paged
