"""mamba2-370m — pure SSM, SSD (state-space duality) [arXiv:2405.21060;
unverified].

48L d_model=1024, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Sub-quadratic -> eligible for long_500k.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=16,       # unused (attention-free); kept for config uniformity
    n_kv_heads=16,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, conv_width=4,
                  chunk_size=512),
    remat="full",
)
