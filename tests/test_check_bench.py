"""scripts/check_bench.py — the serve-bench parity gate shared by the fast
and slow CI lanes. Exercises check() on good/mutated summary dicts and the
CLI exit codes on real JSON files."""
import copy
import json
import sys

import pytest

sys.path.insert(0, "scripts")
from check_bench import _records, check, main  # noqa: E402


def _rec(**over):
    rec = {"weight_dtype": "bfloat16", "retraces": 0,
           "implicit_transfers": 0, "moe_expert_bytes_per_token": 1.0,
           "shed": 0, "quarantined": 0, "transient_retries": 0}
    rec.update(over)
    return rec


@pytest.fixture()
def good():
    """Minimal summary with the same shape serve_bench.py writes."""
    return {
        "full": {"before": _rec(), "after": _rec()},
        "compressed": {"before": _rec(), "after": _rec()},
        "int8": {
            "full": _rec(weight_dtype="int8"),
            "compressed": _rec(weight_dtype="int8"),
            "top1_match_full": 0.97, "top1_match_compressed": 0.95,
            "tolerance": 0.85, "parity_ok": True,
            "expert_stream_gate": 3.0, "expert_stream_ok": True,
            "modeled_full_scale": {"int8_full": {
                "expert_stream_reduction_vs_bf16_half": 3.9}},
        },
        "spec": {
            "rows": {
                "k4_int8_half": _rec(draft="int8_half",
                                     acceptance_rate=0.03),
                "k4_int8_full": _rec(draft="int8_full",
                                     acceptance_rate=0.9),
            },
            "parity_greedy_bitwise": True, "parity_t07_bitwise": True,
            "acceptance_floor_self": 0.5,
            "acceptance_floor_merged": 0.0078,
            "reference_acceptance": 0.85, "gate_slots": 64,
            "speedup_gate": 1.0, "modeled_speedup_at_reference": 1.38,
            "acceptance_ok": True, "speedup_ok": True,
        },
        "paged": {
            "kv_block": 16,
            "bf16": _rec(kv_dtype="bf16", kv_layout="paged"),
            "int8": _rec(kv_dtype="int8", kv_layout="paged"),
            "parity_bf16_bitwise": True,
            "top1_match_int8_kv": 0.97, "tolerance": 0.95,
            "prefix_sharing": {"hit_rate": 1.0, "prefix_rows_shared": 160,
                               "parity_duplicates_bitwise": True},
            "modeled_full_scale_kv": {"bf16_bytes_per_token": 512,
                                      "int8_bytes_per_token": 264,
                                      "kv_stream_reduction": 1.939},
            "kv_stream_gate": 1.7, "kv_stream_ok": True, "parity_ok": True,
        },
        "ep": {
            "mesh": "data=2,model=2", "devices": 4,
            "modes": {
                "dense_block": {"parity_bitwise": True, "tokens": 120},
                "paged_block": {"parity_bitwise": True, "tokens": 120},
            },
            "parity_ok": True,
            "full_scale": {"arch": "kimi-k2-1t-a32b", "ep_degree": 16,
                           "dp_degree": 4, "n_slots": 64,
                           "expert_stream_reduction": 13.5,
                           "interconnect_bytes_per_token": 1.0e5},
            "expert_stream_gate": 12.8, "expert_stream_ok": True,
        },
        "faults": {
            "seed": 0,
            "injected": {"nan_logits": 1, "transient": 1, "exhaust": 1,
                         "transient_fails": 2},
            "observed": {"quarantined": 1, "transient_retries": 2,
                         "shed": 1},
            "statuses": {"ok": 6, "shed": 1, "failed_numeric": 1},
            "shed_reasons": {"pool_pressure": 1},
            "healthy_parity_bitwise": True,
            "quarantined_prefix_of_clean": True,
            "clean_run_counters_zero": True,
            "fault_trace_digest": "deadbeef" * 8,
            "replay_digest_equal": True,
            "replay_tokens_bitwise": True,
            "retraces": 0, "implicit_transfers": 0,
            "accounting_exact": True,
            "restore": {"dense": True, "paged": True, "spec": True},
            "ok": True,
        },
        "parity": {"fused_vs_step_bitwise": True,
                   "gather_vs_ragged_bitwise": True,
                   "batched_vs_serial_admission_bitwise": True},
    }


def test_good_summary_passes(good):
    assert check(good) == []


def test_records_enumerates_all_rows(good):
    labels = [label for label, _ in _records(good)]
    assert labels == ["full/before", "full/after", "compressed/before",
                      "compressed/after", "int8/full", "int8/compressed",
                      "spec/k4_int8_half", "spec/k4_int8_full",
                      "paged/bf16", "paged/int8"]


def test_parity_bit_false_fails(good):
    for key in good["parity"]:
        bad = copy.deepcopy(good)
        bad["parity"][key] = False
        errs = check(bad)
        assert len(errs) == 1 and key in errs[0]


def test_parity_bit_missing_fails(good):
    bad = copy.deepcopy(good)
    del bad["parity"]["gather_vs_ragged_bitwise"]
    assert any("gather_vs_ragged" in e for e in check(bad))


def test_int8_quality_gate(good):
    bad = copy.deepcopy(good)
    bad["int8"]["parity_ok"] = False
    errs = check(bad)
    assert any("below tolerance" in e for e in errs)


def test_int8_dtype_gate(good):
    bad = copy.deepcopy(good)
    bad["int8"]["compressed"]["weight_dtype"] = "bfloat16"
    errs = check(bad)
    assert any("int8.compressed.weight_dtype" in e for e in errs)


def test_int8_expert_stream_gate(good):
    bad = copy.deepcopy(good)
    bad["int8"]["expert_stream_ok"] = False
    assert any("expert-stream" in e for e in check(bad))


def test_spec_section_missing_fails(good):
    bad = copy.deepcopy(good)
    del bad["spec"]
    assert any("spec section missing" in e for e in check(bad))


def test_spec_parity_bits_gate(good):
    for key in ("parity_greedy_bitwise", "parity_t07_bitwise"):
        bad = copy.deepcopy(good)
        bad["spec"][key] = False
        errs = check(bad)
        assert len(errs) == 1 and key in errs[0]


def test_spec_acceptance_checked_against_recorded_floor(good):
    """The gate re-checks the NUMBERS, not the summary's acceptance_ok bit:
    a row below its floor fails even with acceptance_ok still True."""
    bad = copy.deepcopy(good)
    bad["spec"]["rows"]["k4_int8_full"]["acceptance_rate"] = 0.2  # < 0.5
    errs = check(bad)
    assert len(errs) == 1 and "spec/k4_int8_full" in errs[0] \
        and "floor 0.5" in errs[0]
    bad = copy.deepcopy(good)
    bad["spec"]["rows"]["k4_int8_half"]["acceptance_rate"] = 0.001
    assert any("spec/k4_int8_half" in e and "floor 0.0078" in e
               for e in check(bad))


def test_spec_speedup_checked_against_recorded_gate(good):
    bad = copy.deepcopy(good)
    bad["spec"]["modeled_speedup_at_reference"] = 0.9   # speedup_ok untouched
    errs = check(bad)
    assert len(errs) == 1 and "0.9x" in errs[0] and "below gate 1.0x" in errs[0]


def test_spec_row_counters_gated(good):
    bad = copy.deepcopy(good)
    bad["spec"]["rows"]["k4_int8_half"]["retraces"] = 3
    errs = check(bad)
    assert len(errs) == 1 and "spec/k4_int8_half" in errs[0]


def test_paged_section_missing_fails(good):
    bad = copy.deepcopy(good)
    del bad["paged"]
    assert any("paged section missing" in e for e in check(bad))


def test_paged_bf16_parity_gate(good):
    bad = copy.deepcopy(good)
    bad["paged"]["parity_bf16_bitwise"] = False
    errs = check(bad)
    assert len(errs) == 1 and "parity_bf16_bitwise" in errs[0]


def test_paged_duplicate_parity_gate(good):
    bad = copy.deepcopy(good)
    bad["paged"]["prefix_sharing"]["parity_duplicates_bitwise"] = False
    assert any("duplicate parity" in e for e in check(bad))


def test_paged_int8_kv_tolerance_checked_against_recorded_floor(good):
    """Re-checks the NUMBER, not the summary's parity_ok bit."""
    bad = copy.deepcopy(good)
    bad["paged"]["top1_match_int8_kv"] = 0.91          # parity_ok untouched
    errs = check(bad)
    assert len(errs) == 1 and "0.91" in errs[0] \
        and "tolerance 0.95" in errs[0]


def test_paged_kv_stream_checked_against_recorded_gate(good):
    bad = copy.deepcopy(good)
    bad["paged"]["modeled_full_scale_kv"]["kv_stream_reduction"] = 1.2
    errs = check(bad)                                  # kv_stream_ok untouched
    assert len(errs) == 1 and "1.2x < 1.7x" in errs[0]


def test_paged_kv_dtype_gate(good):
    bad = copy.deepcopy(good)
    bad["paged"]["int8"]["kv_dtype"] = "bf16"
    assert any("paged.int8.kv_dtype" in e for e in check(bad))


def test_paged_row_counters_gated(good):
    bad = copy.deepcopy(good)
    bad["paged"]["int8"]["retraces"] = 2
    errs = check(bad)
    assert len(errs) == 1 and "paged/int8" in errs[0]


def test_nonzero_retrace_fails_that_row_only(good):
    bad = copy.deepcopy(good)
    bad["compressed"]["after"]["retraces"] = 2
    errs = check(bad)
    assert len(errs) == 1
    assert "compressed/after" in errs[0] and "'retraces'] == 2" in errs[0]


def test_nonzero_implicit_transfer_fails(good):
    bad = copy.deepcopy(good)
    bad["int8"]["full"]["implicit_transfers"] = 1
    assert any("int8/full" in e and "implicit_transfers" in e
               for e in check(bad))


def test_missing_counters_pass(good):
    """Counters absent (older JSON) defaults to 0 — the gate is on
    regressions, not on schema presence."""
    old = copy.deepcopy(good)
    for _, rec in _records(old):
        rec.pop("retraces"), rec.pop("implicit_transfers")
    assert check(old) == []


def test_multiple_failures_all_reported(good):
    bad = copy.deepcopy(good)
    bad["parity"]["fused_vs_step_bitwise"] = False
    bad["int8"]["full"]["weight_dtype"] = "float32"
    bad["full"]["before"]["retraces"] = 1
    assert len(check(bad)) == 3


def test_main_exit_codes(good, tmp_path, capsys):
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(good))
    assert main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "parity OK" in out and "trace-guard counters OK" in out

    bad = copy.deepcopy(good)
    bad["parity"]["fused_vs_step_bitwise"] = False
    p.write_text(json.dumps(bad))
    assert main([str(p)]) == 1
    assert "check_bench FAIL" in capsys.readouterr().out


# --------------------------------------------------------------------------
# expert-parallel gates (DESIGN.md §13)
# --------------------------------------------------------------------------

def test_ep_section_missing_fails(good):
    bad = copy.deepcopy(good)
    del bad["ep"]
    assert any("ep section missing" in e for e in check(bad))


def test_ep_mode_parity_gated_per_mode(good):
    for mode in ("dense_block", "paged_block"):
        bad = copy.deepcopy(good)
        bad["ep"]["modes"][mode]["parity_bitwise"] = False
        errs = check(bad)
        assert len(errs) == 1 and f"ep/{mode}" in errs[0] \
            and "token-for-token" in errs[0]


def test_ep_expert_stream_checked_against_recorded_gate(good):
    """Re-checks the NUMBER against the recorded gate, not the summary's
    expert_stream_ok bit."""
    bad = copy.deepcopy(good)
    bad["ep"]["full_scale"]["expert_stream_reduction"] = 2.0  # ok untouched
    errs = check(bad)
    assert len(errs) == 1 and "2.0x < 12.8x" in errs[0] and "EP=16" in errs[0]


# --------------------------------------------------------------------------
# resilience gates (DESIGN.md §12)
# --------------------------------------------------------------------------

def test_happy_row_nonzero_shed_fails_that_row_only(good):
    """A happy-path row shedding work (or quarantining, or retrying) is a
    regression even though the degraded-mode row records the same counters
    nonzero by design."""
    for c in ("shed", "quarantined", "transient_retries"):
        bad = copy.deepcopy(good)
        bad["full"]["after"][c] = 1
        errs = check(bad)
        assert len(errs) == 1 and "full/after" in errs[0] and c in errs[0]


def test_missing_resilience_counters_pass(good):
    """Older JSON without the §12 counters still passes — the gate is on
    regressions, not schema presence (same stance as the guard counters)."""
    old = copy.deepcopy(good)
    for _, rec in _records(old):
        for c in ("shed", "quarantined", "transient_retries"):
            rec.pop(c)
    assert check(old) == []


def test_faults_section_missing_fails(good):
    bad = copy.deepcopy(good)
    del bad["faults"]
    assert any("faults section missing" in e for e in check(bad))


def test_faults_observed_must_equal_injected(good):
    """The degraded row must account for injected faults EXACTLY — an
    over-count (spurious quarantine) and an under-count (swallowed fault)
    both fail, even with accounting_exact left True."""
    for got, want in (("quarantined", "nan_logits"), ("shed", "exhaust"),
                      ("transient_retries", "transient_fails")):
        for delta in (-1, 1):
            bad = copy.deepcopy(good)
            bad["faults"]["observed"][got] += delta
            errs = check(bad)
            assert any(got in e and want in e and "EXACTLY" in e
                       for e in errs), (got, delta, errs)


def test_faults_accounting_exact_bit_gated(good):
    bad = copy.deepcopy(good)
    bad["faults"]["accounting_exact"] = False
    assert any("accounting_exact" in e for e in check(bad))


def test_faults_parity_and_replay_bits_gated(good):
    for key in ("healthy_parity_bitwise", "quarantined_prefix_of_clean",
                "clean_run_counters_zero", "replay_digest_equal",
                "replay_tokens_bitwise"):
        bad = copy.deepcopy(good)
        bad["faults"][key] = False
        errs = check(bad)
        assert len(errs) == 1 and key in errs[0]


def test_faults_degraded_row_guard_counters_gated(good):
    """Injected faults must not smuggle retraces/implicit transfers into
    the hot loop — the degraded row keeps the §9 purity contract."""
    bad = copy.deepcopy(good)
    bad["faults"]["retraces"] = 4
    assert any("under" in e and "injected faults" in e for e in check(bad))


def test_faults_restore_flags_gated_per_mode(good):
    for mode in ("dense", "paged", "spec"):
        bad = copy.deepcopy(good)
        bad["faults"]["restore"][mode] = False
        errs = check(bad)
        assert len(errs) == 1 and f"restore[{mode!r}]" in errs[0] \
            and "uninterrupted" in errs[0]
