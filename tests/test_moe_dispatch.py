"""MoE dispatch invariants (property tests) + multi-device collective
compression (subprocess with 8 simulated devices)."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models import moe as MoE
from repro.models import model as MD


def _cfg(E=8, k=2, cf=2.0, G=64):
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, n_experts=E, top_k=k, capacity_factor=cf, group_size=G))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]))
def test_topk_iterative_matches_lax(seed, E, k):
    probs = jax.random.uniform(jax.random.PRNGKey(seed), (6, 7, E))
    w_ref, i_ref = jax.lax.top_k(probs, k)
    w, i = MoE._topk_iterative(probs, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_dispatch_combine_weights_sum_to_topk_weights():
    """Every undropped token's combine weights equal its top-k routing
    weights; dropped tokens contribute zero (never NaN)."""
    cfg = _cfg(cf=8.0)   # big capacity: nothing dropped
    m = cfg.moe
    G, E = 32, m.n_experts
    key = jax.random.PRNGKey(0)
    w = jax.nn.softmax(jax.random.normal(key, (G, m.top_k)), axis=-1)
    idx = jax.random.randint(key, (G, m.top_k), 0, E)
    C = MoE._capacity(m, G, E)
    combine, dispatch = MoE._dispatch_tensors(cfg, w, idx, E, C)
    per_token = np.asarray(jnp.sum(combine, axis=(1, 2)))
    np.testing.assert_allclose(per_token, np.asarray(jnp.sum(w, -1)),
                               atol=1e-5)
    assert bool(jnp.all(jnp.sum(dispatch, axis=(1, 2)) <= m.top_k))


def test_capacity_drops_are_deterministic_prefix():
    """With capacity 4, only the first 4 tokens routed to an expert keep
    their slots (GShard prefix semantics)."""
    cfg = _cfg(E=2, k=1, cf=0.25, G=32)   # tiny capacity
    m = cfg.moe
    G = 32
    w = jnp.ones((G, 1))
    idx = jnp.zeros((G, 1), jnp.int32)    # everyone wants expert 0
    C = MoE._capacity(m, G, 2)
    combine, _ = MoE._dispatch_tensors(cfg, w, idx, 2, C)
    kept = np.asarray(jnp.sum(combine, axis=(1, 2)))
    assert kept[:C].sum() == C and kept[C:].sum() == 0


def test_remap_duplicates_sum_weights():
    """After compression, two selected originals mapping to the same merged
    expert contribute additively (matrix A acting on routing weights)."""
    cfg = _cfg(E=4, k=2, cf=8.0)
    params = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.bfloat16)
    # all originals -> one real expert
    p1 = dict(params, remap=jnp.zeros(4, jnp.int32))
    y1 = MoE.moe_apply(cfg, p1, x).y
    # reference: that expert applied with weight 1 (softmax weights sum to 1)
    from repro.kernels import ref
    xe = x.reshape(-1, cfg.d_model)
    e0 = ref.swiglu_mlp(xe, p1["wg"][0], p1["wu"][0], p1["wd"][0])
    np.testing.assert_allclose(
        np.asarray(y1.reshape(-1, cfg.d_model), np.float32),
        np.asarray(e0, np.float32), atol=2.0, rtol=0.02)  # bf16 precision


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), bad=st.integers(0, 7))
def test_route_fail_closed_drops_corrupted_remap_targets(seed, bad):
    """route() masking must FAIL CLOSED: an original expert whose remap
    lands at or beyond ``live`` (possible only through corruption — valid
    remaps stay below live by construction) can never win top-k, so tokens
    are never dispatched into zero-filled pad rows. The no-op direction
    (valid remap => mask changes nothing) is covered by
    test_plan.py::test_router_logit_mask_is_noop_for_valid_remap; this is
    the DROP direction."""
    cfg = _cfg(E=8, k=2)
    key = jax.random.PRNGKey(seed)
    p = MoE.moe_init(cfg, key, n_real=4)            # M=4 physical rows
    live = 3
    remap = np.array(jax.random.randint(key, (8,), 0, live), np.int32)
    remap[bad] = live                               # corrupted: pad row
    p = dict(p, remap=jnp.asarray(remap),
             live=jnp.asarray(live, jnp.int32))
    # router biased hard toward the corrupted expert so unmasked routing
    # WOULD pick it for every token — the mask must divert all of them
    router = np.zeros((cfg.d_model, 8), np.float32)
    router[:, bad] = 10.0
    p["router"] = jnp.asarray(router)
    x = jax.random.normal(key, (2, 9, cfg.d_model), jnp.float32)

    w, idx, probs = MoE.route(cfg, p, x)
    chosen_remap = np.asarray(jnp.take(p["remap"], idx))
    assert (chosen_remap < live).all(), \
        "masked routing dispatched a token to a pad row"
    assert not np.isin(np.asarray(idx), bad).any()
    assert np.asarray(probs)[..., bad].max() == 0.0   # -inf before softmax
    # weights stay a valid renormalized top-k distribution
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)

    # non-vacuous: without the live mask the corrupted expert DOES win top-k
    # for the tokens whose projection onto its router column is positive
    stripped = {k_: v for k_, v in p.items() if k_ != "live"}
    _, idx_unmasked, _ = MoE.route(cfg, stripped, x)
    assert np.isin(np.asarray(idx_unmasked), bad).any(), \
        "test setup failed to make the corrupted expert attractive"

    # and the full forward stays finite with the corrupted remap in place
    params = MD.init(cfg.compressed(4, 1), jax.random.PRNGKey(0))
    moe_c = dict(params["stack_c"]["moe"])
    lr = np.array(moe_c["remap"])
    lr[:, bad] = 4                                  # >= live on every layer
    moe_c["remap"] = jnp.asarray(lr)
    params["stack_c"] = dict(params["stack_c"], moe=moe_c)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    logits, _, _ = MD.forward(cfg.compressed(4, 1), params, batch)
    assert np.isfinite(np.asarray(logits)).all()


def _apply_with(cfg, p, x, dispatch, **kw):
    c = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=dispatch))
    return MoE.moe_apply(c, p, x, **kw).y


def test_gather_ragged_dense_parity_uniform():
    """Decode-sized token counts: gather == ragged bitwise (identical
    per-row arithmetic + fp32 combine), both == dense within bf16 tolerance
    (dense combines through the capacity einsum)."""
    cfg = _cfg(E=8, k=2, cf=8.0)     # capacity headroom: dense drops nothing
    p = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    # decode shape: [n_slots, 1, d] — one token per slot sequence
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model),
                          jnp.bfloat16)
    assert 4 <= cfg.moe.gather_max_tokens
    y_g = _apply_with(cfg, p, x, "gather", need_aux=False)
    y_r = _apply_with(cfg, p, x, "ragged", need_aux=False)
    y_d = _apply_with(cfg, p, x, "dense", need_aux=False)
    np.testing.assert_array_equal(np.asarray(y_g, np.float32),
                                  np.asarray(y_r, np.float32))
    # dense combines through bf16 dispatch/combine einsums -> looser tol
    np.testing.assert_allclose(np.asarray(y_g, np.float32),
                               np.asarray(y_d, np.float32),
                               atol=0.1, rtol=0.05)


def test_gather_ragged_dense_parity_hetero_live_masked():
    """Heterogeneous compressed layer: padded tables (live < n_real), valid
    remap onto the live rows — all three dispatches agree and never touch
    the zero pad rows."""
    cfg = _cfg(E=8, k=2, cf=8.0)
    key = jax.random.PRNGKey(3)
    p = MoE.moe_init(cfg, key, n_real=4)
    live = 3
    p = dict(p,
             remap=jax.random.randint(key, (8,), 0, live).astype(jnp.int32),
             live=jnp.asarray(live, jnp.int32))
    # poison the pad row: if any dispatch touched it, outputs would diverge
    p["wd"] = p["wd"].at[live:].set(1e4)
    x = jax.random.normal(key, (6, 1, cfg.d_model), jnp.bfloat16)
    y_g = _apply_with(cfg, p, x, "gather", need_aux=False)
    y_r = _apply_with(cfg, p, x, "ragged", need_aux=False)
    y_d = _apply_with(cfg, p, x, "dense", need_aux=False)
    assert np.isfinite(np.asarray(y_g, np.float32)).all()
    assert np.abs(np.asarray(y_g, np.float32)).max() < 1e3
    np.testing.assert_array_equal(np.asarray(y_g, np.float32),
                                  np.asarray(y_r, np.float32))
    # dense combines through bf16 dispatch/combine einsums -> looser tol
    np.testing.assert_allclose(np.asarray(y_g, np.float32),
                               np.asarray(y_d, np.float32),
                               atol=0.1, rtol=0.05)


def test_gather_fail_closed_corrupted_remap():
    """The corrupted-remap fail-closed contract (DESIGN.md §5) through the
    gather path: a remap entry pointing at a pad row is masked in routing,
    so the gather kernel never loads that row and the output stays finite
    even with the router biased hard toward the corrupted expert."""
    cfg = _cfg(E=8, k=2)
    key = jax.random.PRNGKey(5)
    p = MoE.moe_init(cfg, key, n_real=4)
    live, bad = 3, 6
    remap = np.array(jax.random.randint(key, (8,), 0, live), np.int32)
    remap[bad] = live                               # corrupted: pad row
    router = np.zeros((cfg.d_model, 8), np.float32)
    router[:, bad] = 10.0
    p = dict(p, remap=jnp.asarray(remap),
             live=jnp.asarray(live, jnp.int32), router=jnp.asarray(router))
    p["wd"] = p["wd"].at[live:].set(1e4)            # poisoned pad row
    x = jax.random.normal(key, (6, 1, cfg.d_model), jnp.bfloat16)
    for need_aux in (False, True):
        y = _apply_with(cfg, p, x, "gather", need_aux=need_aux)
        assert np.isfinite(np.asarray(y, np.float32)).all()
        assert np.abs(np.asarray(y, np.float32)).max() < 1e3


def test_gather_falls_back_to_ragged_outside_decode_shape(monkeypatch):
    """dispatch='gather' is a trace-time switch on static shapes: only
    decode-shaped calls (S == 1, T <= gather_max_tokens) take the gather
    kernel; prefill-shaped calls (S > 1) and over-ceiling decode batches
    run the sort-based grouped path and never invoke it."""
    import repro.kernels.ops as kops
    calls = []
    real = kops.gather_swiglu
    monkeypatch.setattr(kops, "gather_swiglu",
                        lambda *a, **k: (calls.append(a[0].shape), real(*a, **k))[1])
    cfg = _cfg(E=8, k=2)
    p = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    decode = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model),
                               jnp.bfloat16)
    prefill = jax.random.normal(jax.random.PRNGKey(1),
                                (1, cfg.moe.gather_max_tokens, cfg.d_model),
                                jnp.bfloat16)
    wide = jax.random.normal(jax.random.PRNGKey(1),
                             (cfg.moe.gather_max_tokens + 1, 1, cfg.d_model),
                             jnp.bfloat16)
    _apply_with(cfg, p, decode, "gather", need_aux=False)
    assert calls == [(4, cfg.d_model)]
    for x in (prefill, wide):                       # gather never re-invoked
        y = _apply_with(cfg, p, x, "gather", need_aux=False)
        assert calls == [(4, cfg.d_model)]
        np.testing.assert_array_equal(
            np.asarray(y, np.float32),
            np.asarray(_apply_with(cfg, p, x, "ragged", need_aux=False),
                       np.float32))


def test_need_aux_false_matches_training_routing():
    """route_infer (top-k on logits + subset softmax) must reproduce
    route()'s renormalized weights and selection; aux comes back as a
    constant zero."""
    cfg = _cfg(E=8, k=2)
    p = MoE.moe_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, cfg.d_model),
                          jnp.bfloat16)
    w_t, i_t, probs = MoE.route(cfg, p, x)
    w_i, i_i = MoE.route_infer(cfg, p, x)
    np.testing.assert_array_equal(np.asarray(i_t), np.asarray(i_i))
    np.testing.assert_allclose(np.asarray(w_t), np.asarray(w_i),
                               atol=1e-6, rtol=1e-6)
    out_t = MoE.moe_apply(cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch="ragged")), p, x)
    out_i = MoE.moe_apply(cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch="ragged")), p, x, need_aux=False)
    assert float(out_t.aux_loss) > 0.0
    assert float(out_i.aux_loss) == 0.0
    np.testing.assert_allclose(np.asarray(out_t.y, np.float32),
                               np.asarray(out_i.y, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_compressed_psum_multidevice():
    """int8-over-the-wire psum inside shard_map on 8 simulated devices."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16) / 7.0

        def body(xs):
            return compressed_psum(xs[0], "data", jax.random.PRNGKey(0))[None]

        f = shard_map(body, mesh=mesh, in_specs=P("data", None),
                      out_specs=P("data", None))
        out = f(x)
        exact = jnp.sum(x, axis=0)
        err = float(jnp.max(jnp.abs(out[0] - exact)) / jnp.max(jnp.abs(exact)))
        assert err < 0.05, err
        print("OK", err)
    """)
    # JAX_PLATFORMS=cpu: without it, a container with libtpu installed spends
    # ~8 min retrying GCP metadata probes before falling back to CPU.
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                       "HOME": "/root",
                                       "JAX_PLATFORMS": "cpu"}, cwd="/root/repo",
                       timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr
