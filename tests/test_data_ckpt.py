"""Data pipeline + checkpointing: determinism, resume, elasticity, GC."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import checkpoint as CKPT
from repro.data.pipeline import (SyntheticLM, TokenFileDataset, DataState,
                                 write_token_file, make_pipeline)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_resumable():
    a = SyntheticLM(100, 16, 4, seed=7)
    b1 = [next(a)["tokens"] for _ in range(3)]
    st = a.state()
    b2 = next(a)["tokens"]
    a.restore(st)
    np.testing.assert_array_equal(next(a)["tokens"], b2)
    fresh = SyntheticLM(100, 16, 4, seed=7)
    np.testing.assert_array_equal(next(fresh)["tokens"], b1[0])


def test_synthetic_host_sharding_differs():
    h0 = SyntheticLM(100, 16, 8, seed=1, host_id=0, num_hosts=2)
    h1 = SyntheticLM(100, 16, 8, seed=1, host_id=1, num_hosts=2)
    assert next(h0)["tokens"].shape == (4, 16)
    assert not np.array_equal(next(h0)["tokens"], next(h1)["tokens"])


def test_file_dataset_round_robin(tmp_path):
    toks = np.arange(16 * 10, dtype=np.int32)
    write_token_file(tmp_path / "part0.bin", toks)
    ds = TokenFileDataset([tmp_path / "part0.bin"], seq_len=16, global_batch=2)
    b = next(ds)["tokens"]
    np.testing.assert_array_equal(b[0], toks[:16])
    np.testing.assert_array_equal(b[1], toks[16:32])
    st = ds.state()
    b2 = next(ds)["tokens"]
    ds.restore(st)
    np.testing.assert_array_equal(next(ds)["tokens"], b2)


def test_file_dataset_hosts_partition_corpus(tmp_path):
    toks = np.arange(16 * 8, dtype=np.int32)
    write_token_file(tmp_path / "p.bin", toks)
    h0 = TokenFileDataset([tmp_path / "p.bin"], 16, 4, host_id=0, num_hosts=2)
    h1 = TokenFileDataset([tmp_path / "p.bin"], 16, 4, host_id=1, num_hosts=2)
    rows = np.concatenate([next(h0)["tokens"], next(h1)["tokens"]])
    starts = sorted(r[0] for r in rows)
    assert starts == [0, 16, 32, 48]      # union covers corpus, no overlap


def test_make_pipeline_fallback():
    cfg = configs.get("granite-8b").reduced()
    p = make_pipeline(cfg, 16, 2)
    assert next(p)["tokens"].shape == (2, 16)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip_with_bf16(tmp_path):
    t = _tree()
    CKPT.save(tmp_path, 5, t, extras={"note": "hi"})
    t2, extras = CKPT.load(tmp_path)
    assert extras["note"] == "hi"
    assert t2["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(t2["a"]), np.asarray(t["a"]))


def test_keep_n_gc(tmp_path):
    for s in range(6):
        CKPT.save(tmp_path, s, _tree(), keep=2)
    assert CKPT.latest_step(tmp_path) == 5
    steps = sorted(d.name for d in tmp_path.glob("step_????????"))
    assert len(steps) == 2


def test_partial_write_ignored(tmp_path):
    CKPT.save(tmp_path, 1, _tree())
    # simulate a crash mid-write: tmp dir without COMMIT
    bad = tmp_path / "step_00000002.tmp"
    bad.mkdir()
    (bad / "meta.json").write_text("{}")
    assert CKPT.latest_step(tmp_path) == 1
    t, _ = CKPT.load(tmp_path)      # loads step 1, not the corpse
    assert "a" in t


def test_async_manager(tmp_path):
    mgr = CKPT.CheckpointManager(tmp_path, keep=2, async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.latest_step() == 1
    t, _ = mgr.restore()
    assert t["b"]["d"] == 3


def test_elastic_restore_new_sharding(tmp_path):
    """Save unsharded, restore with an explicit NamedSharding (the 1-device
    degenerate case of remeshing; the same API reshards on real fleets)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(8, dtype=jnp.float32)}
    CKPT.save(tmp_path, 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    t2, _ = CKPT.load(tmp_path, shardings=sh)
    assert t2["w"].sharding.spec == P("data")
    np.testing.assert_allclose(np.asarray(t2["w"]), np.asarray(t["w"]))
