from repro.core.errors import TechniqueInapplicable, CalibrationError  # noqa: F401
from repro.core.compress import (  # noqa: F401
    compress_model, compress_with_plan, MIN_SAMPLE_WARN)
from repro.core.calibration import CalibrationStream, collect  # noqa: F401
from repro.core.merge import merge_layer, MergeResult, METHODS  # noqa: F401
from repro.core.plan import (  # noqa: F401
    CompressionPlan, LayerSpec, MergeStrategy, register_method,
    get_strategy, available_methods, uniform, suffix, for_target_ratio)
from repro.core.clustering import (  # noqa: F401
    cluster_experts, merge_weights, summation_matrix, mixing_matrix)
