"""Request-level serving: continuous batching over the slotted KV cache,
plus self-speculative decoding (draft = MergeMoE-compressed, verify = full;
DESIGN.md §10) and deterministic fault injection (DESIGN.md §12)."""
from repro.serving.engine import (  # noqa: F401
    Engine,
    EngineConfig,
    Request,
    poisson_trace,
)
from repro.serving.faults import FaultPlan, FaultSpec  # noqa: F401
from repro.serving.spec import accept_drafts  # noqa: F401
