"""Sharding rules: divisibility handling, path matching, cache/batch specs.
Uses AbstractMesh — no devices needed for spec derivation."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH
from repro.launch.mesh import make_abstract_mesh

SDS = jax.ShapeDtypeStruct
MESH = make_abstract_mesh((16, 16), ("data", "model"))
MESH3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_param_rules_basic():
    shapes = {"stack": {
        "attn": {"wq": SDS((36, 4096, 4096), jnp.bfloat16),
                 "wk": SDS((36, 4096, 1024), jnp.bfloat16),
                 "wo": SDS((36, 4096, 4096), jnp.bfloat16)},
        "mlp": {"wg": SDS((36, 4096, 14336), jnp.bfloat16),
                "wd": SDS((36, 14336, 4096), jnp.bfloat16)},
        "ln1": {"scale": SDS((4096,), jnp.bfloat16)},
    }}
    specs = SH.params_pspecs(shapes, MESH)
    st = specs["stack"]
    assert st["attn"]["wq"] == P(None, "data", "model")
    assert st["attn"]["wk"] == P(None, "data")       # KV replicated on model
    assert st["attn"]["wo"] == P(None, "model", "data")
    assert st["mlp"]["wg"] == P(None, "data", "model")
    assert st["ln1"]["scale"] == P()


def test_moe_expert_parallel_rules():
    shapes = {"stack": {"moe": {
        "wg": SDS((61, 384, 7168, 2048), jnp.bfloat16),
        "wd": SDS((61, 384, 2048, 7168), jnp.bfloat16),
        "router": SDS((61, 7168, 384), jnp.float32),
        "remap": SDS((61, 384), jnp.int32)}}}
    specs = SH.params_pspecs(shapes, MESH)["stack"]["moe"]
    assert specs["wg"] == P(None, "model", "data")
    assert specs["wd"] == P(None, "model", None, "data")
    assert specs["router"] == P()
    assert specs["remap"] == P()


def test_non_divisible_axis_dropped():
    # vocab 50280 is not divisible by 16 -> "model" entry must be dropped
    shapes = {"embed": {"tok": SDS((50280, 1024), jnp.bfloat16)}}
    spec = SH.params_pspecs(shapes, MESH)["embed"]["tok"]
    assert spec == P(None, "data")


def test_opt_state_inherits_param_rules():
    shapes = {"stack": {"mlp": {"wg": {
        "m": SDS((36, 4096, 14336), jnp.float32),
        "v": SDS((36, 4096, 14336), jnp.float32)}}}}
    specs = SH.opt_pspecs(shapes, MESH)
    assert specs["stack"]["mlp"]["wg"]["m"] == P(None, "data", "model")


def test_adafactor_factored_state_truncates():
    shapes = {"stack": {"mlp": {"wg": {
        "vr": SDS((36, 4096), jnp.float32),          # param minus last dim
        "vc": SDS((36, 14336), jnp.float32)}}}}
    specs = SH.opt_pspecs(shapes, MESH)
    # template right-aligned: [36, 4096] -> ("data","model"); 36 is not
    # divisible by 16 so the "data" entry is dropped, "model" kept on 4096
    assert specs["stack"]["mlp"]["wg"]["vr"] == P(None, "model")
    assert specs["stack"]["mlp"]["wg"]["vc"] == P(None, "model")


def test_batch_specs_pod_axis():
    b = {"tokens": SDS((256, 4096), jnp.int32)}
    spec = SH.batch_pspecs(b, MESH3)["tokens"]
    assert spec == P(("pod", "data"))
    one = {"tokens": SDS((1, 4096), jnp.int32)}      # long_500k: B=1
    assert SH.batch_pspecs(one, MESH)["tokens"] == P()


def test_cache_specs_sequence_sharded():
    cache = {"k": SDS((36, 128, 32768, 8, 128), jnp.bfloat16),
             "v": SDS((36, 128, 32768, 8, 128), jnp.bfloat16),
             "pos": SDS((), jnp.int32)}
    specs = SH.cache_pspecs(cache, MESH)
    assert specs["k"] == P(None, "data", "model")
    assert specs["pos"] == P()
    # B=1: batch unshardable -> sequence takes BOTH axes
    cache1 = {"k": SDS((9, 1, 524288, 32, 80), jnp.bfloat16)}
    assert SH.cache_pspecs(cache1, MESH)["k"] == P(None, None,
                                                   ("data", "model"))


def test_logits_pspec_shape_aware():
    assert SH.logits_pspec(MESH, (256, 64000)) == P("data", "model")
    assert SH.logits_pspec(MESH, (1, 50280)) == P()


def test_constrain_noop_without_mesh():
    from repro.models.numerics import constrain, set_activation_mesh
    set_activation_mesh(None)
    x = jnp.ones((4, 4))
    assert constrain(x, "DP", "M") is x
