"""Int8 expert-weight quantization (repro.core.quant, DESIGN.md §8):
property tests for the per-channel error bound, zero-channel exactness,
determinism, tree surgery, and the plan/compress integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.core import quant as Q
from repro.models import model as MD
from repro.models import moe as MoE

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# quantize/dequantize properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(E=st.sampled_from([1, 3, 8]), rows=st.sampled_from([4, 16, 33]),
       cols=st.sampled_from([1, 8, 24]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       scale_pow=st.integers(-6, 6), seed=st.integers(0, 1000))
def test_quant_dequant_error_bounded_by_half_scale(E, rows, cols, dtype,
                                                   scale_pow, seed):
    """|w - dequant(quant(w))| <= scale/2 per (expert, output channel) —
    the round-to-nearest symmetric-quantization bound, at any shape, input
    dtype, and magnitude (scales are per-channel, so wildly different
    channel norms must not leak error across channels)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((E, rows, cols)) * (2.0 ** scale_pow)
    # heterogeneous channel norms: scale each output channel independently
    w = w * (2.0 ** rng.integers(-3, 4, size=(1, 1, cols)))
    w = jnp.asarray(w, jnp.dtype(dtype))
    q, s = Q.quantize_channelwise(w)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == w.shape and s.shape == (E, 1, cols)
    deq = np.asarray(Q.dequantize(q, s, jnp.float32))
    w32 = np.asarray(w, np.float32)
    bound = np.asarray(s) / 2.0
    # tiny epsilon absorbs the fp32 rounding of the q*scale product itself
    assert (np.abs(w32 - deq) <= bound + 1e-6 * np.abs(w32) + 1e-30).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_quant_deterministic_and_symmetric(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((2, 8, 4)), jnp.float32)
    q1, s1 = Q.quantize_channelwise(w)
    q2, s2 = Q.quantize_channelwise(w)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # symmetric range: the channel max hits +-127 exactly, never saturates
    assert int(np.abs(np.asarray(q1)).max()) == 127
    qn, sn = Q.quantize_channelwise(-w)
    np.testing.assert_array_equal(np.asarray(qn), -np.asarray(q1))
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(s1))


def test_quant_zero_channels_exact():
    """All-zero channels (hetero pad rows, DESIGN.md §5) must quantize to
    q == 0 with scale == 0 and dequantize back to exact zeros — no NaNs
    from the 0/0 scale."""
    w = jnp.zeros((3, 4, 5), jnp.bfloat16).at[0, :, 2].set(1.5)
    q, s = Q.quantize_channelwise(w)
    assert np.isfinite(np.asarray(s)).all()
    zero = np.ones((3, 1, 5), bool)
    zero[0, 0, 2] = False
    assert (np.asarray(s)[zero] == 0).all()
    deq = np.asarray(Q.dequantize(q, s, jnp.bfloat16), np.float32)
    ref = np.asarray(w, np.float32)
    np.testing.assert_array_equal(deq, ref)   # 1.5 is int8-representable


def test_exactly_representable_values_roundtrip():
    """Values on the quantization grid come back bitwise."""
    s = 0.25
    grid = jnp.asarray(np.arange(-127, 128, dtype=np.float32) * s)
    w = jnp.tile(grid[None, :, None], (2, 1, 3))
    q, scale = Q.quantize_channelwise(w)
    np.testing.assert_allclose(np.asarray(scale), s, rtol=1e-6)
    deq = np.asarray(Q.dequantize(q, scale, jnp.float32))
    np.testing.assert_allclose(deq, np.asarray(w), rtol=1e-6, atol=1e-7)


def test_scale_axes_match_output_channels():
    """wg/wu reduce over d (scales span f); wd reduces over f (scales span
    d) — the per-OUTPUT-channel convention the kernels' BlockSpecs encode."""
    E, d, f = 2, 6, 10
    wg = jnp.asarray(RNG.standard_normal((E, d, f)), jnp.float32)
    wd = jnp.asarray(RNG.standard_normal((E, f, d)), jnp.float32)
    qt = Q.quantize_expert_tables(wg, wg, wd)
    assert qt.wg_scale.shape == (E, 1, f)
    assert qt.wd_scale.shape == (E, 1, d)
    assert qt.n_experts == E


# ---------------------------------------------------------------------------
# tree surgery
# ---------------------------------------------------------------------------

def _moe_params():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    return cfg, MoE.moe_init(cfg, jax.random.PRNGKey(0))


def test_quantize_moe_tree_roundtrip():
    cfg, p = _moe_params()
    pq = Q.quantize_moe_tree(p)
    assert Q.is_quantized(pq) and not Q.is_quantized(p)
    assert sorted(pq["qexp"].keys()) == sorted(Q.QEXP_KEYS)
    for k in ("router", "remap", "live"):
        assert pq[k] is p[k]
    assert "wg" not in pq
    # view <-> tree
    qt = Q.QuantizedExpertTables.from_tree(pq["qexp"])
    assert qt.to_tree().keys() == pq["qexp"].keys()
    # dequantize_moe_tree restores table leaves within the quant bound
    back = Q.dequantize_moe_tree(pq, cfg.param_dtype)
    assert "qexp" not in back and back["wg"].dtype == cfg.param_dtype
    err = np.abs(np.asarray(back["wg"], np.float32)
                 - np.asarray(p["wg"], np.float32))
    assert err.max() <= np.asarray(pq["qexp"]["wg_scale"]).max()
    # idempotent
    assert Q.quantize_moe_tree(pq)["qexp"] is not None


def test_quantize_model_experts_covers_both_stacks():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (2, 32),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=1, batches=calib)
    q = Q.quantize_model_experts(nparams)
    assert Q.is_quantized(q["stack"]["moe"])
    assert Q.is_quantized(q["stack_c"]["moe"])
    # non-moe leaves untouched
    assert q["embed"] is nparams["embed"]


# ---------------------------------------------------------------------------
# plan + compress integration
# ---------------------------------------------------------------------------

def test_plan_weight_dtype_roundtrip_and_validation():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    plan = PLAN.uniform(cfg, merged_experts=4, split=0, weight_dtype="int8")
    again = PLAN.CompressionPlan.from_json(plan.to_json())
    assert again == plan and again.weight_dtype == "int8"
    # back-compat: pre-int8 plan files have no weight_dtype -> bf16
    d = plan.to_json_dict()
    del d["weight_dtype"]
    assert PLAN.CompressionPlan.from_json_dict(d).weight_dtype == "bf16"
    # mesh annotation preserves the dtype
    assert plan.with_mesh({"data": 2}).weight_dtype == "int8"
    with pytest.raises(ValueError, match="weight_dtype"):
        PLAN.CompressionPlan(plan.specs,
                             weight_dtype="fp4").validate(cfg)


def test_compress_with_plan_int8_quantizes_suffix():
    """weight_dtype='int8' replaces the suffix tables with a qexp subtree;
    the merge itself is identical to the bf16 plan (solves are
    deterministic), so dequantized tables sit within one scale step of the
    bf16 ones and the byte accounting reflects the int8 storage."""
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    specs = tuple(PLAN.LayerSpec(l, "mergemoe", 4 - l)
                  for l in range(cfg.n_layers))       # hetero M: 4, 3
    p8 = PLAN.CompressionPlan(specs, weight_dtype="int8").validate(cfg)
    pbf = PLAN.CompressionPlan(specs, weight_dtype="bf16").validate(cfg)
    c8, q8, i8 = CMP.compress_with_plan(cfg, params, p8, batches=calib,
                                        calib_policy="head")
    cb, qb, ib = CMP.compress_with_plan(cfg, params, pbf, batches=calib,
                                        calib_policy="head")
    assert c8 == cb                                    # same config view
    moe8 = q8["stack_c"]["moe"]
    assert Q.is_quantized(moe8) and "wg" not in moe8
    assert moe8["qexp"]["wg"].dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(moe8["remap"]),
                                  np.asarray(qb["stack_c"]["moe"]["remap"]))
    # int8 storage compresses strictly further at identical merge
    assert i8["weight_dtype"] == "int8" and ib["weight_dtype"] == "bf16"
    assert i8["bytes_compressed"] < ib["bytes_compressed"]
    assert i8["compression_ratio"] > ib["compression_ratio"]
    # dequantized tables within the per-channel bound of the bf16 merge
    deq = np.asarray(Q.dequantize(moe8["qexp"]["wg"],
                                  moe8["qexp"]["wg_scale"], jnp.float32))
    ref = np.asarray(qb["stack_c"]["moe"]["wg"], np.float32)
    bound = np.asarray(moe8["qexp"]["wg_scale"]) / 2 + 5e-3 * np.abs(ref)
    assert (np.abs(deq - ref) <= bound + 1e-6).all()
    # pad rows (hetero layer 1 has M=3 of max 4) quantize to exact zeros
    assert (np.asarray(moe8["qexp"]["wg"])[1, 3:] == 0).all()
    assert (np.asarray(moe8["qexp"]["wg_scale"])[1, 3:] == 0).all()


def test_expert_bytes_int8_accounting():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    d, f = cfg.d_model, cfg.moe.d_ff_expert
    assert PLAN.expert_bytes(cfg) == 3 * d * f * 2
    assert PLAN.expert_bytes(cfg, "int8") == 3 * d * f + 4 * (2 * f + d)
    assert PLAN.expert_bytes(cfg, "int8") < PLAN.expert_bytes(cfg)


# ---------------------------------------------------------------------------
# dispatch-path parity on quantized params
# ---------------------------------------------------------------------------

def test_int8_gather_matches_int8_ragged_at_moe_level():
    """The int8 gather and ragged paths consume the same dequantized values
    through the same fp32 combine — bitwise-identical MoE outputs at decode
    shape (the §7 dispatch-parity contract, carried over to §8)."""
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    p = Q.quantize_moe_tree(MoE.moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model),
                          cfg.param_dtype)
    out = {}
    for disp in ("gather", "ragged"):
        c = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=disp))
        out[disp] = np.asarray(MoE.moe_apply(c, p, x, need_aux=False).y,
                               np.float32)
    np.testing.assert_array_equal(out["gather"], out["ragged"])


def test_int8_dense_path_runs_and_tracks_ragged():
    """Dense (capacity) dispatch accepts the qexp leaf too — train/dry-run
    paths keep working on quantized artifacts. Dense is GShard-lossy, so
    the contract is allclose-on-kept-tokens at generous capacity, not
    bitwise."""
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = Q.quantize_moe_tree(MoE.moe_init(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model),
                          cfg.param_dtype)
    cd = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="dense"))
    cr = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    yd = np.asarray(MoE.moe_apply(cd, p, x).y, np.float32)
    yr = np.asarray(MoE.moe_apply(cr, p, x).y, np.float32)
    assert np.isfinite(yd).all()
    # bf16 intermediates differ between the einsum and kernel-oracle paths
    # even UNQUANTIZED (~0.1 abs on O(20) outputs); the tolerance covers
    # that baseline, not quantization error
    np.testing.assert_allclose(yd, yr, atol=0.3, rtol=0.05)
