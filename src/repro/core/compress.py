"""End-to-end MergeMoE compression pipeline, driven by a CompressionPlan.

``compress_with_plan(cfg, params, plan, batches=...)``:
  1. stream calibration batches through the ORIGINAL model
     (:class:`repro.core.calibration.CalibrationStream` — bounded host
     memory, running counts),
  2. execute the plan layer by layer: each :class:`LayerSpec` picks a
     registered merge strategy and a per-layer budget M_ℓ (the paper's
     back-to-front traversal is equivalent under pure-functional capture —
     DESIGN.md §3),
  3. return (compressed_cfg, compressed_params, report) with the suffix
     stack's expert tables replaced by the merged experts (padded to the
     plan's max M for scan homogeneity — DESIGN.md §5) + the [N]->[M] remap
     (matrix A) and the per-layer live-expert counts.

``compress_model(cfg, params, method=..., merged_experts=..., split=...)``
survives as a compatibility shim that builds a uniform plan — bit-for-bit
identical to the historical single-method pipeline.

**Mesh execution (DESIGN.md §6).** ``compress_with_plan(..., mesh=...)``
runs the two hot stages sharded: calibration capture data-parallel over the
mesh's batch axes (per-shard reservoirs merged under a fixed global-index
replacement schedule) and the per-layer expert solves sharded over the
mesh's expert ("model") axis, all-gathered back into the same padded hetero
tables. The contract — enforced by ``tests/test_dist_compress.py`` — is
bit-for-bit: an N-device mesh produces exactly the single-device tables,
remaps, and report.

Works on any MoE config; raises TechniqueInapplicable for expert-free
architectures (DESIGN.md §4).
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration as CAL
from repro.core import plan as PLAN
from repro.core.errors import TechniqueInapplicable, CalibrationError
from repro.distributed.compression import shard_layer_solves
from repro.models.config import ModelConfig

# Paper Fig. 4: below ~32 calibration samples the least-squares system is
# under-determined and quality collapses to chance.
MIN_SAMPLE_WARN = 32


def _slice_layers(tree, sel):
    return jax.tree.map(lambda a: a[sel], tree)


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _pad_rows(a: np.ndarray, M_max: int) -> np.ndarray:
    """Zero-pad the expert (first) axis of a merged table to M_max."""
    if a.shape[0] == M_max:
        return a
    widths = [(0, M_max - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, widths)


def compress_with_plan(cfg: ModelConfig, params: dict,
                       plan: PLAN.CompressionPlan, *,
                       batches: Optional[Iterable[dict]] = None,
                       stream: Optional[CAL.CalibrationStream] = None,
                       max_tokens: Optional[int] = None,
                       strict_samples: bool = False, seed: int = 0,
                       calib_policy: str = "reservoir",
                       mesh=None,
                       ) -> Tuple[ModelConfig, dict, Dict]:
    """Execute ``plan`` against ``params``. Calibration comes from ``stream``
    (a pre-fed :class:`CalibrationStream`, reusable across planning and
    merging) or is collected here from ``batches`` (``calib_policy`` picks
    what survives a ``max_tokens`` cap: a uniform reservoir sample, or
    ``"head"`` — the legacy first-``max_tokens`` truncation).

    ``mesh``: run calibration capture data-parallel over the mesh's batch
    axes and the per-layer solves sharded over its expert ("model") axis —
    bit-for-bit identical to the single-device run (DESIGN.md §6). A pre-fed
    ``stream`` keeps whatever mesh it was built with."""
    plan.validate(cfg)
    if cfg.moe_merged:
        raise ValueError("model is already compressed")

    new_cfg = plan.apply_to(cfg)
    split = plan.split
    L, N = cfg.n_layers, cfg.moe.n_experts
    M_max = plan.max_merged

    t0 = time.perf_counter()
    if stream is None:
        stream = CAL.CalibrationStream(cfg, params,
                                       max_tokens_per_layer=max_tokens,
                                       seed=seed, policy=calib_policy,
                                       mesh=mesh)
    if batches is not None:
        stream.consume(batches)
    t_calib = time.perf_counter() - t0

    n_samples = stream.n_tokens
    if n_samples < MIN_SAMPLE_WARN:
        if strict_samples:
            raise CalibrationError(
                f"{n_samples} calibration tokens < critical threshold "
                f"{MIN_SAMPLE_WARN} (paper Fig. 4)")
        warnings.warn(
            f"only {n_samples} calibration tokens (< {MIN_SAMPLE_WARN}, "
            "paper Fig. 4): the least-squares merge may be under-determined",
            stacklevel=2)

    stack = params["stack"]
    moe_p = stack["moe"]
    needs_router = "router" in plan.requirements()
    router_all = (np.asarray(moe_p["router"], np.float32)
                  if needs_router else None)          # [L, d, N]

    # ---- solve stage: one closure per layer, sharded over the mesh's
    # expert axis (host threads — the solves are replicated-input fp64
    # NumPy, so the gather is bit-identical to the sequential loop for any
    # shard count; DESIGN.md §6)
    calibs = {spec.layer: stream.layer(spec.layer) for spec in plan.specs}

    def solve_one(spec):
        strategy = PLAN.get_strategy(spec.method)
        calib = calibs[spec.layer]
        return strategy.merge(
            np.asarray(moe_p["wg"][spec.layer], np.float32),
            np.asarray(moe_p["wu"][spec.layer], np.float32),
            np.asarray(moe_p["wd"][spec.layer], np.float32),
            calib.counts if "counts" in strategy.requires else None,
            calib.x if "x" in strategy.requires else None,
            spec.merged_experts,
            router=(router_all[spec.layer]
                    if "router" in strategy.requires else None),
        )

    n_solve_shards = 1
    if mesh is not None:
        from repro.launch.mesh import expert_axis_size
        n_solve_shards = min(expert_axis_size(mesh), len(plan.specs))

    t0 = time.perf_counter()
    merged, solve_stats = shard_layer_solves(
        [lambda spec=spec: solve_one(spec) for spec in plan.specs],
        max(n_solve_shards, 1))
    t_merge = time.perf_counter() - t0

    per_layer: List[Dict] = []
    for spec, res in zip(plan.specs, merged):
        resid = res.info.get("resid")
        per_layer.append({
            "layer": spec.layer, "method": spec.method,
            "merged_experts": spec.merged_experts,
            "resid": (None if resid is None
                      else [float(r) for r in np.asarray(resid)]),
        })

    # ---- assemble the compressed parameter tree (padded to max M)
    dt = cfg.param_dtype
    suffix = _slice_layers(stack, slice(split, L))
    suffix_moe = dict(suffix["moe"])
    suffix_moe["wg"] = jnp.asarray(
        np.stack([_pad_rows(r.wg, M_max) for r in merged]), dt)
    suffix_moe["wu"] = jnp.asarray(
        np.stack([_pad_rows(r.wu, M_max) for r in merged]), dt)
    suffix_moe["wd"] = jnp.asarray(
        np.stack([_pad_rows(r.wd, M_max) for r in merged]), dt)
    suffix_moe["remap"] = jnp.asarray(np.stack([r.remap for r in merged]),
                                      jnp.int32)
    suffix_moe["live"] = jnp.asarray(plan.merged_per_layer, jnp.int32)
    if plan.weight_dtype == "int8":
        # calibration-aware int8: scales come from the CALIBRATED tables the
        # solves just produced (per expert, per output channel); pad rows are
        # zeros and quantize to zero scale, staying exact (DESIGN.md §8).
        # Deterministic on the gathered solves, so the §6 mesh bit-for-bit
        # contract carries over unchanged.
        from repro.core import quant as QT
        suffix_moe = QT.quantize_moe_tree(suffix_moe)
    suffix = dict(suffix)
    suffix["moe"] = suffix_moe

    new_params = {k: v for k, v in params.items() if k != "stack"}
    if split > 0:
        new_params["stack"] = _slice_layers(stack, slice(0, split))
    new_params["stack_c"] = suffix

    orig = _tree_bytes(params)
    padded = _tree_bytes(new_params)
    # live bytes: what a ragged artifact stores — pad rows excluded (same
    # per-expert byte model the budget planner optimizes, at the plan's
    # storage dtype)
    pad_bytes = sum((M_max - m) * PLAN.expert_bytes(cfg, plan.weight_dtype)
                    for m in plan.merged_per_layer)
    comp = padded - pad_bytes
    methods = sorted(set(plan.methods))
    mesh_info = None
    if mesh is not None:
        from repro.launch.mesh import mesh_shape_dict, mesh_devices
        mesh_info = {"axes": mesh_shape_dict(mesh),
                     "devices": mesh_devices(mesh),
                     "solve_shards": solve_stats["n_shards"],
                     "t_solve_shards_s": solve_stats["t_shard_s"]}
    info = {
        "method": methods[0] if len(methods) == 1 else "mixed",
        "plan": plan.with_mesh(mesh).to_json_dict(),
        "mesh": mesh_info,
        "weight_dtype": plan.weight_dtype,
        "layers_merged": list(plan.layers),
        "merged_per_layer": list(plan.merged_per_layer),
        "per_layer": per_layer,
        "n_experts": N,
        "merged_experts": M_max,
        "calib_tokens": int(n_samples),
        "calib_warning": bool(n_samples < MIN_SAMPLE_WARN),
        "t_calibrate_s": t_calib,
        "t_merge_s": t_merge,
        "bytes_original": int(orig),
        "bytes_compressed": int(comp),
        "bytes_padded": int(padded),
        "compression_ratio": float(orig) / float(comp),
        "resid": [e["resid"] for e in per_layer if e["resid"] is not None],
    }
    return new_cfg, new_params, info


def compress_model(cfg: ModelConfig, params: dict, *, method: str = "mergemoe",
                   merged_experts: int, split: int | None = None,
                   batches: Iterable[dict], max_tokens: int | None = None,
                   strict_samples: bool = False, seed: int = 0,
                   ) -> Tuple[ModelConfig, dict, Dict]:
    """Legacy single-method surface: builds a uniform plan and executes it."""
    if cfg.moe is None:
        raise TechniqueInapplicable(
            f"{cfg.name} ({cfg.family}) has no routed experts (DESIGN.md §4).")
    plan = PLAN.uniform(cfg, method=method, merged_experts=merged_experts,
                        split=split)
    # calib_policy="head": a max_tokens cap truncates to the FIRST tokens,
    # exactly as the historical pipeline did
    return compress_with_plan(cfg, params, plan, batches=batches,
                              max_tokens=max_tokens,
                              strict_samples=strict_samples, seed=seed,
                              calib_policy="head")
