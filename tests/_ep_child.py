"""Subprocess worker for the expert-parallel serving differential tests.

Runs the SAME deterministic request trace through the continuous-batching
engine in every decode mode — plain step loop, fused block, speculative,
dense and paged KV, batched admission throughout — either single-device
(no ``--mesh``) or shard_map'd over a forced multi-device host platform
(``--mesh data=2,model=2`` under ``XLA_FLAGS=--xla_force_host_platform_
device_count=4``), and emits a JSON record of every request's token stream.
The parent test asserts the records are token-for-token IDENTICAL across
device counts: the DESIGN.md §13 contract (EP all-to-all dispatch + sharded
KV is bitwise-transparent under the fp32 combine wire).

Not a test module (no ``test_`` prefix); invoked by
``tests/test_ep_serving.py`` and reusable from the command line:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tests/_ep_child.py --mesh data=2,model=2
"""
import argparse
import json
import sys

import numpy as np


def build_trace(cfg, seed: int = 3, n_requests: int = 6):
    """Deterministic request set: varied prompt lengths across two buckets,
    two requests sharing a 16-token prefix (exercises paged prefix
    sharing), staggered arrivals so admission batches some and not others,
    a temperature stream per-uid as always."""
    rng = np.random.default_rng(seed)
    reqs = []
    arrivals = [0.0, 0.0, 0.0, 2.0, 5.0, 9.0, 13.0, 17.0]
    shared = rng.integers(0, cfg.vocab_size, size=16, dtype=np.int64)
    for i in range(n_requests):
        n = int(rng.integers(4, 28))
        prompt = rng.integers(0, cfg.vocab_size, size=n, dtype=np.int64)
        if i in (1, 4):     # prefix sharers (identical first 16 tokens)
            prompt = np.concatenate([shared, prompt[:8]])
        reqs.append({
            "prompt": prompt,
            "max_new_tokens": int(rng.integers(4, 12)),
            "arrival_time": arrivals[i % len(arrivals)],
        })
    return reqs


def run_trace(cfg, params, ec_kwargs, trace, draft_cfg=None,
              draft_params=None):
    import time
    from repro.serving.engine import Engine, EngineConfig
    eng = Engine(EngineConfig(**ec_kwargs), cfg=cfg, params=params,
                 draft_cfg=draft_cfg, draft_params=draft_params)
    for t in trace:
        eng.submit(t["prompt"], t["max_new_tokens"],
                   arrival_time=t["arrival_time"])
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = int(eng.counters["tokens_out"])
    return {
        "tokens": {str(r.uid): [int(t) for t in r.out_tokens]
                   for r in done},
        "statuses": {str(r.uid): r.status for r in done},
        "tokens_out": toks,
        "quarantined": int(eng.counters["quarantined"]),
        # run-local performance — excluded from cross-device-count parity
        # comparisons (wall time obviously differs)
        "perf": {
            "wall_s": round(dt, 3),
            "tok_per_s": round(toks / max(dt, 1e-9), 1),
            "host_dispatches_per_token": round(
                eng.host_dispatches_per_token, 4),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--modes", default=None,
                    help="comma-separated subset (default: all)")
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.models import model as MD

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    # an independently seeded model of the same architecture serves as the
    # draft: spec mode's token-for-token contract holds for ANY draft (low
    # acceptance just exercises rollback harder), and it keeps the child
    # free of a compression run
    draft_params = MD.init(cfg, jax.random.PRNGKey(2))
    trace = build_trace(cfg)

    base = dict(n_slots=4, s_max=64, prefill_buckets=(16, 32),
                seed=0, mesh=args.mesh)
    modes = {
        "dense_plain": dict(base, decode_block=1),
        "dense_block": dict(base, decode_block=4),
        "dense_block_t": dict(base, decode_block=4, temperature=0.7),
        "paged_block": dict(base, decode_block=4, kv_layout="paged",
                            kv_block=8),
        "spec_dense": dict(base, spec_k=3),
        "spec_paged": dict(base, spec_k=3, kv_layout="paged", kv_block=8),
    }
    wanted = (set(args.modes.split(",")) if args.modes else set(modes))

    out = {"devices": jax.device_count(), "mesh": args.mesh}
    for name, kwargs in modes.items():
        if name not in wanted:
            continue
        spec = name.startswith("spec")
        out[name] = run_trace(
            cfg, params, kwargs, trace,
            draft_cfg=cfg if spec else None,
            draft_params=draft_params if spec else None)
    json.dump(out, sys.stdout)


if __name__ == "__main__":
    main()
