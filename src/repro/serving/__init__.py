"""Request-level serving: continuous batching over the slotted KV cache."""
from repro.serving.engine import (  # noqa: F401
    Engine,
    EngineConfig,
    Request,
    poisson_trace,
)
