"""Deterministic fault-injection harness for the serving engine
(DESIGN.md §12).

A :class:`FaultPlan` is a SEEDED, pure description of what goes wrong and
when: every fire/no-fire decision is a counter-based hash of
``(seed, site, step, salt)`` — no wall clock, no global RNG state, no
``Date.now``-style nondeterminism anywhere — so the same seed replays the
identical fault trace and every failure mode is a regression test instead
of a war story.

The engine consults the plan at four named sites:

====== ===================== ==========================================
site   kind                  injected effect
====== ===================== ==========================================
decode ``nan_logits``        NaN-poison chosen slots' logits inside the
                             fused decode / spec-verify block (the
                             numeric sentinel must quarantine them)
decode ``transient``         the jitted decode call fails ``fails``
                             times before succeeding (bounded retry)
admit  ``transient``         same, for the admission call
alloc  ``exhaust``           the block allocator reports an empty pool,
                             deferring the FIFO head (deadline/shedding
                             paths under pool pressure)
ckpt   ``corrupt``           deterministic bit-flips over artifact bytes
                             (``tree_digest`` verification must catch)
====== ===================== ==========================================

Each firing appends one record to :attr:`FaultPlan.trace`;
:meth:`FaultPlan.trace_digest` hashes the ordered trace so tests and
``check_bench`` can assert same-seed runs reproduce the identical fault
sequence bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Tuple

import numpy as np

SITES = ("decode", "admit", "alloc", "ckpt")
KINDS = {"decode": ("nan_logits", "transient"),
         "admit": ("transient",),
         "alloc": ("exhaust",),
         "ckpt": ("corrupt",)}

_MASK64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One splitmix64 scramble round — the same counter-based construction
    the calibration reservoir uses: stateless, platform-independent, and a
    pure function of its integer input."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def _hash(seed: int, site: str, step: int, salt: int) -> int:
    h = _splitmix64(seed & _MASK64)
    h = _splitmix64(h ^ (SITES.index(site) + 1))
    h = _splitmix64(h ^ (step & _MASK64))
    return _splitmix64(h ^ (salt & _MASK64))


def _uniform(seed: int, site: str, step: int, salt: int) -> float:
    return _hash(seed, site, step, salt) / float(1 << 64)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault family. Fires at every step listed in ``steps`` and,
    independently, with probability ``p`` per consulted step (hash-driven,
    so probabilistic firings are still seed-deterministic)."""
    site: str
    kind: str
    steps: Tuple[int, ...] = ()
    p: float = 0.0
    # nan_logits: slots to poison (empty = one hash-picked slot per firing)
    slots: Tuple[int, ...] = ()
    # transient: consecutive injected failures per firing step
    fails: int = 1
    # corrupt: byte positions whose low bit flips (empty = first byte)
    byte_offsets: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites are {SITES}")
        if self.kind not in KINDS[self.site]:
            raise ValueError(f"kind {self.kind!r} is not injectable at site "
                             f"{self.site!r} (valid: {KINDS[self.site]})")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p={self.p} outside [0, 1]")
        if self.fails < 1:
            raise ValueError("fails must be >= 1")


class FaultPlan:
    """Seeded fault schedule consulted by the Engine's hooks.

    Decisions are pure functions of ``(seed, site, step)``; the only
    mutable state is the append-only :attr:`trace` of faults that actually
    FIRED, in consultation order — replaying the same engine trace with the
    same plan seed appends the same records."""

    def __init__(self, seed: int = 0, specs: Tuple[FaultSpec, ...] = ()):
        self.seed = int(seed)
        self.specs = tuple(specs)
        self.trace: List[Dict] = []

    # -- pure fire decisions ------------------------------------------------

    def _fires(self, spec: FaultSpec, step: int, salt: int) -> bool:
        if step in spec.steps:
            return True
        return spec.p > 0.0 and _uniform(self.seed, spec.site, step,
                                         salt) < spec.p

    def _record(self, step: int, site: str, kind: str, **detail) -> None:
        self.trace.append(dict(step=int(step), site=site, kind=kind,
                               **detail))

    # -- site hooks ---------------------------------------------------------

    def poison_mask(self, step: int, k: int, n_slots: int) -> np.ndarray:
        """Per-slot NaN-poison mask for the decode block covering engine
        steps ``[step, step + k)``. A listed step anywhere inside the block
        fires (fused blocks advance the step clock by K per call)."""
        mask = np.zeros((n_slots,), bool)
        for si, spec in enumerate(self.specs):
            if spec.site != "decode" or spec.kind != "nan_logits":
                continue
            hit = [s for s in range(step, step + k)
                   if self._fires(spec, s, salt=si)]
            if not hit:
                continue
            slots = spec.slots or (
                _hash(self.seed, "decode", hit[0], salt=1000 + si)
                % n_slots,)
            for s in slots:
                if 0 <= s < n_slots:
                    mask[s] = True
            self._record(hit[0], "decode", "nan_logits",
                         slots=sorted(int(s) for s in slots
                                      if 0 <= s < n_slots))
        return mask

    def transient_failures(self, site: str, step: int) -> int:
        """Consecutive injected failures for the device call at
        ``(site, step)`` — the engine retries up to its budget."""
        total = 0
        for si, spec in enumerate(self.specs):
            if spec.site != site or spec.kind != "transient":
                continue
            if self._fires(spec, step, salt=si):
                total += spec.fails
                self._record(step, site, "transient", fails=spec.fails)
        return total

    def exhausted(self, step: int) -> bool:
        """True when the allocator pool should report exhaustion at
        ``step``, deferring the FIFO head."""
        for si, spec in enumerate(self.specs):
            if spec.site != "alloc" or spec.kind != "exhaust":
                continue
            if self._fires(spec, step, salt=si):
                self._record(step, "alloc", "exhaust")
                return True
        return False

    def corrupt(self, data: bytes, step: int = 0) -> bytes:
        """Deterministically bit-flip ``data`` (site ``ckpt``). Returns the
        corrupted copy; the input is untouched. With no firing ckpt spec
        the data passes through unchanged."""
        out = bytearray(data)
        for si, spec in enumerate(self.specs):
            if spec.site != "ckpt" or spec.kind != "corrupt":
                continue
            if not self._fires(spec, step, salt=si) or not out:
                continue
            offsets = spec.byte_offsets or (0,)
            for off in offsets:
                out[off % len(out)] ^= 1
            self._record(step, "ckpt", "corrupt",
                         byte_offsets=[int(o) for o in offsets])
        return bytes(out)

    # -- trace identity -----------------------------------------------------

    def counts(self) -> Dict[str, int]:
        """Fired-fault counts by kind (what a degraded-mode bench row must
        record exactly)."""
        out: Dict[str, int] = {}
        for ev in self.trace:
            out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    def trace_digest(self) -> str:
        """sha256 over the ordered fault trace — two runs produced the same
        faults iff their digests match."""
        h = hashlib.sha256()
        for ev in self.trace:
            h.update(repr(sorted(ev.items())).encode())
        return h.hexdigest()
