"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret-mode
allclose tests in tests/test_kernels.py) and the fallback implementations on
backends without Pallas support.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.models.numerics import ein, ein32, dot as _ndot

F32 = jnp.float32


def swiglu_mlp(x: jax.Array, wg: jax.Array, wu: jax.Array,
               wd: jax.Array) -> jax.Array:
    """Fused SwiGLU MLP oracle. x: [T, d]; wg/wu: [d, f]; wd: [f, d]."""
    g = _ndot(x, wg)
    u = _ndot(x, wu)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return _ndot(h, wd).astype(x.dtype)


def grouped_swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                   group_sizes: jax.Array) -> jax.Array:
    """Grouped (per-expert) SwiGLU oracle.

    x: [T, d] rows sorted by expert; wg/wu: [E, d, f]; wd: [E, f, d];
    group_sizes: [E] int32 with sum == T. Row t is processed by expert
    e(t) = the bucket t falls into.
    """
    T = x.shape[0]
    E = wg.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes
    eid = jnp.searchsorted(starts, jnp.arange(T), side="right") - 1
    eid = jnp.clip(eid, 0, E - 1)
    g = ein("td,tdf->tf", x, wg[eid])
    u = ein("td,tdf->tf", x, wu[eid])
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return ein("tf,tfd->td", h, wd[eid]).astype(x.dtype)


def gather_swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array,
                  idx: jax.Array, w: jax.Array) -> jax.Array:
    """Decode-mode (gather-dispatch) MoE oracle.

    x: [T, d]; wg/wu: [E, d, f]; wd: [E, f, d]; idx: [T, k] int32 REAL-expert
    ids; w: [T, k] combine weights. Returns [T, d] with row t equal to
    ``Σ_j w[t, j] · SwiGLU_{idx[t, j]}(x[t])`` — the same per-row arithmetic
    as :func:`grouped_swiglu` on expert-sorted rows, evaluated token-major
    (no sort/bincount/scatter). The combine accumulates in fp32, mirroring
    the ragged path's scatter-add.
    """
    T, d = x.shape
    k = idx.shape[-1]
    E = wg.shape[0]
    eid = jnp.clip(idx.reshape(-1), 0, E - 1)        # [T*k] token-major
    xr = jnp.repeat(x, k, axis=0)                    # [T*k, d]
    g = ein("td,tdf->tf", xr, wg[eid])
    u = ein("td,tdf->tf", xr, wu[eid])
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = ein("tf,tfd->td", h, wd[eid]).astype(x.dtype)
    out = jnp.sum(y.reshape(T, k, d).astype(F32)
                  * w.reshape(T, k, 1).astype(F32), axis=1)
    return out.astype(x.dtype)


def _dequant32(qt):
    """fp32 dequantized tables — ``q * scale`` with NO intermediate downcast.

    The int8 paths keep the dequantized weights at fp32 all the way through
    the SwiGLU (one output-side downcast only). Intermediate ``bf16``
    roundings would be unstable validation targets: XLA's excess-precision
    pass cancels f32→bf16→f32 round-trips inside fused computations, so a
    kernel could not reproduce them bit for bit (DESIGN.md §8)."""
    return (qt.wg.astype(F32) * qt.wg_scale,
            qt.wu.astype(F32) * qt.wu_scale,
            qt.wd.astype(F32) * qt.wd_scale)


def grouped_swiglu_q(x: jax.Array, qt, group_sizes: jax.Array) -> jax.Array:
    """Int8 grouped SwiGLU oracle.

    ``qt``: :class:`repro.core.quant.QuantizedExpertTables`. Same grouping
    semantics as :func:`grouped_swiglu`; arithmetic is fp32 end-to-end on
    the dequantized tables with a single downcast at the output — exactly
    the int8 Pallas kernel's dataflow, which matches this oracle bit for
    bit when the f axis is unblocked (tests/test_kernels.py)."""
    wg32, wu32, wd32 = _dequant32(qt)
    T = x.shape[0]
    E = qt.wg.shape[0]
    starts = jnp.cumsum(group_sizes) - group_sizes
    eid = jnp.searchsorted(starts, jnp.arange(T), side="right") - 1
    eid = jnp.clip(eid, 0, E - 1)
    x32 = x.astype(F32)
    g = jnp.einsum("td,tdf->tf", x32, wg32[eid])
    u = jnp.einsum("td,tdf->tf", x32, wu32[eid])
    h = jax.nn.silu(g) * u
    return jnp.einsum("tf,tfd->td", h, wd32[eid]).astype(x.dtype)


def gather_swiglu_q(x: jax.Array, qt, idx: jax.Array,
                    w: jax.Array) -> jax.Array:
    """Int8 decode-mode (gather-dispatch) oracle.

    Row semantics of :func:`gather_swiglu` on the fp32-dequantized tables:
    each (token, j) contribution is computed at fp32, downcast to
    ``x.dtype`` (the same output rounding :func:`grouped_swiglu_q` applies,
    so the int8 ragged and gather paths stay bitwise-consistent at
    top_k = 2), then combined with fp32 weights."""
    T, d = x.shape
    k = idx.shape[-1]
    E = qt.wg.shape[0]
    wg32, wu32, wd32 = _dequant32(qt)
    eid = jnp.clip(idx.reshape(-1), 0, E - 1)
    xr = jnp.repeat(x, k, axis=0).astype(F32)
    g = jnp.einsum("td,tdf->tf", xr, wg32[eid])
    u = jnp.einsum("td,tdf->tf", xr, wu32[eid])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tf,tfd->td", h, wd32[eid]).astype(x.dtype)
    out = jnp.sum(y.reshape(T, k, d).astype(F32)
                  * w.reshape(T, k, 1).astype(F32), axis=1)
    return out.astype(x.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None) -> jax.Array:
    """Attention oracle. q/k/v: [B, H, S, hd] (same H; GQA expansion is done
    by the caller)."""
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    logits = ein("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        S_q, S_k = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((S_q, S_k), bool), k=S_k - S_q)
        logits = jnp.where(mask[None, None], logits, jnp.finfo(F32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return ein("bhqk,bhkd->bhqd", probs, v)


def _paged_sdpa(q: jax.Array, kc: jax.Array, vc: jax.Array,
                lens: jax.Array) -> jax.Array:
    """Shared paged-decode attention body over a GATHERED contiguous view.

    This is a bitwise mirror of ``layers._sdpa`` on the decode mask
    (``arange(s_max) <= pos`` with ``lens = pos + 1``): same GQA
    ``jnp.repeat`` expansion, same ``ein32`` logits, same fp32 min fill,
    same softmax-then-downcast, same output einsum. Rows past ``lens``
    carry whatever the pool holds (zeros, stale blocks, clipped sentinels)
    — they get probability exactly 0, and adding exact fp zeros to the
    reductions is the identity, which is why paged bf16 decode is bitwise
    equal to the dense slot cache (DESIGN.md §11)."""
    B, S, nkv, hd = kc.shape
    n_rep = q.shape[1] // nkv
    if n_rep > 1:
        kc = jnp.repeat(kc, n_rep, axis=2)
        vc = jnp.repeat(vc, n_rep, axis=2)
    logits = ein32("bqhd,bkhd->bhqk", q[:, None], kc) / math.sqrt(hd)
    mask = (jnp.arange(S)[None, :] < lens[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, jnp.finfo(F32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(vc.dtype)
    out = ein("bhqk,bkhd->bqhd", probs, vc).astype(vc.dtype)
    return out[:, 0]


def _gather_pool(pool: jax.Array, tab: jax.Array) -> jax.Array:
    """[n_blocks, bs, ...] pool + [B, mb] table -> [B, mb*bs, ...] view.
    Sentinel entries (>= n_blocks) clip to the last real block; their rows
    are masked by ``lens`` downstream."""
    nb, bs = pool.shape[0], pool.shape[1]
    tabc = jnp.clip(tab.astype(jnp.int32), 0, nb - 1)
    g = pool[tabc]                                    # [B, mb, bs, ...]
    return g.reshape((g.shape[0], g.shape[1] * bs) + g.shape[3:])


def paged_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                    tab: jax.Array, lens: jax.Array) -> jax.Array:
    """Paged decode attention oracle.

    q: [B, nq, hd] (the current token's query; its K/V row is already in
    the pool); kp/vp: [n_blocks, bs, nkv, hd]; tab: [B, mb] int32 block
    table (sentinel = n_blocks); lens: [B] int32 valid rows (``pos + 1``).
    Returns [B, nq, hd].
    """
    return _paged_sdpa(q, _gather_pool(kp, tab), _gather_pool(vp, tab), lens)


def paged_attention_q(q: jax.Array, kp: jax.Array, vp: jax.Array,
                      ks: jax.Array, vs: jax.Array, tab: jax.Array,
                      lens: jax.Array) -> jax.Array:
    """Int8-pool paged decode attention oracle.

    kp/vp: int8 [n_blocks, bs, nkv, hd]; ks/vs: fp32 [n_blocks, bs, nkv]
    per-(row, head) scales (``core.quant.quantize_kv``). Dequantizes the
    gathered view through ``quant.dequantize_kv`` — the same helper the
    verify path uses — so decode and verify see one consistent KV
    representation (the spec-decode self-consistency requirement, §11).
    """
    kc = Q.dequantize_kv(_gather_pool(kp, tab), _gather_pool(ks, tab),
                         q.dtype)
    vc = Q.dequantize_kv(_gather_pool(vp, tab), _gather_pool(vs, tab),
                         q.dtype)
    return _paged_sdpa(q, kc, vc, lens)
