"""End-to-end serving example: request-level continuous batching.

Submits a staggered trace of variable-length requests to the
continuous-batching engine, then serves the SAME trace with the model
MergeMoE-compressed to half the experts — both through the ragged
grouped-kernel MoE path — and compares throughput.

    PYTHONPATH=src python examples/serve_batched.py --requests 12
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import numpy as np

from repro.core import compress as CMP
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace
from repro import configs


def serve_trace(cfg, params, requests, n_slots=4, s_max=64,
                max_new_tokens=12, rate=0.5):
    buckets = (8, 16, 32)
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets),
                 cfg=cfg, params=params)
    rng = np.random.default_rng(0)
    arrivals = poisson_trace(requests, rate=rate, seed=1)
    # warmup (compile each prefill bucket + the decode step)
    for b in buckets:
        eng.submit(np.zeros(b, np.int32), max_new_tokens=2)
    eng.run()

    base = float(eng.steps)   # keep the trace staggered past the warmup clock
    for i in range(requests):
        n = int(rng.choice(buckets))
        eng.submit(rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32),
                   max_new_tokens=max_new_tokens,
                   arrival_time=base + float(arrivals[i]))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    return tokens / dt, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="ragged"))
    params = MD.init(cfg, jax.random.PRNGKey(0))

    tput_full, done = serve_trace(cfg, params, args.requests,
                                  n_slots=args.n_slots)
    print(f"[full      ] {tput_full:8.1f} tok/s "
          f"({cfg.moe.n_experts} experts, {len(done)} requests)")

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)
    tput_comp, done = serve_trace(ncfg, nparams, args.requests,
                                  n_slots=args.n_slots)
    print(f"[mergemoe  ] {tput_comp:8.1f} tok/s "
          f"({info['merged_experts']} experts, "
          f"{info['compression_ratio']:.2f}x smaller, "
          f"{len(done)} requests)")
    r = done[0]
    print(f"sample request {r.uid}: prompt {r.n_prompt} tokens -> "
          f"{r.out_tokens[:8]} ... [{r.finish_reason}]")


if __name__ == "__main__":
    main()
