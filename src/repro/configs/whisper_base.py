"""whisper-base — enc-dec audio, conv frontend STUB [arXiv:2212.04356;
unverified].

6L (encoder) + 6L (decoder), d_model=512 8H (kv=8, MHA) d_ff=2048
vocab=51865. ``input_specs()`` provides precomputed mel-frame embeddings
[B, 1500, d_model]; positional scheme: sinusoidal (encoder) + RoPE (decoder
self-attention) — noted in DESIGN.md as a TPU-idiomatic simplification.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encdec=True,
    n_audio_ctx=1500,
    remat="none",
)
