"""End-to-end self-speculative decoding example (DESIGN.md §10).

Serves a staggered request trace three ways and compares:

1. the plain continuous-batching engine (the reference),
2. the SPECULATIVE engine with the MergeMoE M = N/2 merge drafting
   ``--spec-k`` tokens per slot and the full model verifying them in one
   multi-position forward, accept/rollback on device,
3. the speculative engine again with the full model's own int8-quantized
   weights as the draft — a near-perfect drafter that shows the acceptance
   machinery at the other end of the dial.

Whatever the draft proposes, the committed tokens are bitwise what the
full model would have produced — the example asserts it. Acceptance (and
with it the decode-speedup economics) depends on how well the compressed
draft tracks the full model: high for trained MergeMoE artifacts, near
chance for the random-init weights used here.

    PYTHONPATH=src python examples/serve_spec.py --requests 8
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import configs
from repro.core import compress as CMP
from repro.core import quant as Q
from repro.models import model as MD
from repro.serving import Engine, EngineConfig, poisson_trace


def serve_trace(cfg, params, requests, *, draft=None, spec_k=4,
                n_slots=4, s_max=64, max_new_tokens=12, rate=0.5):
    buckets = (8, 16, 32)
    eng = Engine(EngineConfig(n_slots=n_slots, s_max=s_max,
                              prefill_buckets=buckets, spec_k=spec_k),
                 cfg=cfg, params=params,
                 draft_cfg=draft[0] if draft else None,
                 draft_params=draft[1] if draft else None)
    rng = np.random.default_rng(0)
    arrivals = poisson_trace(requests, rate=rate, seed=1)
    # warmup (compile each prefill bucket + the decode / spec round)
    for b in buckets:
        eng.submit(np.zeros(b, np.int32), max_new_tokens=2)
    eng.run()
    for c in eng.counters:
        eng.counters[c] = 0

    base = float(eng.steps)
    for i in range(requests):
        n = int(rng.choice(buckets))
        eng.submit(rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32),
                   max_new_tokens=max_new_tokens,
                   arrival_time=base + float(arrivals[i]), uid=i)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    out = {r.uid: list(r.out_tokens) for r in done}
    return tokens / dt, out, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--spec-k", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))

    calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(7), (4, 64),
                                           0, cfg.vocab_size)}]
    ncfg, nparams, info = CMP.compress_model(
        cfg, params, method="mergemoe",
        merged_experts=cfg.moe.n_experts // 2, split=0, batches=calib)

    tput, ref, _ = serve_trace(cfg, params, args.requests)
    print(f"[full            ] {tput:8.1f} tok/s "
          f"({cfg.moe.n_experts} experts, reference)")

    tput, out, eng = serve_trace(cfg, params, args.requests,
                                 draft=(ncfg, nparams), spec_k=args.spec_k)
    assert out == ref, "spec output diverged from the full model"
    print(f"[spec: merged    ] {tput:8.1f} tok/s  "
          f"acceptance {eng.acceptance_rate:.3f}  "
          f"({eng.counters['tokens_accepted']}/{eng.counters['tokens_drafted']}"
          f" drafts, {info['compression_ratio']:.2f}x smaller draft, "
          f"output bitwise == full)")

    qparams = Q.quantize_model_experts(params)
    tput, out, eng = serve_trace(cfg, params, args.requests,
                                 draft=(cfg, qparams), spec_k=args.spec_k)
    assert out == ref, "spec output diverged from the full model"
    print(f"[spec: int8-self ] {tput:8.1f} tok/s  "
          f"acceptance {eng.acceptance_rate:.3f}  "
          f"({eng.counters['tokens_accepted']}/{eng.counters['tokens_drafted']}"
          f" drafts, same weights quantized, output bitwise == full)")

    print("spec decode is EXACT by construction: acceptance only moves "
          "throughput, never tokens (trained MergeMoE drafts sit near the "
          "int8-self end; random-init merges near chance).")


if __name__ == "__main__":
    main()
