"""Expert-parallel sharded serving: the engine-level differential harness
(DESIGN.md §13) plus the crash-recovery snapshot drill (§12).

Evidence layers:

1. DIFFERENTIAL (subprocess, ``--dist`` lane): the same request trace runs
   through every engine mode — plain / fused-block / speculative decode,
   dense and paged KV, greedy and sampled — single-device and shard_map'd
   over a forced 4-device (data=2, model=2) mesh. Token streams must be
   IDENTICAL: EP all-to-all dispatch + sharded KV is bitwise-transparent
   under the fp32 combine wire.
2. INT8 COMBINE WIRE (in-process, ``--dist`` lane): the opt-in
   ``combine_wire_dtype='int8'`` return path is tolerance-gated — top-1
   agreement with the fp32-wire logits plus a relative-error bound.
3. FAIL-FAST VALIDATION (in-process, ``--dist`` lane): non-divisible
   expert tables and slot counts raise at construction, never mid-decode.
4. CRASH DRILL (subprocess, default lane): a periodic-snapshot engine is
   killed hard mid-trace; restoring from the snapshot directory finishes
   the trace token-for-token identical to an uninterrupted run.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs a forced 4-device host platform (scripts/test.sh --dist)")


def _child_env(devices=None):
    # JAX_PLATFORMS=cpu: without it, a container with libtpu installed
    # spends minutes retrying GCP metadata probes before falling back
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return env


def _run_ep_child(mesh=None, devices=None, modes=None):
    cmd = [sys.executable, "tests/_ep_child.py"]
    if mesh:
        cmd += ["--mesh", mesh]
    if modes:
        cmd += ["--modes", modes]
    r = subprocess.run(cmd, capture_output=True, text=True,
                       env=_child_env(devices), cwd=str(REPO), timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    return json.loads(r.stdout)


# ---------------------------------------------------------------------------
# 1. differential: forced-mesh engine == single-device, token for token
# ---------------------------------------------------------------------------

@pytest.mark.distributed
def test_ep_engine_token_identical_to_single_device():
    """Every decode mode of the engine — step loop, fused block (greedy AND
    sampled), speculative, dense and paged KV — produces token-for-token
    identical streams on a forced (data=2, model=2) mesh vs one device."""
    single = _run_ep_child()
    assert single["devices"] == 1
    meshed = _run_ep_child(mesh="data=2,model=2", devices=4)
    assert meshed["devices"] == 4
    modes = [k for k in single if k not in ("devices", "mesh")]
    assert len(modes) == 6
    for mode in modes:
        strip = lambda rec: {k: v for k, v in rec.items() if k != "perf"}
        assert strip(meshed[mode]) == strip(single[mode]), \
            f"{mode}: EP-sharded engine diverged from single device"
        # not vacuous: every request served ok and produced tokens
        assert single[mode]["tokens_out"] > 0
        assert all(s == "ok" for s in single[mode]["statuses"].values())
        assert single[mode]["quarantined"] == 0


# ---------------------------------------------------------------------------
# in-process multi-device cases (scripts/test.sh --dist lane)
# ---------------------------------------------------------------------------

def _mesh_and_model():
    import dataclasses
    from repro import configs
    from repro.models import model as MD
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    # the serving dispatch the Engine would apply (EP engages only on the
    # gather/ragged paths; the dense einsum dispatch replicates)
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, dispatch="gather", gather_max_tokens=64))
    params = MD.init(cfg, jax.random.PRNGKey(0))
    return mesh, cfg, params


@pytest.mark.distributed
@needs_devices
def test_int8_combine_wire_top1_agreement():
    """The int8 combine wire (``compressed_psum`` of the pair-output
    table) is tolerance-gated: decode logits stay close to the fp32-wire
    logits and the greedy token agrees on (almost) every slot."""
    from repro.launch import steps as ST
    from repro.models import model as MD
    from repro.models.numerics import set_activation_mesh

    mesh, cfg, params = _mesh_and_model()
    set_activation_mesh(None)
    n_slots, s_max = 4, 32
    cache = MD.init_slot_cache(cfg, n_slots, s_max)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, size=(n_slots, 16))
    lengths = np.full((n_slots,), 16, np.int32)
    admit = jax.jit(ST.make_slot_admit_mesh(cfg, mesh, params, cache))
    _, _, cache = admit(params, cache, tokens, lengths,
                        np.arange(n_slots, dtype=np.int32))

    tok = np.asarray(rng.integers(0, cfg.vocab_size, size=(n_slots,)),
                     np.int32)
    act = np.ones((n_slots,), bool)
    poison = np.zeros((n_slots,), bool)
    out = {}
    for wire in ("fp32", "int8"):
        dec = jax.jit(ST.make_slot_decode_mesh(cfg, mesh, params, cache,
                                               combine_wire_dtype=wire))
        logits, aux, _ = dec(params, cache, tok, act, poison)
        out[wire] = (np.asarray(logits, np.float32), np.asarray(aux))
    l32, a32 = out["fp32"]
    l8, a8 = out["int8"]
    assert not np.array_equal(l8, l32)          # the int8 wire really ran
    rel = np.abs(l8 - l32).max() / (np.abs(l32).max() + 1e-9)
    assert rel < 0.05, f"int8 combine wire rel err {rel:.4f} >= 5%"
    top1 = float((a8[:, 0] == a32[:, 0]).mean())
    assert top1 >= 0.75, f"top-1 agreement {top1:.2f} < 0.75"


@pytest.mark.distributed
@needs_devices
def test_ep_validation_fails_fast():
    """Non-divisible expert tables (E % ep != 0) and slot counts
    (n_slots % dp != 0) raise at Engine construction."""
    import dataclasses
    from repro import configs
    from repro.models import model as MD
    from repro.serving.engine import Engine, EngineConfig

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    bad = cfg.replace(moe=dataclasses.replace(cfg.moe, n_experts=6))
    bad_params = MD.init(bad, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not divisible by the EP degree"):
        Engine(EngineConfig(n_slots=4, s_max=32, prefill_buckets=(16,),
                            mesh="data=1,model=4"),
               cfg=bad, params=bad_params)
    with pytest.raises(ValueError, match="n_slots"):
        Engine(EngineConfig(n_slots=6, s_max=32, prefill_buckets=(16,),
                            mesh="data=4,model=1"), cfg=cfg)
    with pytest.raises(ValueError, match="n_blocks"):
        Engine(EngineConfig(n_slots=4, s_max=32, prefill_buckets=(16,),
                            kv_layout="paged", kv_block=8, kv_blocks=18,
                            mesh="data=4,model=1"), cfg=cfg)


@pytest.mark.distributed
@needs_devices
def test_sharded_allocator_partitions_block_ranges():
    """With n_shards > 1 every slot's blocks stay inside its shard's block
    range, prefix chains never cross shards, and deferral is per-shard
    (one full shard defers its slot even while the other has room)."""
    from repro.serving.paging import PagedAllocator

    # slots 0,1 -> shard 0 (blocks 0..5); slots 2,3 -> shard 1 (blocks 6..11)
    a = PagedAllocator(n_slots=4, n_blocks=12, block_size=4, s_max=16,
                       n_shards=2)
    p = np.arange(12, dtype=np.int32)
    assert a.admit(0, p, 16) == 0               # 4 blocks; shard 0 has 2 left
    a.register_prefix(0, p)
    # the registered chain is invisible from the other shard's slots
    assert a.lookup_prefix(p, shard=1) == (0, ())
    # ... but same-shard slot 1 adopts it: 2 shared + 2 new = shard 0 full
    assert a.admit(1, p, 16) == 8
    assert a.stats["prefix_hits"] == 1
    for slot in (0, 1):
        assert all(a.shard_of_block(b) == 0 for b in a._owned[slot])
    a.release(1)                                # shard 0 back to 2 free blocks
    # per-shard capacity: slot 1 needs 4 blocks, shard 0 has 2, and registry
    # eviction can't help (the chain's blocks are still owned by slot 0) ->
    # DEFER, even though shard 1 could satisfy the same request right now
    q = np.arange(50, 62, dtype=np.int32)
    assert a.admit(1, q, 16) is None
    assert a.stats["deferrals"] == 1
    assert a.admit(3, q, 16) == 0               # same request, shard 1: fine
    assert all(a.shard_of_block(b) == 1 for b in a._owned[3])
    a.check_invariants()
    got = a.state_dict()
    b = PagedAllocator(n_slots=4, n_blocks=12, block_size=4, s_max=16,
                       n_shards=2)
    b.load_state(got)
    b.check_invariants()
    assert b.state_dict() == got


# ---------------------------------------------------------------------------
# 4. crash-recovery drill (default lane)
# ---------------------------------------------------------------------------

def test_crash_recovery_drill_token_identical(tmp_path):
    """Kill a periodic-snapshot engine hard mid-trace (os._exit), restore
    from the snapshot directory, finish the trace: the union of pre-crash
    and post-restore token streams equals an uninterrupted run's,
    token-for-token (DESIGN.md §12)."""
    from repro import configs
    from repro.serving.engine import Engine, EngineConfig
    sys.path.insert(0, str(REPO / "tests"))
    from _ep_child import build_trace

    snap_dir = tmp_path / "snaps"
    r = subprocess.run(
        [sys.executable, "tests/_snapshot_drill_child.py",
         "--snapshot-dir", str(snap_dir), "--kill-after-steps", "12"],
        capture_output=True, text=True, env=_child_env(), cwd=str(REPO),
        timeout=900)
    assert r.returncode == 17, \
        f"drill child should die with exit 17, got {r.returncode}: " \
        f"{r.stdout + r.stderr}"
    pre_crash = [json.loads(line) for line in r.stdout.splitlines() if line]

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    ec = EngineConfig(n_slots=4, s_max=64, prefill_buckets=(16, 32), seed=0,
                      decode_block=4, kv_layout="paged", kv_block=8)
    trace = build_trace(cfg)

    # the uninterrupted reference run (same seeded default params)
    ref_eng = Engine(ec, cfg=cfg)
    for t in trace:
        ref_eng.submit(t["prompt"], t["max_new_tokens"],
                       arrival_time=t["arrival_time"])
    ref = {r_.uid: [int(t) for t in r_.out_tokens] for r_ in ref_eng.run()}

    # restore from the last committed periodic snapshot and finish
    eng = Engine.restore(str(snap_dir), cfg=cfg)
    assert eng.steps > 0 and eng.steps <= 12    # resumed mid-trace
    done = eng.run()
    post = {r_.uid: [int(t) for t in r_.out_tokens] for r_ in done}

    for rec in pre_crash:
        assert rec["tokens"] == ref[rec["uid"]], \
            f"uid {rec['uid']}: pre-crash stream diverged"
    for uid, toks in post.items():
        assert toks == ref[uid], f"uid {uid}: post-restore stream diverged"
    assert set(post) | {rec["uid"] for rec in pre_crash} == set(ref), \
        "some requests were lost across the crash"
    assert len(post) > 0                        # the restore really resumed
