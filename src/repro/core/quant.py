"""Int8 expert-weight quantization (DESIGN.md §8).

MergeMoE shrinks the NUMBER of expert tables; the bits per weight in each
surviving table are the other, multiplicative axis of the decode memory
budget (PuzzleMoE's bit-packed-inference observation). This module owns that
axis: symmetric per-expert-per-OUTPUT-CHANNEL int8 quantization of the
calibrated SwiGLU tables ``wg/wu/wd``, applied at the end of
``compress_with_plan`` when the plan's ``weight_dtype`` is ``"int8"``.

Format
------
For ``wg``/``wu`` of shape ``[..., E, d, f]`` the output channel is the FFN
column ``f``; for ``wd`` ``[..., E, f, d]`` it is the model column ``d``. Each
(expert, output channel) pair gets one fp32 scale ``amax / 127`` (reduced
over the contraction axis, ``axis=-2``), stored with a broadcast-ready
keepdim: scales are ``[..., E, 1, f]`` / ``[..., E, 1, d]``. Values quantize
by round-to-nearest-even of ``w / scale`` clipped to ``[-127, 127]`` — the
symmetric range, so dequantization is a single fused multiply with no zero
point. All-zero channels (the pad rows of heterogeneous suffixes,
DESIGN.md §5) store scale 0 and dequantize back to exact zeros.

Per-output-channel (not per-tensor) granularity matters because the merge
solve (§1-§2) leaves the merged down projection with strongly heterogeneous
column norms; a per-tensor scale would burn most of the 8-bit range on the
few largest columns.

In the parameter tree the six arrays live as a plain dict under
``moe["qexp"]`` (replacing the ``wg``/``wu``/``wd`` leaves) so generic tree
machinery — checkpoint treedef proto serialization, path-rule sharding,
``lax.scan`` over stacked layers — needs no custom pytree registration;
:class:`QuantizedExpertTables` is the typed view model/kernel code works
with (``QuantizedExpertTables.from_tree(p["qexp"])``).

Numerics contract (DESIGN.md §8): dequantization inside the Pallas kernels
reproduces the jnp dequant oracles bit for bit (tests/test_kernels.py),
and the int8 ragged and gather paths consume identical fp32-dequantized
values through identical fp32 combines, so they agree bitwise with each
other at top_k = 2. Across REPRESENTATIONS the contract is a tolerance,
not parity: the int8 paths keep the dequantized weights at fp32
internally, while serving tables materialized at the model dtype
(:func:`dequantize_moe_tree`) round through bf16 inside the standard
paths — same weights, different intermediate roundings.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
I8_MAX = 127.0

#: tree keys of one quantized expert-table set, in a fixed order
#: (checkpoint packing and tests iterate this)
QEXP_KEYS = ("wg", "wu", "wd", "wg_scale", "wu_scale", "wd_scale")


class QuantizedExpertTables(NamedTuple):
    """Typed view over a ``moe["qexp"]`` subtree.

    ``wg``/``wu``: int8 ``[..., E, d, f]``; ``wd``: int8 ``[..., E, f, d]``;
    scales: fp32 with the contraction axis kept at 1 (``[..., E, 1, f]`` /
    ``[..., E, 1, d]``) so ``q * scale`` broadcasts. NamedTuples cannot ride
    in checkpointed trees (treedef proto rejects user nodes), hence
    :meth:`to_tree`/:meth:`from_tree`.
    """
    wg: jax.Array
    wu: jax.Array
    wd: jax.Array
    wg_scale: jax.Array
    wu_scale: jax.Array
    wd_scale: jax.Array

    @classmethod
    def from_tree(cls, tree: Dict) -> "QuantizedExpertTables":
        return cls(**{k: tree[k] for k in QEXP_KEYS})

    def to_tree(self) -> Dict:
        return {k: getattr(self, k) for k in QEXP_KEYS}

    @property
    def n_experts(self) -> int:
        return self.wg.shape[-3]

    def dequant(self, dtype) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """(wg, wu, wd) materialized at ``dtype`` (dense dispatch, export);
        the ragged/gather kernels apply the same fp32 product per block but
        skip the ``dtype`` cast (fp32-internal, DESIGN.md §8)."""
        return (dequantize(self.wg, self.wg_scale, dtype),
                dequantize(self.wu, self.wu_scale, dtype),
                dequantize(self.wd, self.wd_scale, dtype))


def quantize_channelwise(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization over ``axis=-2`` (the contraction axis).

    Returns ``(q int8, scale f32 keepdim)`` with
    ``|w - q*scale| <= scale/2`` per channel (round-to-nearest) and
    ``q == 0, scale == 0`` for all-zero channels.
    """
    w32 = jnp.asarray(w, F32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
    scale = amax / I8_MAX
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(w32 * inv), -I8_MAX, I8_MAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """``q * scale`` at fp32, cast to ``dtype``. The Pallas kernels inline
    the same fp32 product per block and keep it at fp32 (their single
    downcast happens at the output store — DESIGN.md §8)."""
    return (q.astype(F32) * scale).astype(dtype)


def quantize_expert_tables(wg: jax.Array, wu: jax.Array, wd: jax.Array
                           ) -> QuantizedExpertTables:
    """Quantize one expert-table set (any leading stack dims)."""
    qg, sg = quantize_channelwise(wg)
    qu, su = quantize_channelwise(wu)
    qd, sd = quantize_channelwise(wd)
    return QuantizedExpertTables(qg, qu, qd, sg, su, sd)


# ---------------------------------------------------------------------------
# KV-row quantization (paged cache, DESIGN.md §11)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of KV rows over the LAST axis: one fp32
    scale per ``(..., head)`` — ``x`` is ``[..., nkv, hd]``, scales come back
    ``[..., nkv]`` (no keepdim; pool storage carries them as their own
    array). Same symmetric ``amax/127`` format as
    :func:`quantize_channelwise`, reduced over ``hd`` instead of the weight
    contraction axis: a K/V row's dynamic range is per head, and per-head
    granularity is what keeps RoPE'd keys inside 8 bits."""
    x32 = jnp.asarray(x, F32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = amax / I8_MAX
    inv = jnp.where(scale > 0, 1.0 / jnp.where(scale > 0, scale, 1.0), 0.0)
    q = jnp.clip(jnp.round(x32 * inv[..., None]),
                 -I8_MAX, I8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """``q * scale`` at fp32 with the per-head scale broadcast over ``hd``,
    cast to ``dtype``. Decode (oracle and kernel) and verify both dequantize
    through THIS function before the attention arithmetic, so the spec-decode
    draft/verify coupling sees one consistent KV representation — the reason
    int8-KV parity is a tolerance against the bf16 engine but the paged-int8
    engine agrees with itself across plain/block/spec decode (§11)."""
    return (q.astype(F32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# parameter-tree surgery
# ---------------------------------------------------------------------------

def quantize_moe_tree(moe_p: Dict) -> Dict:
    """Return ``moe_p`` with ``wg/wu/wd`` replaced by a ``qexp`` subtree.
    Router, remap, live, and shared-expert leaves pass through untouched
    (the router stays fp32; shared experts are a dense MLP, out of scope)."""
    if "qexp" in moe_p:
        return dict(moe_p)
    qt = quantize_expert_tables(moe_p["wg"], moe_p["wu"], moe_p["wd"])
    out = {k: v for k, v in moe_p.items() if k not in ("wg", "wu", "wd")}
    out["qexp"] = qt.to_tree()
    return out


def quantize_model_experts(params: Dict) -> Dict:
    """Quantize every routed-expert table in a model parameter tree (both
    the untouched prefix ``stack`` and the merged suffix ``stack_c``).
    Used for the full-model int8 rows of ``serve_bench`` and by callers that
    want int8 serving WITHOUT merging."""
    out = dict(params)
    for key in ("stack", "stack_c"):
        if key in params and "moe" in params[key]:
            out[key] = dict(params[key],
                            moe=quantize_moe_tree(params[key]["moe"]))
    return out


def is_quantized(moe_p: Dict) -> bool:
    return "qexp" in moe_p


def dequantize_moe_tree(moe_p: Dict, dtype) -> Dict:
    """Inverse surgery: materialize plain tables from a ``qexp`` subtree.

    NOT a bitwise stand-in for serving the int8 tree: the int8 kernel/oracle
    paths keep the dequantized weights at fp32 internally, while a
    materialized ``dtype`` table rounds through ``dtype`` (and the standard
    bf16 paths round their intermediates) — outputs agree to quantization-
    scale tolerance only. Use it to recover a conventional table layout
    (export, analysis), not for parity contracts (DESIGN.md §8)."""
    if "qexp" not in moe_p:
        return dict(moe_p)
    qt = QuantizedExpertTables.from_tree(moe_p["qexp"])
    wg, wu, wd = qt.dequant(dtype)
    out = {k: v for k, v in moe_p.items() if k != "qexp"}
    out.update(wg=wg, wu=wu, wd=wd)
    return out
