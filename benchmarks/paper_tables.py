"""One benchmark per paper table/figure, at reduced scale (CPU container).

The paper's absolute numbers need the original 14-30B checkpoints + GPU
eval; these benchmarks reproduce each experiment's MECHANISM and report the
same comparisons on an in-repo trained MoE (DESIGN.md §8 fidelity note):

  table_quality        — Tables 1-3: Full / MergeMoE / M-SMoE / Average /
                         ZipIt at equal compression ratio (held-out loss)
  table_generalization — Table 4: calibrate on corpus A, evaluate on B
  table_ablation       — Table 5: w/ vs w/o merging errors (oracle)
  fig_ratio            — Fig. 2: loss vs #merged-experts and #layers
  fig_timecost         — Fig. 3: merge wall-time MergeMoE vs M-SMoE
  fig_samples          — Fig. 4: loss vs calibration sample count
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import calibration as CAL
from repro.core import clustering as CL
from repro.core import compress as CMP
from repro.core import merge as MG
from repro.core import oracle as ORC
from repro.data.pipeline import SyntheticLM
from repro.launch.train import TrainConfig, train
from repro.models import model as MD

_CACHE: Dict = {}


def bench_cfg():
    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    # a little deeper than the smoke config so layer sweeps are meaningful
    return cfg.replace(n_layers=4)


def trained_model(steps=80):
    if "model" not in _CACHE:
        tc = TrainConfig(arch="qwen3-moe-30b-a3b", reduced=True, steps=steps,
                         global_batch=4, seq_len=64, lr=3e-3, ckpt_dir="",
                         log_every=1000)
        cfg = bench_cfg()
        out = _train_with_cfg(cfg, tc)
        _CACHE["model"] = (cfg, out)
    return _CACHE["model"]


def _train_with_cfg(cfg, tc):
    """train() but with an explicit cfg (benchmarks tweak depth)."""
    from repro.launch import sharding as SH
    from repro.launch import steps as ST
    from repro.launch.mesh import make_host_mesh
    from repro.optim import make_optimizer
    from repro.models.numerics import set_activation_mesh
    mesh = make_host_mesh()
    set_activation_mesh(mesh)
    opt = make_optimizer("adamw", lr=tc.lr)
    params = MD.init(cfg, jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(ST.make_train_step(cfg, opt))
    data = SyntheticLM(cfg.vocab_size, tc.seq_len, tc.global_batch,
                       seed=tc.seed)
    loss = None
    for step in range(tc.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt_state, loss, _ = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
    return params


def _eval_batches(cfg, n=4, seed=0, corpus_seed=999, batch=4, seq=64):
    data = SyntheticLM(cfg.vocab_size, seq, batch, seed=corpus_seed)
    out = []
    for _ in range(n):
        out.append({k: jnp.asarray(v) for k, v in next(data).items()})
    return out


def _loss(cfg, params, batches):
    fn = jax.jit(lambda p, b: MD.loss(cfg, p, b)[0])
    return float(np.mean([float(fn(params, b)) for b in batches]))


# ---------------------------------------------------------------------------

def table_quality(merged=4, split=2) -> List[dict]:
    cfg, params = trained_model()
    calib = _eval_batches(cfg, n=2, corpus_seed=7)
    evalb = _eval_batches(cfg, n=4, corpus_seed=999)
    rows = [{"strategy": "Full", "ratio": 1.0,
             "loss": _loss(cfg, params, evalb), "t_merge_s": 0.0}]
    for method in ("average", "zipit", "msmoe", "mergemoe"):
        t0 = time.perf_counter()
        ncfg, nparams, info = CMP.compress_model(
            cfg, params, method=method, merged_experts=merged, split=split,
            batches=calib)
        dt = time.perf_counter() - t0
        rows.append({"strategy": method, "ratio": info["compression_ratio"],
                     "loss": _loss(ncfg, nparams, evalb),
                     "t_merge_s": round(info["t_merge_s"], 3),
                     "t_total_s": round(dt, 3)})
    return rows


def table_generalization(merged=4, split=2) -> List[dict]:
    cfg, params = trained_model()
    corpora = {"A": 7, "B": 21, "C": 42}
    evals = {k: _eval_batches(cfg, n=3, corpus_seed=s + 1000)
             for k, s in corpora.items()}
    rows = []
    for src, seed in corpora.items():
        calib = _eval_batches(cfg, n=2, corpus_seed=seed)
        ncfg, nparams, _ = CMP.compress_model(
            cfg, params, method="mergemoe", merged_experts=merged,
            split=split, batches=calib)
        row = {"calib_source": src}
        for tgt in corpora:
            row[f"loss_on_{tgt}"] = round(_loss(ncfg, nparams, evals[tgt]), 4)
        rows.append(row)
    return rows


def table_ablation(merged=4) -> List[dict]:
    """w/ merging errors (real compressed model) vs w/o (output oracle)."""
    cfg, params = trained_model()
    batches = _eval_batches(cfg, n=2, corpus_seed=7)
    calib = CAL.collect(cfg, params, batches)
    ncfg, nparams, _ = CMP.compress_model(
        cfg, params, method="mergemoe", merged_experts=merged, split=0,
        batches=batches)
    remaps = np.asarray(nparams["stack_c"]["moe"]["remap"])
    assigns = {l: remaps[l] for l in range(cfg.n_layers)}
    bweights = {l: CL.merge_weights(remaps[l], calib[l].counts, merged)
                for l in range(cfg.n_layers)}
    batch = batches[0]
    full, _, _ = MD.forward(cfg, params, batch)
    oracle = ORC.oracle_forward(cfg, params, batch, assigns, bweights)
    merged_l, _, _ = MD.forward(ncfg, nparams, batch)
    mse = lambda a: float(jnp.mean((a.astype(jnp.float32)
                                    - full.astype(jnp.float32)) ** 2))
    return [
        {"strategy": "full", "logit_mse_vs_full": 0.0},
        {"strategy": "w/o merging errors (oracle)",
         "logit_mse_vs_full": mse(oracle)},
        {"strategy": "w/ merging errors (MergeMoE)",
         "logit_mse_vs_full": mse(merged_l)},
    ]


def fig_ratio() -> List[dict]:
    cfg, params = trained_model()
    calib = _eval_batches(cfg, n=2, corpus_seed=7)
    evalb = _eval_batches(cfg, n=3, corpus_seed=999)
    rows = []
    for merged in (8, 6, 4, 2):      # vary #experts (Fig. 2a)
        ncfg, npar, info = CMP.compress_model(
            cfg, params, method="mergemoe", merged_experts=merged, split=2,
            batches=calib)
        rows.append({"sweep": "experts", "merged": merged, "split": 2,
                     "ratio": round(info["compression_ratio"], 3),
                     "loss": round(_loss(ncfg, npar, evalb), 4)})
    for split in (3, 2, 1, 0):       # vary #layers (Fig. 2b)
        ncfg, npar, info = CMP.compress_model(
            cfg, params, method="mergemoe", merged_experts=4, split=split,
            batches=calib)
        rows.append({"sweep": "layers", "merged": 4, "split": split,
                     "ratio": round(info["compression_ratio"], 3),
                     "loss": round(_loss(ncfg, npar, evalb), 4)})
    return rows


def fig_timecost() -> List[dict]:
    cfg, params = trained_model()
    calib = _eval_batches(cfg, n=2, corpus_seed=7)
    rows = []
    for method in ("msmoe", "mergemoe"):
        t0 = time.perf_counter()
        CMP.compress_model(cfg, params, method=method, merged_experts=4,
                           split=0, batches=calib)
        rows.append({"method": method,
                     "t_total_s": round(time.perf_counter() - t0, 3)})
    return rows


def fig_samples() -> List[dict]:
    cfg, params = trained_model()
    evalb = _eval_batches(cfg, n=3, corpus_seed=999)
    calib_all = _eval_batches(cfg, n=4, corpus_seed=7)
    rows = []
    for max_tokens in (8, 32, 128, 512):
        ncfg, npar, info = CMP.compress_model(
            cfg, params, method="mergemoe", merged_experts=4, split=2,
            batches=calib_all, max_tokens=max_tokens)
        rows.append({"calib_tokens": max_tokens,
                     "loss": round(_loss(ncfg, npar, evalb), 4)})
    return rows
