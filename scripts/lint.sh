#!/usr/bin/env bash
# Static analysis entry point (DESIGN.md §9):
#
#   scripts/lint.sh                   # AST lint + kernel contracts
#   scripts/lint.sh --no-contracts    # AST rules only (fast)
#   scripts/lint.sh --arch qwen3-moe-30b-a3b   # contracts on one config
#
# Extra arguments are passed through to `python -m repro.analysis`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
