"""Fused SwiGLU MLP Pallas kernel (TPU target, interpret-validated on CPU).

Computes  y = (silu(x @ wg) * (x @ wu)) @ wd  without materializing the
[T, d_ff] intermediates in HBM: the grid tiles (tokens x d_ff), the hidden
block lives in VMEM, and the down-projection accumulates into an fp32 VMEM
scratch that is flushed to the output on the last d_ff block.

Blocking: bt x bf tiles, MXU-aligned (multiples of 128 where shapes allow);
the fp32 accumulator gives exact f32 accumulation across d_ff blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=F32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jnp.dot(h, wd_ref[...], preferred_element_type=F32)

    @pl.when(j == nf - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_t", "block_f", "interpret"))
def swiglu_mlp(x, wg, wu, wd, block_t: int = 256, block_f: int = 512,
               interpret: bool = False):
    """x: [T, d]; wg/wu: [d, f]; wd: [f, d] -> [T, d]."""
    T, d = x.shape
    f = wg.shape[1]
    bt = _block(T, block_t)
    bf = _block(f, block_f)
    nt, nf = T // bt, f // bf

    return pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, d), F32)],
        interpret=interpret,
    )(x, wg, wu, wd)
