"""Subprocess worker for the mesh-differential compression tests.

Runs ``compress_with_plan`` for a uniform and a heterogeneous plan — with
``--mesh SPEC`` on whatever devices the environment provides (the parent
test forces a 4-device host platform via XLA_FLAGS), without it on the
default single device — and emits a JSON record of content digests plus the
canonicalized report. The parent asserts the records are IDENTICAL across
device counts: the bit-for-bit contract of DESIGN.md §6.

Not a test module (no ``test_`` prefix); invoked by
``tests/test_dist_compress.py`` and reusable from the command line:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tests/_dist_compress_child.py --mesh data=2,model=2
"""
import argparse
import json
import sys

import jax
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import tree_digest
from repro.core import compress as CMP
from repro.core import plan as PLAN
from repro.models import model as MD

# volatile report keys: wall times and the mesh annotation (provenance) are
# the ONLY fields allowed to differ between a sharded and a single-device run
_VOLATILE = ("t_calibrate_s", "t_merge_s", "mesh")


def canonical_report(info: dict) -> dict:
    d = {k: v for k, v in info.items() if k not in _VOLATILE}
    d["plan"] = {k: v for k, v in d["plan"].items() if k != "mesh"}
    return d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch import mesh as MESH
        mesh = MESH.make_compression_mesh(args.mesh)

    cfg = configs.get("qwen3-moe-30b-a3b").reduced()
    params = MD.init(cfg, jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i), (8, 32),
                                             0, cfg.vocab_size)}
               for i in range(3)]

    plans = {
        "uniform": PLAN.uniform(cfg, method="mergemoe", merged_experts=4,
                                split=1),
        "hetero": PLAN.CompressionPlan((
            PLAN.LayerSpec(0, "mergemoe", 4),
            PLAN.LayerSpec(1, "average", 2),
        )),
    }

    out = {"devices": jax.device_count(), "mesh": args.mesh}
    for name, plan in plans.items():
        # max_tokens < total stream so the reservoir replacement schedule is
        # exercised, not just the fill phase
        ncfg, nparams, info = CMP.compress_with_plan(
            cfg, params, plan, batches=batches, max_tokens=100, mesh=mesh)
        moe = nparams["stack_c"]["moe"]
        out[name] = {
            "params_digest": tree_digest(nparams),
            "tables_digest": tree_digest(
                {k: moe[k] for k in ("wg", "wu", "wd")}),
            "remap": np.asarray(moe["remap"]).tolist(),
            "live": np.asarray(moe["live"]).tolist(),
            "report": canonical_report(info),
        }
    json.dump(out, sys.stdout)


if __name__ == "__main__":
    main()
