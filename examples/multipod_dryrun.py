"""Production-mesh dry-run from the public API: lower + compile one
(arch x shape) cell on the 512-chip multi-pod mesh and print its roofline.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        --arch qwen3-moe-30b-a3b --shape prefill_32k
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import dryrun as DR   # sets XLA device-count flags on import


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    rec = DR.run_cell(args.arch, args.shape, multi_pod=not args.single_pod)
    r = rec["roofline"]
    print(json.dumps({
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": rec["chips"], "profile": rec.get("profile"),
        "peak_GiB_per_dev": round(rec["mem_per_dev"]["peak"] / 2**30, 2),
        "t_compute_s": round(r["t_compute_s"], 3),
        "t_memory_s": round(r["t_memory_s"], 3),
        "t_collective_s": round(r["t_collective_s"], 3),
        "dominant": r["dominant"],
        "roofline_fraction": round(r["roofline_fraction"], 3),
    }, indent=1))


if __name__ == "__main__":
    main()
